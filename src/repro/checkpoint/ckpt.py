"""msgpack-based pytree checkpointing (orbax is not available offline).

Layout: ``<dir>/step_<N>.msgpack`` holding a flat map
``{path: {dtype, shape, data}}`` plus a ``__meta__`` entry.  Sharded arrays
are gathered to host before writing (fine at the scales this container
trains; the production path would write per-shard files — noted in
DESIGN.md).  bfloat16 round-trips via a uint16 view.
"""
from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np

import jax
import jax.numpy as jnp

_BF16 = "bfloat16"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload: Dict[str, Any] = {}
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
        if dtype == _BF16:
            data = np.asarray(jax.device_get(leaf)).view(np.uint16).tobytes()
        else:
            data = arr.tobytes()
        payload[_path_str(path)] = {
            "dtype": dtype,
            "shape": list(arr.shape),
            "data": data,
        }
    payload["__meta__"] = dict(meta or {}, step=step)
    fname = os.path.join(directory, f"step_{step}.msgpack")
    tmp = fname + ".tmp"
    # atomic publication: tmp + fsync + rename.  A reader (or a resumed
    # run) either sees the previous complete checkpoint or this complete
    # one — never a torn file, even across a kill/power-loss mid-write.
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, fname)
    try:  # persist the rename itself (directory entry)
        dfd = os.open(directory, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:  # platforms that refuse directory fsync
        pass
    return fname


def load_checkpoint(directory: str, step: Optional[int], like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a template pytree)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    fname = os.path.join(directory, f"step_{step}.msgpack")
    with open(fname, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    meta = payload.pop("__meta__", {})

    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat[0]:
        key = _path_str(path)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        entry = payload[key]
        if entry["dtype"] == _BF16:
            arr = np.frombuffer(entry["data"], dtype=np.uint16).reshape(entry["shape"])
            leaves.append(jnp.asarray(arr).view(jnp.bfloat16))
        else:
            arr = np.frombuffer(entry["data"], dtype=np.dtype(entry["dtype"]))
            leaves.append(jnp.asarray(arr.reshape(entry["shape"])))
    tree = jax.tree_util.tree_unflatten(flat[1], leaves)
    return tree, meta


def load_flat(directory: str, step: Optional[int] = None) -> Tuple[Dict[str, Any], Dict]:
    """Template-FREE restore: the raw ``{path: array}`` map plus meta.

    ``load_checkpoint`` needs a template pytree with the exact stored
    shapes — fine for params/server planes, impossible for a population
    store's ``{"ids": (M,), "rows": (M, P)}`` packing whose M (touched
    clients) is run-dependent.  This variant reconstructs every leaf from
    its stored dtype/shape instead; callers rebuild structure themselves
    (``HostPopulationStore.from_pytree`` consumes it directly)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    fname = os.path.join(directory, f"step_{step}.msgpack")
    with open(fname, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    meta = payload.pop("__meta__", {})
    out: Dict[str, Any] = {}
    for key, entry in payload.items():
        if entry["dtype"] == _BF16:
            arr = np.frombuffer(entry["data"], dtype=np.uint16).reshape(entry["shape"])
            out[key] = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(entry["data"], dtype=np.dtype(entry["dtype"]))
            out[key] = jnp.asarray(arr.reshape(entry["shape"]))
    return out, meta


def save_fed_run(directory: str, step: int, state: Any, population: Any = None,
                 residuals: Any = None, meta: Optional[Dict] = None) -> str:
    """One atomic snapshot of a whole federated run.

    Packs ``{"state": FedState}`` plus, when a host population store is
    in play, ``{"population": store.to_pytree()}`` into a single
    ``step_<N>.msgpack`` — the two halves publish together or not at all,
    so a kill between "state written" and "store written" cannot leave a
    resumable-but-inconsistent pair on disk.  ``population`` accepts the
    store object (``to_pytree`` is called) or an already-packed dict.

    ``residuals`` packs the top-k error-feedback residual store the same
    way under a ``"residuals"`` key (``FederatedEngine.residual_population``
    when compression runs against a host store).  RESIDENT residuals need
    no parameter: they are a leaf of the FedState and ride the ``state``
    template like every other plane."""
    tree: Dict[str, Any] = {"state": state}
    if population is not None:
        tree["population"] = (
            population.to_pytree() if hasattr(population, "to_pytree") else population
        )
    if residuals is not None:
        tree["residuals"] = (
            residuals.to_pytree() if hasattr(residuals, "to_pytree") else residuals
        )
    return save_checkpoint(directory, step, tree, meta=meta)


def _store_tree(flat: Dict[str, Any], prefix: str,
                num_clients: Optional[int]) -> Any:
    """Rebuild one packed store half (``population/…`` or ``residuals/…``)."""
    packed = {
        k.split("/", 1)[1]: np.asarray(v)
        for k, v in flat.items()
        if k.startswith(prefix + "/")
    }
    if not packed:
        return None
    if num_clients is not None:
        from repro.data.population import HostPopulationStore

        return HostPopulationStore.from_pytree(packed, num_clients)
    return packed


def load_fed_run(directory: str, step: Optional[int], like_state: Any,
                 num_clients: Optional[int] = None) -> Tuple[Any, Any, Any, Dict]:
    """Restore a ``save_fed_run`` snapshot → ``(state, population, residuals,
    meta)``.

    The FedState half restores through the template path (``like_state``
    fixes structure and dtypes; extra ``population/…``/``residuals/…`` keys
    in the payload are ignored by construction) — a template WITH a
    resident ``residuals`` plane restores it like any other leaf.  The
    store halves — whose packed ``(M, P)`` shapes no template can predict
    — restore template-free via ``load_flat`` and, when ``num_clients`` is
    given, come back as rebuilt ``HostPopulationStore``s; otherwise as the
    raw packed dicts.  Either is ``None`` when the snapshot carried no
    such store."""
    state, meta = load_checkpoint(directory, step, {"state": like_state})
    flat, _ = load_flat(directory, step if step is not None else meta.get("step"))
    population = _store_tree(flat, "population", num_clients)
    residuals = _store_tree(flat, "residuals", num_clients)
    return state["state"], population, residuals, meta


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.msgpack", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
