from repro.checkpoint.ckpt import (
    latest_step,
    load_checkpoint,
    load_fed_run,
    load_flat,
    save_checkpoint,
    save_fed_run,
)

__all__ = [
    "latest_step",
    "load_checkpoint",
    "load_fed_run",
    "load_flat",
    "save_checkpoint",
    "save_fed_run",
]
