"""Federated data pipeline.

``FederatedData`` holds the client-partitioned dataset as *stacked* arrays
(num_clients, n_per_client, ...) so an entire cohort's K local minibatches
can be gathered as one device-friendly array per round:

    batches = fed.sample_round_batches(rng, cohort_idx, K, batch_size)
    # -> {"x": (cohort, K, B, ...), "y": (cohort, K, B)}

which the round engine consumes with vmap(client)->scan(K).  On a mesh the
cohort axis is sharded over ("pod","data").
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.dirichlet import dirichlet_partition


class FederatedData:
    def __init__(
        self,
        x: np.ndarray,
        y: np.ndarray,
        num_clients: int,
        dirichlet_alpha: float = float("inf"),
        seed: int = 0,
    ) -> None:
        parts: List[np.ndarray] = dirichlet_partition(y, num_clients, dirichlet_alpha, seed=seed)
        n_per = min(len(p) for p in parts)
        self.num_clients = num_clients
        self.n_per_client = n_per
        self.client_x = jnp.asarray(np.stack([x[p[:n_per]] for p in parts]))  # (N, n, ...)
        self.client_y = jnp.asarray(np.stack([y[p[:n_per]] for p in parts]))  # (N, n)

    def sample_round_batches(
        self,
        rng: jax.Array,
        cohort_idx: jax.Array,  # (S,) int32 client ids
        local_steps: int,
        batch_size: int,
    ) -> Dict[str, jax.Array]:
        """Gather (S, K, B, ...) minibatches for the sampled cohort.

        Sampling is with replacement at the minibatch level (standard local
        SGD on small client datasets).  jit-safe: shapes depend only on
        (S, K, B).
        """
        S = cohort_idx.shape[0]
        idx = jax.random.randint(
            rng, (S, local_steps, batch_size), 0, self.n_per_client
        )
        x = self.client_x[cohort_idx[:, None, None], idx]
        y = self.client_y[cohort_idx[:, None, None], idx]
        return {"x": x, "y": y}

    def full_client_batch(self, client_ids: jax.Array) -> Dict[str, jax.Array]:
        """Full local dataset for given clients (used by MimeLite's full-batch
        gradient at x_t)."""
        return {"x": self.client_x[client_ids], "y": self.client_y[client_ids]}


def lm_batch_iterator(
    tokens: np.ndarray,  # (n_seqs, seq_len+1) or (n_seqs, seq_len)
    batch_size: int,
    seed: int = 0,
):
    """Infinite iterator of {"tokens": (B, S), "labels": (B, S)} for LM training.

    Labels are the inputs shifted by one; the final position predicts the
    next-sequence's first token is avoided by trimming.
    """
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        idx = rng.integers(0, n, size=batch_size)
        batch = tokens[idx]
        yield {
            "tokens": jnp.asarray(batch[:, :-1], dtype=jnp.int32),
            "labels": jnp.asarray(batch[:, 1:], dtype=jnp.int32),
        }
