"""Federated data pipeline.

``FederatedData`` holds the client-partitioned dataset as *stacked* arrays
(num_clients, n_per_client, ...) so an entire cohort's K local minibatches
can be gathered as one device-friendly array per round:

    batches = fed.sample_round_batches(rng, cohort_idx, K, batch_size)
    # -> {"x": (cohort, K, B, ...), "y": (cohort, K, B)}

which the round engine consumes with vmap(client)->scan(K).  On a mesh the
cohort axis is sharded over ("pod","data").

The gathers themselves live in the module-level pure functions
``gather_round_batches`` / ``gather_full_client_batch`` (arrays in, arrays
out, fully traceable) so the fused multi-round engine
(``FederatedEngine.run_rounds``) can draw minibatches *inside* its jitted
``lax.scan`` body instead of round-tripping to the host between rounds; the
``FederatedData`` methods are thin wrappers over the same functions.

This module assumes the whole population's data fits on device as one
stacked ``(N, n_per, ...)`` array — fine up to ~1e4 clients.  Beyond that,
``repro.data.population.StreamingClientData`` is the streaming counterpart:
it materializes ONLY the sampled cohort's shards per round on the host
(deterministically re-derived from ``(seed, client_id)``), pairing with the
out-of-core ``HostPopulationStore`` engine path.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.dirichlet import dirichlet_partition


def gather_round_batches(
    client_x: jax.Array,  # (N, n_per_client, ...)
    client_y: jax.Array,  # (N, n_per_client)
    rng: jax.Array,
    cohort_idx: jax.Array,  # (S,) int32 client ids
    local_steps: int,
    batch_size: int,
) -> Dict[str, jax.Array]:
    """Pure, jit-safe cohort minibatch gather: (S, K, B, ...) per field.

    Sampling is with replacement at the minibatch level (standard local SGD
    on small client datasets); shapes depend only on the static (S, K, B).
    """
    S = cohort_idx.shape[0]
    n_per = client_x.shape[1]
    idx = jax.random.randint(rng, (S, local_steps, batch_size), 0, n_per)
    x = client_x[cohort_idx[:, None, None], idx]
    y = client_y[cohort_idx[:, None, None], idx]
    return {"x": x, "y": y}


def gather_full_client_batch(
    client_x: jax.Array, client_y: jax.Array, client_ids: jax.Array
) -> Dict[str, jax.Array]:
    """Pure, jit-safe full-local-dataset gather (MimeLite's x_t gradient)."""
    return {"x": client_x[client_ids], "y": client_y[client_ids]}


class FederatedData:
    def __init__(
        self,
        x: np.ndarray,
        y: np.ndarray,
        num_clients: int,
        dirichlet_alpha: float = float("inf"),
        seed: int = 0,
    ) -> None:
        parts: List[np.ndarray] = dirichlet_partition(y, num_clients, dirichlet_alpha, seed=seed)
        n_per = min(len(p) for p in parts)
        self.num_clients = num_clients
        self.n_per_client = n_per
        self.client_x = jnp.asarray(np.stack([x[p[:n_per]] for p in parts]))  # (N, n, ...)
        self.client_y = jnp.asarray(np.stack([y[p[:n_per]] for p in parts]))  # (N, n)

    def sample_round_batches(
        self,
        rng: jax.Array,
        cohort_idx: jax.Array,  # (S,) int32 client ids
        local_steps: int,
        batch_size: int,
    ) -> Dict[str, jax.Array]:
        """Gather (S, K, B, ...) minibatches for the sampled cohort.

        Sampling is with replacement at the minibatch level (standard local
        SGD on small client datasets).  jit-safe: shapes depend only on
        (S, K, B).
        """
        return gather_round_batches(
            self.client_x, self.client_y, rng, cohort_idx, local_steps, batch_size
        )

    def full_client_batch(self, client_ids: jax.Array) -> Dict[str, jax.Array]:
        """Full local dataset for given clients (used by MimeLite's full-batch
        gradient at x_t)."""
        return gather_full_client_batch(self.client_x, self.client_y, client_ids)


def lm_batch_iterator(
    tokens: np.ndarray,  # (n_seqs, seq_len+1) or (n_seqs, seq_len)
    batch_size: int,
    seed: int = 0,
):
    """Infinite iterator of {"tokens": (B, S), "labels": (B, S)} for LM training.

    Labels are the inputs shifted by one; the final position predicts the
    next-sequence's first token is avoided by trimming.
    """
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        idx = rng.integers(0, n, size=batch_size)
        batch = tokens[idx]
        yield {
            "tokens": jnp.asarray(batch[:, :-1], dtype=jnp.int32),
            "labels": jnp.asarray(batch[:, 1:], dtype=jnp.int32),
        }
