"""Dirichlet non-IID client partitioner (paper appendix C.1).

For each client draw q ~ Dir(alpha * 1) over classes, then fill the client's
(balanced) quota by sampling training points class-by-class according to q.
alpha -> inf approaches IID; alpha -> 0 approaches single-class clients.
The split is balanced: every client holds exactly n_total // num_clients
points (paper §6.1 keeps client data balanced).
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    num_clients: int,
    alpha: float,
    seed: int = 0,
) -> List[np.ndarray]:
    """Returns a list of index arrays, one per client, balanced sizes.

    alpha=float('inf') (or <=0 treated as error) gives the IID split.
    """
    labels = np.asarray(labels)
    n = len(labels)
    per_client = n // num_clients
    rng = np.random.default_rng(seed)

    if np.isinf(alpha):
        perm = rng.permutation(n)
        return [perm[i * per_client : (i + 1) * per_client] for i in range(num_clients)]
    if alpha <= 0:
        raise ValueError("dirichlet alpha must be > 0 (use float('inf') for IID)")

    classes = np.unique(labels)
    n_classes = len(classes)
    # pools of shuffled indices per class, consumed front-to-back
    pools = {c: rng.permutation(np.nonzero(labels == c)[0]).tolist() for c in classes}
    out: List[np.ndarray] = []
    for _ in range(num_clients):
        q = rng.dirichlet(alpha * np.ones(n_classes))
        counts = rng.multinomial(per_client, q)
        idxs: List[int] = []
        for ci, c in enumerate(classes):
            take = min(counts[ci], len(pools[c]))
            idxs.extend(pools[c][:take])
            del pools[c][:take]
        # top up from whatever classes still have data (pool exhaustion)
        deficit = per_client - len(idxs)
        if deficit > 0:
            leftovers = [i for c in classes for i in pools[c]]
            rng.shuffle(leftovers)
            take = leftovers[:deficit]
            taken = set(take)
            for c in classes:
                pools[c] = [i for i in pools[c] if i not in taken]
            idxs.extend(take)
        out.append(np.asarray(idxs, dtype=np.int64))
    return out


def label_distribution(labels: np.ndarray, parts: List[np.ndarray], n_classes: int) -> np.ndarray:
    """(num_clients, n_classes) empirical label distribution per client."""
    dist = np.zeros((len(parts), n_classes))
    for i, idx in enumerate(parts):
        if len(idx) == 0:
            continue
        binc = np.bincount(labels[idx], minlength=n_classes).astype(np.float64)
        dist[i] = binc / binc.sum()
    return dist


def heterogeneity_score(labels: np.ndarray, parts: List[np.ndarray], n_classes: int) -> float:
    """Mean total-variation distance between client label dist and global dist.

    0 = perfectly IID; ->1 as clients become single-class.  Used by tests to
    assert that smaller Dirichlet alpha yields more heterogeneity.
    """
    dist = label_distribution(labels, parts, n_classes)
    global_dist = np.bincount(labels, minlength=n_classes).astype(np.float64)
    global_dist /= global_dist.sum()
    return float(0.5 * np.abs(dist - global_dist[None]).sum(axis=1).mean())
