"""Million-client population layer: out-of-core client state + availability.

The engine's client-population axis has two resource problems at fleet
scale (paper §6.2 is ABOUT fleets):

1. stateful specs (scaffold c_i, feddyn λ_i) keep a stacked ``(N, P)``
   device plane — 80 GB at N=1e6 for a 20k-param model, before the model
   itself;
2. ``FederatedData`` stacks every client's shard into ``(N, n_per, …)``
   device arrays — same wall.

This module removes both:

``HostPopulationStore``
    A sparse host-memory store of per-client flat state rows.  Rows are
    ``np.float32 (P,)`` (the flat plane's wire dtype — see
    ``repro.core.flat.FlatSpec``), keyed by client id, zero until first
    written.  The engine gathers a dense ``(C, P)`` block for the cohort
    before the round scan and scatters the updated block back after the
    fold — one contiguous indexed copy each way, so device memory scales
    with the COHORT and host memory with the set of *touched* clients.
    The resident ``(N, P)`` path stays as the bitwise oracle behind
    ``cfg.population_store="resident"`` (tests/test_population.py pins
    f32-bitwise agreement on sync and async engines).

``availability_log_weights``
    Pluggable client-availability processes as pure data on ``FedConfig``
    (``availability`` + its knobs): uniform (legacy, bitwise-preserved),
    Zipf-skewed traffic, and a time-of-day sinusoid phase-distributed over
    clients.  The sampler (``engine.sample_cohort_ex``) turns the log
    weights into a Gumbel top-k draw without replacement, plus per-client
    Bernoulli thinning under ``participation="bernoulli"`` and optional
    straggler dropout.

``StreamingClientData``
    A virtual federated dataset: per-client shards are regenerated
    deterministically from ``(seed, client_id)`` on demand, so only the
    sampled cohort's minibatches ever materialize.  Duck-types the subset
    of ``FederatedData`` the store-backed host loop needs
    (``host_round_batches`` / ``host_full_batches`` / ``test_set``).

See data/README.md ("Population store & streaming availability") for the
layout and semantics contract.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

import jax.numpy as jnp

AVAILABILITY_PROCESSES = ("uniform", "zipf", "diurnal")
POPULATION_STORES = ("resident", "host")


# ----------------------------------------------------------------------
# availability processes
# ----------------------------------------------------------------------


def availability_log_weights(cfg, t=None):
    """``(N,)`` f32 log availability weights for round ``t`` — or ``None``
    for the uniform process.

    ``None`` is load-bearing: the sampler keeps the exact legacy
    ``jax.random.choice`` / scalar-p Bernoulli branch when no weights are
    given, so every pre-existing trajectory stays bitwise-identical.
    ``t`` may be a traced round counter (the diurnal process is the only
    one that reads it; ``None`` means t=0).
    """
    avail = getattr(cfg, "availability", "uniform")
    if avail == "uniform":
        return None
    n = cfg.num_clients
    i = jnp.arange(n, dtype=jnp.float32)
    if avail == "zipf":
        # traffic skew: w_i ∝ (i+1)^-s  (client ids double as a popularity
        # ranking — the partial-participation survey's head/tail split)
        return -jnp.float32(getattr(cfg, "zipf_exponent", 1.1)) * jnp.log1p(i)
    if avail == "diurnal":
        # time-of-day sinusoid: client i peaks at phase i/N of a
        # `diurnal_period`-round day; amplitude→1 approaches on/off
        period = jnp.float32(getattr(cfg, "diurnal_period", 24.0))
        amp = jnp.float32(getattr(cfg, "diurnal_amplitude", 0.8))
        tt = jnp.float32(0.0) if t is None else jnp.asarray(t, jnp.float32)
        avail_i = 1.0 + amp * jnp.sin(2.0 * jnp.pi * (tt / period + i / jnp.float32(n)))
        return jnp.log(jnp.maximum(avail_i, 1e-6))
    raise ValueError(
        f"unknown availability process {avail!r}; known: {AVAILABILITY_PROCESSES}"
    )


# ----------------------------------------------------------------------
# client-state store
# ----------------------------------------------------------------------


class HostPopulationStore:
    """Sparse host-memory store of per-client flat state rows.

    Layout: ``{client_id: np.float32 (plane_size,)}`` — a client absent
    from the dict reads as the zero row (every registered client-state
    init is zeros, so "never touched" and "explicit zeros" coincide).
    ``gather``/``scatter`` are the ONLY engine-facing operations; both are
    dense contiguous copies over the cohort axis.

    Checkpointing: ``to_pytree()`` packs the touched rows into
    ``{"ids": int32 (M,), "rows": f32 (M, P)}`` (ids sorted, M = touched
    count) — a shape no template can predict, hence
    ``repro.checkpoint.ckpt.load_flat`` (template-free restore).
    """

    def __init__(self, num_clients: int, plane_size: int, dtype=np.float32):
        self.num_clients = int(num_clients)
        self.plane_size = int(plane_size)
        self.dtype = np.dtype(dtype)
        self._rows: Dict[int, np.ndarray] = {}

    @property
    def touched(self) -> int:
        """Number of clients whose state has ever been written."""
        return len(self._rows)

    @property
    def nbytes(self) -> int:
        return len(self._rows) * self.plane_size * self.dtype.itemsize

    def gather(self, ids: np.ndarray) -> np.ndarray:
        """Dense ``(C, P)`` block of the cohort's rows (zeros if unwritten)."""
        ids = np.asarray(ids)
        out = np.zeros((ids.shape[0], self.plane_size), dtype=self.dtype)
        for r, cid in enumerate(ids):
            row = self._rows.get(int(cid))
            if row is not None:
                out[r] = row
        return out

    def scatter(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Write the cohort's updated rows back (row r → client ids[r]).

        Every row is written — including inactive (w=0) clients, whose row
        the engine emits unchanged — mirroring the resident plane's
        ``at[ids].set`` semantics exactly (bitwise, incl. signed zeros).
        Cohorts are drawn without replacement, so ids are unique per call.
        """
        rows = np.asarray(rows, dtype=self.dtype)
        if rows.shape != (len(ids), self.plane_size):
            raise ValueError(
                f"scatter rows shape {rows.shape} != ({len(ids)}, {self.plane_size})"
            )
        for r, cid in enumerate(np.asarray(ids)):
            self._rows[int(cid)] = np.array(rows[r], dtype=self.dtype)

    # -- checkpoint packing ------------------------------------------------

    def to_pytree(self) -> Dict[str, np.ndarray]:
        ids = np.array(sorted(self._rows), dtype=np.int32)
        if len(ids):
            rows = np.stack([self._rows[int(i)] for i in ids]).astype(self.dtype)
        else:
            rows = np.zeros((0, self.plane_size), dtype=self.dtype)
        return {"ids": ids, "rows": rows}

    @classmethod
    def from_pytree(cls, tree: Dict[str, Any], num_clients: int,
                    plane_size: Optional[int] = None) -> "HostPopulationStore":
        ids = np.asarray(tree["ids"])
        rows = np.asarray(tree["rows"])
        if plane_size is None:
            plane_size = rows.shape[1] if rows.ndim == 2 else 0
        store = cls(num_clients, plane_size, dtype=rows.dtype if rows.size else np.float32)
        store.scatter(ids, rows)
        return store


class TransientStoreError(RuntimeError):
    """A host-store gather/scatter failed transiently (injected or real).

    The engine's retry contract (``FaultConfig.store_max_retries`` /
    ``store_backoff_base`` / ``store_backoff_cap``): retry the SAME pure
    operation with capped exponential backoff, re-raise once retries are
    exhausted.  Retries never change math — a run that needed them is
    bitwise-equal to one that didn't."""


class FaultyStore:
    """Deterministic chaos wrapper around a population store: each
    ``gather``/``scatter`` call independently raises
    :class:`TransientStoreError` with ``failure_rate`` probability BEFORE
    delegating (a failed call has no side effects, so retrying is safe).
    The failure stream is host-side ``numpy`` RNG — each retry consumes a
    fresh draw, so a retried operation eventually succeeds.  Everything
    else (``touched``/``nbytes``/``to_pytree``/…) passes through to the
    wrapped store via ``inner``."""

    def __init__(self, inner: HostPopulationStore, failure_rate: float,
                 seed: int = 0):
        self.inner = inner
        self.failure_rate = float(failure_rate)
        self._rng = np.random.default_rng((int(seed), 0xFA17))

    def _maybe_fail(self, op: str) -> None:
        if self._rng.random() < self.failure_rate:
            raise TransientStoreError(f"injected transient store {op} failure")

    def gather(self, ids: np.ndarray) -> np.ndarray:
        self._maybe_fail("gather")
        return self.inner.gather(ids)

    def scatter(self, ids: np.ndarray, rows: np.ndarray) -> None:
        self._maybe_fail("scatter")
        return self.inner.scatter(ids, rows)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def make_population_store(cfg, plane_size: int) -> Optional[HostPopulationStore]:
    """Store instance for ``cfg.population_store`` — ``None`` for resident.
    When ``cfg.fault`` injects transient store failures the store comes
    back wrapped in :class:`FaultyStore` (the engine retries through it)."""
    kind = getattr(cfg, "population_store", "resident")
    if kind == "resident":
        return None
    if kind != "host":
        raise ValueError(
            f"unknown population_store {kind!r}; known: {POPULATION_STORES}"
        )
    store = HostPopulationStore(cfg.num_clients, plane_size)
    fault = getattr(cfg, "fault", None)
    if fault is not None and getattr(fault, "store_failure_rate", 0.0) > 0.0:
        return FaultyStore(store, fault.store_failure_rate,
                           seed=getattr(fault, "seed", 0))
    return store


# ----------------------------------------------------------------------
# streaming federated data
# ----------------------------------------------------------------------


class StreamingClientData:
    """On-demand per-client synthetic shards for store-backed populations.

    ``FederatedData`` stacks all N clients' shards into device arrays —
    impossible at N=1e6.  Here each client's shard is a pure function of
    ``(seed, client_id)`` (same Gaussian-mixture family as
    ``make_synthetic_classification``, with label skew via a dominant
    class ``cid % n_classes``), regenerated on the host whenever that
    client is sampled.  Only the cohort's ``(C, K, B, …)`` minibatch block
    ever exists as an array.
    """

    def __init__(self, num_clients: int, dim: int = 32, n_classes: int = 10,
                 n_per_client: int = 50, noise: float = 1.0,
                 separation: float = 2.0, label_skew: float = 0.7,
                 seed: int = 0):
        self.num_clients = int(num_clients)
        self.dim = int(dim)
        self.n_classes = int(n_classes)
        self.n_per_client = int(n_per_client)
        self.noise = float(noise)
        self.label_skew = float(label_skew)
        self.seed = int(seed)
        rng = np.random.default_rng(seed)
        self.means = (rng.normal(size=(n_classes, dim)) * separation).astype(np.float32)
        self.maps = (rng.normal(size=(n_classes, dim, dim))
                     * (0.3 / np.sqrt(dim))).astype(np.float32)

    # -- per-client generation --------------------------------------------

    def client_dataset(self, cid: int):
        """``(x (n_per, dim) f32, y (n_per,) i32)`` — deterministic in cid."""
        rng = np.random.default_rng((self.seed, 977, int(cid)))
        n = self.n_per_client
        dominant = int(cid) % self.n_classes
        take = rng.random(n) < self.label_skew
        y = np.where(take, dominant,
                     rng.integers(0, self.n_classes, size=n)).astype(np.int32)
        eps = rng.normal(size=(n, self.dim)).astype(np.float32)
        x = (self.means[y] + np.einsum("nij,nj->ni", self.maps[y], eps)
             + self.noise * rng.normal(size=(n, self.dim)))
        return x.astype(np.float32), y

    # -- host-loop batch interface ----------------------------------------

    def host_round_batches(self, ids: np.ndarray, seed: int,
                           local_steps: int, batch_size: int) -> Dict[str, np.ndarray]:
        """Cohort minibatch block ``{"x": (C,K,B,dim), "y": (C,K,B)}``.

        ``seed`` is the round's batch key (the engine derives it from the
        same rng stream the device path splits), so resampling is
        deterministic per round.
        """
        ids = np.asarray(ids)
        rng = np.random.default_rng(int(seed))
        C = ids.shape[0]
        x = np.empty((C, local_steps, batch_size, self.dim), np.float32)
        y = np.empty((C, local_steps, batch_size), np.int32)
        for r, cid in enumerate(ids):
            cx, cy = self.client_dataset(int(cid))
            idx = rng.integers(0, self.n_per_client, size=(local_steps, batch_size))
            x[r] = cx[idx]
            y[r] = cy[idx]
        return {"x": x, "y": y}

    def host_full_batches(self, ids: np.ndarray) -> Dict[str, np.ndarray]:
        """Full client shards ``{"x": (C, n_per, dim), "y": (C, n_per)}``
        (mime-style full-batch gradients)."""
        ids = np.asarray(ids)
        C = ids.shape[0]
        x = np.empty((C, self.n_per_client, self.dim), np.float32)
        y = np.empty((C, self.n_per_client), np.int32)
        for r, cid in enumerate(ids):
            x[r], y[r] = self.client_dataset(int(cid))
        return {"x": x, "y": y}

    def test_set(self, n_test: int = 2_000):
        """Held-out iid test split from the same mixture (no label skew)."""
        rng = np.random.default_rng((self.seed, 1009))
        y = rng.integers(0, self.n_classes, size=n_test).astype(np.int32)
        eps = rng.normal(size=(n_test, self.dim)).astype(np.float32)
        x = (self.means[y] + np.einsum("nij,nj->ni", self.maps[y], eps)
             + self.noise * rng.normal(size=(n_test, self.dim)))
        return x.astype(np.float32), y
