from repro.data.dirichlet import dirichlet_partition, label_distribution, heterogeneity_score
from repro.data.synthetic import (
    make_synthetic_classification,
    make_synthetic_images,
    make_synthetic_lm,
)
from repro.data.pipeline import FederatedData, lm_batch_iterator

__all__ = [
    "dirichlet_partition",
    "label_distribution",
    "heterogeneity_score",
    "make_synthetic_classification",
    "make_synthetic_images",
    "make_synthetic_lm",
    "FederatedData",
    "lm_batch_iterator",
]
