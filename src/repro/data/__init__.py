from repro.data.dirichlet import dirichlet_partition, label_distribution, heterogeneity_score
from repro.data.synthetic import (
    make_synthetic_classification,
    make_synthetic_images,
    make_synthetic_lm,
)
from repro.data.pipeline import FederatedData, lm_batch_iterator
from repro.data.population import (
    FaultyStore,
    HostPopulationStore,
    StreamingClientData,
    TransientStoreError,
    availability_log_weights,
    make_population_store,
)

__all__ = [
    "dirichlet_partition",
    "label_distribution",
    "heterogeneity_score",
    "make_synthetic_classification",
    "make_synthetic_images",
    "make_synthetic_lm",
    "FederatedData",
    "lm_batch_iterator",
    "FaultyStore",
    "HostPopulationStore",
    "StreamingClientData",
    "TransientStoreError",
    "availability_log_weights",
    "make_population_store",
]
