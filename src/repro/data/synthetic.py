"""Synthetic datasets (the container has no CIFAR on disk; see DESIGN.md §2).

Three generators:

* ``make_synthetic_classification`` — Gaussian-mixture vectors; linearly
  non-separable (class means + per-class rotations), learnable by an MLP.
  Stands in for CIFAR10/100 in the scaled paper reproduction.
* ``make_synthetic_images`` — tiny (C,H,W) images built from per-class
  frequency templates + noise; learnable by a small CNN.
* ``make_synthetic_lm`` — token streams from a random first-order Markov
  chain (low-entropy rows), so next-token loss has real signal; used for the
  centralized-LM example and the federated-LM example (clients get chains
  with different transition matrices = natural heterogeneity).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_synthetic_classification(
    n_classes: int = 10,
    dim: int = 32,
    n_train: int = 50_000,
    n_test: int = 10_000,
    noise: float = 1.0,
    separation: float = 2.0,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test); x float32, y int32.

    ``separation``/``noise`` set the Bayes error: separation=2, noise=1 is
    near-separable; separation~0.9, noise~2 gives a CIFAR-like irreducible
    error band where optimizer differences are visible."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(n_classes, dim)) * separation
    # per-class linear map to make the task non-trivial for linear models
    maps = rng.normal(size=(n_classes, dim, dim)) * (0.3 / np.sqrt(dim))

    def sample(n):
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        eps = rng.normal(size=(n, dim)).astype(np.float32)
        x = means[y] + np.einsum("nij,nj->ni", maps[y], eps) + noise * rng.normal(size=(n, dim))
        return x.astype(np.float32), y

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te


def make_synthetic_images(
    n_classes: int = 10,
    hw: int = 8,
    channels: int = 3,
    n_train: int = 20_000,
    n_test: int = 4_000,
    noise: float = 0.6,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Tiny images: class template (smooth random field) + noise. NHWC float32."""
    rng = np.random.default_rng(seed)
    # smooth templates: low-frequency random fields per class
    freqs = rng.normal(size=(n_classes, channels, 3, 3))
    yy, xx = np.meshgrid(np.linspace(0, 1, hw), np.linspace(0, 1, hw), indexing="ij")
    basis = np.stack(
        [np.ones_like(xx), np.sin(2 * np.pi * xx), np.sin(2 * np.pi * yy),
         np.cos(2 * np.pi * xx), np.cos(2 * np.pi * yy), np.sin(4 * np.pi * xx),
         np.sin(4 * np.pi * yy), np.sin(2 * np.pi * (xx + yy)), np.cos(2 * np.pi * (xx - yy))],
        axis=-1,
    )  # (hw, hw, 9)
    templates = np.einsum("hwb,ncb->nchw", basis, freqs.reshape(n_classes, channels, 9))

    def sample(n):
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        x = templates[y] + noise * rng.normal(size=(n, channels, hw, hw))
        return np.transpose(x, (0, 2, 3, 1)).astype(np.float32), y  # NHWC

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te


def make_synthetic_lm(
    vocab_size: int = 512,
    seq_len: int = 256,
    n_seqs: int = 4096,
    temperature: float = 0.3,
    seed: int = 0,
    transition: np.ndarray | None = None,
) -> np.ndarray:
    """(n_seqs, seq_len) int32 tokens from a first-order Markov chain.

    ``temperature`` controls row entropy (lower = more predictable = lower
    achievable loss).  Pass ``transition`` to share/perturb chains across
    federated clients.
    """
    rng = np.random.default_rng(seed)
    if transition is None:
        logits = rng.normal(size=(vocab_size, vocab_size)) / max(temperature, 1e-3)
        transition = _softmax(logits)
    toks = np.empty((n_seqs, seq_len), dtype=np.int32)
    state = rng.integers(0, vocab_size, size=n_seqs)
    toks[:, 0] = state
    # vectorized chain stepping via inverse-CDF sampling
    cdf = np.cumsum(transition, axis=1)
    for t in range(1, seq_len):
        u = rng.random(n_seqs)
        state = (cdf[state] < u[:, None]).sum(axis=1)
        state = np.minimum(state, vocab_size - 1)
        toks[:, t] = state
    return toks


def make_markov_transition(vocab_size: int, temperature: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(vocab_size, vocab_size)) / max(temperature, 1e-3)
    return _softmax(logits)


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)
