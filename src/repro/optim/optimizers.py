"""Minimal functional optimizer library (optax is not installed offline).

An ``Optimizer`` is a pair of pure functions:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    new_params = tree_add(params, updates)        # updates already contain -lr

Used for (a) the centralized training driver (AdamW), (b) server optimizers
in federated algorithms (SGD / momentum / Adam for FedAdam), (c) client
local SGD.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.trees import tree_norm


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def sgd(lr) -> Optimizer:
    """lr may be a float or a schedule fn step->lr; state = step count."""
    sched = _as_schedule(lr)

    def init(params):
        return jnp.zeros([], jnp.int32)

    def update(grads, state, params=None):
        step_lr = sched(state)
        updates = jax.tree_util.tree_map(lambda g: -step_lr * g, grads)
        return updates, state + 1

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return (jnp.zeros([], jnp.int32), jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        step, mu = state
        mu = jax.tree_util.tree_map(lambda m, g: beta * m + g, mu, grads)
        if nesterov:
            eff = jax.tree_util.tree_map(lambda m, g: beta * m + g, mu, grads)
        else:
            eff = mu
        step_lr = sched(step)
        updates = jax.tree_util.tree_map(lambda e: -step_lr * e, eff)
        return updates, (step + 1, mu)

    return Optimizer(init, update)


def adam(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    eps_root: float = 0.0,
    bias_correction: bool = True,
    moment_dtype=jnp.float32,
) -> Optimizer:
    """``moment_dtype``: f32 default; bf16 halves optimizer HBM for the
    largest archs (llama4-class) — the update math still runs in f32."""
    sched = _as_schedule(lr)
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=mdt), params)
        return (jnp.zeros([], jnp.int32), z, jax.tree_util.tree_map(jnp.copy, z))

    def update(grads, state, params=None):
        step, m, v = state
        m = jax.tree_util.tree_map(
            lambda mi, g: (b1 * mi.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(mdt),
            m, grads,
        )
        v = jax.tree_util.tree_map(
            lambda vi, g: (b2 * vi.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(mdt),
            v, grads,
        )
        step1 = step + 1
        if bias_correction:
            c1 = 1.0 - b1 ** step1.astype(jnp.float32)
            c2 = 1.0 - b2 ** step1.astype(jnp.float32)
        else:
            c1 = c2 = jnp.float32(1.0)
        step_lr = sched(step)

        def upd(mi, vi):
            mhat = mi / c1
            vhat = vi / c2
            return -step_lr * mhat / (jnp.sqrt(vhat + eps_root) + eps)

        updates = jax.tree_util.tree_map(upd, m, v)
        return updates, (step1, m, v)

    return Optimizer(init, update)


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    mask: Optional[Callable[[Any], Any]] = None,
    moment_dtype=jnp.float32,
) -> Optimizer:
    """AdamW with decoupled weight decay. ``mask(params)`` -> pytree of bools
    selecting which leaves are decayed (default: every leaf with ndim >= 2)."""
    base = adam(lr, b1=b1, b2=b2, eps=eps, moment_dtype=moment_dtype)
    sched = _as_schedule(lr)

    def default_mask(params):
        return jax.tree_util.tree_map(lambda p: p.ndim >= 2, params)

    mask_fn = mask or default_mask

    def init(params):
        return base.init(params)

    def update(grads, state, params):
        step = state[0]
        updates, state = base.update(grads, state, params)
        step_lr = sched(step)
        decay_mask = mask_fn(params)
        updates = jax.tree_util.tree_map(
            lambda u, p, m: u - step_lr * weight_decay * p.astype(u.dtype) * jnp.asarray(m),
            updates,
            params,
            decay_mask,
        )
        return updates, state

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, pre_clip_norm)."""
    gnorm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1):
    """Linear warmup then cosine decay to floor*peak."""

    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def exponential_decay(base_lr: float, decay: float):
    """Paper appendix C.2: eta_l decayed exponentially per round."""

    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        return base_lr * decay**step

    return sched


def _as_schedule(lr):
    if callable(lr):
        return lr
    return lambda step: jnp.float32(lr)
