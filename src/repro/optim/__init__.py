from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    clip_by_global_norm,
    momentum,
    sgd,
    warmup_cosine,
)

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "clip_by_global_norm",
    "momentum",
    "sgd",
    "warmup_cosine",
]
