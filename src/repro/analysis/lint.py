"""Layer 1 — AST lint: repo invariants as executable rules (REP001–005).

The engine's correctness conventions are encoded as AST rules over
``src/repro``.  Each rule carries an ID and a docstring whose first line
is the invariant and whose body opens with the rationale; the README
rule table is generated from exactly those docstrings
(``python -m repro.analysis.lint --write``, byte-agreement enforced by
``tests/test_analysis.py``).

Suppression: a finding is silenced by an inline comment **on the same
line**, with a mandatory justification::

    ids = np.asarray(dev_ids)  # repro: noqa REP003 -- host loop boundary

Reason-less ``noqa`` comments are ignored — a suppression without a
justification is itself a convention violation.

Grandfathered findings live in ``src/repro/analysis/baseline.txt``
(regenerate with ``--baseline``); the CLI exits non-zero only on
findings absent from the baseline, so CI blocks on *new* violations
while the shipped baseline stays empty or justified line-by-line.
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

REPO_SRC = Path(__file__).resolve().parents[2]  # .../src
BASELINE_PATH = Path(__file__).resolve().parent / "baseline.txt"
README_PATH = Path(__file__).resolve().parent / "README.md"

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*noqa\s+(?P<ids>REP\d{3}(?:\s*,\s*REP\d{3})*)\s*--\s*(?P<reason>\S.*)$"
)


# ------------------------------------------------------------------ model
@dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # posix, repo-relative (or the virtual path given to lint_sources)
    line: int
    col: int
    message: str
    line_text: str

    @property
    def baseline_key(self) -> str:
        # keyed on content, not line number, so unrelated edits above a
        # grandfathered line don't churn the baseline
        return f"{self.path}::{self.rule}::{self.line_text.strip()}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class ParsedModule:
    """One source file: AST + parent links + import map + suppressions."""

    def __init__(self, path: str, source: str):
        self.path = path.replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        self.imports = _import_map(self.tree)
        self.suppressions: Dict[int, Tuple[set, str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if m:
                ids = {s.strip() for s in m.group("ids").split(",")}
                self.suppressions[i] = (ids, m.group("reason").strip())

    @property
    def dotted(self) -> str:
        """Module import path, derived from the file path (``repro.…``)."""
        parts = Path(self.path).with_suffix("").parts
        if "repro" in parts:
            parts = parts[parts.index("repro"):]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def line_text(self, lineno: int) -> str:
        return self.lines[lineno - 1] if 0 < lineno <= len(self.lines) else ""

    def suppressed(self, rule: str, lineno: int) -> bool:
        sup = self.suppressions.get(lineno)
        return bool(sup and rule in sup[0])


def _import_map(tree: ast.Module) -> Dict[str, str]:
    """Local alias → fully dotted path (``jnp`` → ``jax.numpy``)."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _dotted(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Resolve an attribute chain to a dotted path through the import map."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(imports.get(node.id, node.id))
        return ".".join(reversed(parts))
    return None


def _region(fn: ast.AST) -> Iterator[ast.AST]:
    """All nodes of ``fn``'s body without descending into nested defs
    (nested functions are separate call-graph nodes; lambdas are part of
    the enclosing region)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(n))


# ------------------------------------------------------------------ rules
class Rule:
    id: str = "REP000"
    scope_doc: str = "src/repro"

    def applies(self, path: str) -> bool:
        return True

    def check(self, mod: ParsedModule, ctx: "RepoContext") -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod: ParsedModule, node: ast.AST, message: str) -> Finding:
        return Finding(self.id, mod.path, node.lineno, node.col_offset,
                       message, mod.line_text(node.lineno))


class REP001(Rule):
    """No name-keyed algorithm branches in ``core/``, ``kernels/``, ``sharding/``.

    Algorithms are ``AlgorithmSpec`` data; the registry is the only
    dispatch point.  A ``cfg.algo == "fedcm"`` branch in the engine or
    kernels silently diverges the moment a new spec registers, so any
    comparison of an ``algo``-named value against string literals is a
    finding.  Replaces the ad-hoc ``grep 'algo =='`` convention check.
    """

    id = "REP001"
    scope_doc = "core/, kernels/, sharding/"

    def applies(self, path: str) -> bool:
        return any(seg in path for seg in ("/core/", "/kernels/", "/sharding/"))

    @staticmethod
    def _algoish(node: ast.AST, imports: Dict[str, str]) -> bool:
        d = _dotted(node, imports)
        if not d:
            return False
        return any(p in ("algo", "algo_name", "algorithm") for p in d.split("."))

    @staticmethod
    def _has_str(node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, str)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(REP001._has_str(e) for e in node.elts)
        return False

    def check(self, mod, ctx):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare):
                continue
            ok_ops = (ast.Eq, ast.NotEq, ast.In, ast.NotIn)
            if not all(isinstance(op, ok_ops) for op in node.ops):
                continue
            sides = [node.left] + list(node.comparators)
            if any(self._algoish(s, mod.imports) for s in sides) and any(
                self._has_str(s) for s in sides
            ):
                yield self.finding(
                    mod, node,
                    "name-keyed algorithm branch — dispatch through the "
                    "AlgorithmSpec registry, not algo-name strings",
                )


class REP002(Rule):
    """Version-sensitive jax APIs must route through ``utils/compat.py``.

    ``set_mesh`` / ``shard_map`` / mesh constructors moved or were
    renamed across jax releases; ``utils/compat.py`` resolves the
    installed spelling per call.  A direct call anywhere else reverts to
    hand-rolled version checks and breaks on the next jax pin bump.
    """

    id = "REP002"
    scope_doc = "src/repro (except utils/compat.py)"

    BANNED = {
        "jax.set_mesh": "compat.set_mesh",
        "jax.sharding.use_mesh": "compat.set_mesh",
        "jax.shard_map": "compat.shard_map",
        "jax.experimental.shard_map.shard_map": "compat.shard_map",
        "jax.make_mesh": "compat.make_mesh",
        "jax.experimental.mesh_utils.create_device_mesh": "compat.make_mesh",
        "jax.sharding.Mesh": "compat.device_mesh",
        "jax.interpreters.pxla.Mesh": "compat.device_mesh",
    }

    def applies(self, path: str) -> bool:
        return not path.endswith("utils/compat.py")

    def check(self, mod, ctx):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func, mod.imports)
            repl = self.BANNED.get(d or "")
            if repl:
                yield self.finding(
                    mod, node,
                    f"direct call to version-sensitive `{d}` — use "
                    f"`repro.utils.{repl}` instead",
                )


class REP003(Rule):
    """No host-sync calls inside functions jitted or scanned by the engine.

    A ``.item()`` / ``float(traced)`` / ``np.asarray`` / ``print``
    inside the fused ``lax.scan`` either fails to trace or, worse,
    silently freezes a traced value at trace time.  The rule resolves
    the jit/scan call graph (``jax.jit`` decorators and call sites,
    ``lax.scan``/``cond``/``vmap``/``shard_map`` function arguments,
    nested defs) and walks every reachable function.  ``float``/``int``/
    ``bool`` of static config attributes (``cfg.x``, ``getattr(cfg, …)``)
    is exempt — those are Python values at trace time.
    """

    id = "REP003"
    scope_doc = "functions reachable from jit/scan roots (repo-wide graph)"

    BANNED_DOTTED = {
        "numpy.asarray": "np.asarray",
        "numpy.array": "np.array",
        "jax.device_get": "jax.device_get",
        "time.sleep": "time.sleep",
    }
    CASTS = {"float", "int", "bool"}
    STATIC_CALLS = {"getattr", "len", "min", "max", "abs", "round", "pow"}

    @classmethod
    def _static_arg(cls, node: ast.AST) -> bool:
        """Conservatively: does this expression look like a trace-time
        Python value (config attribute chains, literals) rather than a
        traced array?"""
        if isinstance(node, (ast.Constant, ast.Attribute)):
            return True
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute):
                # math.* raises on tracers, so a math.* result is static
                # by construction
                return (isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "math")
            return isinstance(node.func, ast.Name) and node.func.id in cls.STATIC_CALLS
        if isinstance(node, ast.BinOp):
            return cls._static_arg(node.left) and cls._static_arg(node.right)
        if isinstance(node, ast.UnaryOp):
            return cls._static_arg(node.operand)
        if isinstance(node, ast.IfExp):
            return all(cls._static_arg(n)
                       for n in (node.body, node.test, node.orelse))
        return False

    def check(self, mod, ctx):
        for key in ctx.reachable:
            fpath, _ = key
            if fpath != mod.path:
                continue
            fn = ctx.functions[key].node
            for node in _region(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "item":
                    yield self.finding(
                        mod, node,
                        ".item() host-syncs inside a traced function",
                    )
                    continue
                d = _dotted(f, mod.imports)
                if d in self.BANNED_DOTTED:
                    yield self.finding(
                        mod, node,
                        f"`{self.BANNED_DOTTED[d]}` host-syncs inside a "
                        "traced function",
                    )
                    continue
                if isinstance(f, ast.Name):
                    if f.id == "print":
                        yield self.finding(
                            mod, node,
                            "print() inside a traced function (use "
                            "jax.debug.print if intentional)",
                        )
                    elif (f.id in self.CASTS and node.args
                          and not self._static_arg(node.args[0])):
                        yield self.finding(
                            mod, node,
                            f"{f.id}() on a (potentially) traced value "
                            "host-syncs; keep it, cast with .astype, or "
                            "mark static config reads as attributes",
                        )


class REP004(Rule):
    """Every ``jax.random`` draw consumes a ``split``/``fold_in`` key, never a reused one.

    Reusing a key across two draws silently correlates streams that must
    stay independent (cohort sampling, fault realization, batch choice);
    drawing from a stored raw key (``state.rng``) makes
    the round non-reproducible under resume.  Tracks per-function key
    bindings (branch-aware; loop bodies are analyzed twice to catch
    cross-iteration reuse) and flags reused or raw-attribute keys.
    """

    id = "REP004"
    scope_doc = "src/repro"

    PRODUCERS = {"split", "fold_in", "PRNGKey", "key", "clone"}
    NON_DRAWS = PRODUCERS | {"wrap_key_data", "key_data", "key_impl", "bits_dtype"}

    def _rand_name(self, node: ast.Call, imports) -> Optional[str]:
        d = _dotted(node.func, imports)
        if d and d.startswith("jax.random."):
            return d.split(".")[-1]
        return None

    def check(self, mod, ctx):
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                parent = mod.parents.get(node)
                if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                                       ast.Lambda)):
                    continue  # handled by its top-level enclosing function
                self._check_fn(mod, node, out)
        yield from out

    # -- per-function abstract interpretation ------------------------------
    def _check_fn(self, mod, fn, out):
        env: Dict[str, int] = {}  # key var -> draws consumed since binding
        sub: Dict[Tuple[str, object], int] = {}  # (key array var, index) -> draws
        args = fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            env[a.arg] = 0
        body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
        self._stmts(mod, body, env, sub, out)

    def _stmts(self, mod, stmts, env, sub, out):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_fn(mod, st, out)
            elif isinstance(st, ast.Assign):
                self._expr(mod, st.value, env, sub, out)
                for tgt in st.targets:
                    self._bind(mod, tgt, st.value, env, sub)
            elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
                if st.value is not None:
                    self._expr(mod, st.value, env, sub, out)
                    self._bind(mod, st.target, st.value, env, sub)
            elif isinstance(st, ast.If):
                self._expr(mod, st.test, env, sub, out)
                e1, s1 = dict(env), dict(sub)
                self._stmts(mod, st.body, env, sub, out)
                self._stmts(mod, st.orelse, e1, s1, out)
                for k, v in e1.items():  # merge: worst (max) consumption
                    env[k] = max(env.get(k, v), v)
                for k, v in s1.items():
                    sub[k] = max(sub.get(k, v), v)
            elif isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
                if isinstance(st, (ast.For, ast.AsyncFor)):
                    self._expr(mod, st.iter, env, sub, out)
                    self._untrack(st.target, env)
                else:
                    self._expr(mod, st.test, env, sub, out)
                # two passes over the body: a key bound outside and drawn
                # from inside (without rebinding) is reuse across iterations
                self._stmts(mod, st.body, env, sub, out)
                tmp: List[Finding] = []
                self._stmts(mod, st.body, env, sub, tmp)
                known = {(f.line, f.col, f.rule) for f in out}
                out.extend(f for f in tmp
                           if (f.line, f.col, f.rule) not in known)
                self._stmts(mod, st.orelse, env, sub, out)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    self._expr(mod, item.context_expr, env, sub, out)
                self._stmts(mod, st.body, env, sub, out)
            elif isinstance(st, ast.Try):
                self._stmts(mod, st.body, env, sub, out)
                for h in st.handlers:
                    self._stmts(mod, h.body, env, sub, out)
                self._stmts(mod, st.orelse, env, sub, out)
                self._stmts(mod, st.finalbody, env, sub, out)
            elif isinstance(st, ast.Return) and st.value is not None:
                self._expr(mod, st.value, env, sub, out)
            elif isinstance(st, ast.Expr):
                self._expr(mod, st.value, env, sub, out)

    def _bind(self, mod, target, value, env, sub):
        names = []
        if isinstance(target, ast.Name):
            names = [target.id]
        elif isinstance(target, (ast.Tuple, ast.List)):
            names = [e.id for e in target.elts if isinstance(e, ast.Name)]
        fresh = False
        if isinstance(value, ast.Call):
            rn = self._rand_name(value, mod.imports)
            fresh = rn in self.PRODUCERS
        elif isinstance(value, ast.Subscript) and isinstance(value.value, ast.Name):
            fresh = value.value.id in env  # slice of a tracked key array
        elif isinstance(value, ast.Name):
            fresh = value.id in env
        for n in names:
            if fresh:
                env[n] = 0
                for k in [k for k in sub if k[0] == n]:
                    del sub[k]
            else:
                env.pop(n, None)

    def _untrack(self, target, env):
        if isinstance(target, ast.Name):
            env.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._untrack(e, env)

    def _expr(self, mod, expr, env, sub, out):
        for node in ast.walk(expr):
            if isinstance(node, ast.Lambda):
                self._check_fn(mod, node, out)
            if not isinstance(node, ast.Call):
                continue
            rn = self._rand_name(node, mod.imports)
            if rn is None or rn in self.NON_DRAWS or not node.args:
                continue
            key = node.args[0]
            if isinstance(key, ast.Name):
                if key.id in env:
                    env[key.id] += 1
                    if env[key.id] > 1:
                        out.append(self.finding(
                            mod, node,
                            f"key `{key.id}` feeds more than one "
                            f"jax.random draw — split/fold_in between draws",
                        ))
            elif (isinstance(key, ast.Subscript)
                  and isinstance(key.value, ast.Name)
                  and key.value.id in env):
                idx = key.slice
                tag = (key.value.id,
                       idx.value if isinstance(idx, ast.Constant) else id(idx))
                sub[tag] = sub.get(tag, 0) + 1
                if sub[tag] > 1:
                    out.append(self.finding(
                        mod, node,
                        f"key slot `{key.value.id}[{tag[1]}]` feeds more "
                        "than one jax.random draw",
                    ))
            elif isinstance(key, ast.Attribute):
                out.append(self.finding(
                    mod, node,
                    f"draw consumes stored raw key "
                    f"`{_dotted(key, mod.imports) or '…'}` — split/fold_in "
                    "first so the stream advances",
                ))


class REP005(Rule):
    """Reductions over sub-f32 operands must accumulate/cast in f32.

    The PR-3 bf16-master bug class: summing a bf16 plane re-associates
    in bf16 and the sequential-round drift is unbounded.  Any
    ``jnp`` reduction whose operand is freshly ``.astype``-downcast (or
    cast to a variable dtype that may be sub-f32) must either pass
    ``dtype=jnp.float32`` / ``preferred_element_type=jnp.float32`` or
    immediately ``.astype(jnp.float32)`` the result.
    """

    id = "REP005"
    scope_doc = "src/repro"

    REDUCTIONS = {"sum", "mean", "prod", "dot", "vdot", "tensordot",
                  "matmul", "einsum", "inner", "norm"}
    SUB_F32 = {"bfloat16", "float16", "bf16", "fp16", "float8_e4m3fn",
               "float8_e5m2"}
    F32 = {"float32", "float64", "f32"}

    def _dtype_class(self, node, imports) -> str:
        """'safe' | 'suspect' for a dtype expression."""
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return "suspect" if node.value in self.SUB_F32 else (
                "safe" if node.value in self.F32 else "suspect")
        d = _dotted(node, imports)
        if d:
            leaf = d.split(".")[-1]
            if leaf in self.F32:
                return "safe"
            if leaf == "dtype":
                # `w.astype(x.dtype)` aligns one operand to another — the
                # reduction dtype is decided by x, not introduced here
                return "safe"
            return "suspect"  # bf16 literal or a variable dtype
        return "suspect"

    def _astype_suspect(self, call: ast.Call, imports) -> bool:
        f = call.func
        if not (isinstance(f, ast.Attribute) and f.attr == "astype"):
            return False
        dt = call.args[0] if call.args else next(
            (k.value for k in call.keywords if k.arg == "dtype"), None)
        return dt is not None and self._dtype_class(dt, imports) == "suspect"

    def _is_reduction(self, call: ast.Call, imports) -> bool:
        f = call.func
        d = _dotted(f, imports)
        if d and d.split(".")[-1] in self.REDUCTIONS and (
            "numpy" in d or "linalg" in d or d.startswith("jax.")
        ):
            return True
        return isinstance(f, ast.Attribute) and f.attr in {"sum", "mean"}

    def _mitigated(self, call: ast.Call, mod: ParsedModule) -> bool:
        for kw in call.keywords:
            if kw.arg in ("dtype", "preferred_element_type", "acc_dtype"):
                if self._dtype_class(kw.value, mod.imports) == "safe":
                    return True
        parent = mod.parents.get(call)
        if (isinstance(parent, ast.Attribute) and parent.attr == "astype"
                and parent.value is call):
            gp = mod.parents.get(parent)
            if isinstance(gp, ast.Call) and gp.func is parent:
                dt = gp.args[0] if gp.args else next(
                    (k.value for k in gp.keywords if k.arg == "dtype"), None)
                if dt is not None and self._dtype_class(dt, mod.imports) == "safe":
                    return True
        return False

    def check(self, mod, ctx):
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and self._is_reduction(node, mod.imports)):
                continue
            operands = list(node.args) + [k.value for k in node.keywords
                                          if k.arg not in ("dtype", "axis")]
            suspect = any(
                isinstance(sb, ast.Call) and self._astype_suspect(sb, mod.imports)
                for op in operands for sb in ast.walk(op)
            )
            if suspect and not self._mitigated(node, mod):
                yield self.finding(
                    mod, node,
                    "reduction over a sub-f32 (or unknown-dtype) cast — "
                    "accumulate in f32 (dtype=/preferred_element_type="
                    "jnp.float32) or .astype(jnp.float32) the result",
                )


RULES: Sequence[Rule] = (REP001(), REP002(), REP003(), REP004(), REP005())


# ---------------------------------------------------------- REP003 graph
@dataclass
class _FuncInfo:
    key: Tuple[str, str]  # (path, qualname)
    node: ast.AST
    module: ParsedModule
    parent: Optional[Tuple[str, str]]
    cls: Optional[str]


class RepoContext:
    """Cross-module call graph: jit/scan roots → reachable functions."""

    TRACERS = {
        "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
        "jax.checkpoint", "jax.remat", "jax.custom_jvp", "jax.custom_vjp",
        "jax.lax.scan", "jax.lax.map", "jax.lax.cond", "jax.lax.switch",
        "jax.lax.while_loop", "jax.lax.fori_loop", "jax.lax.associative_scan",
        "jax.experimental.shard_map.shard_map", "repro.utils.compat.shard_map",
    }

    def __init__(self, modules: Sequence[ParsedModule]):
        self.modules = list(modules)
        self.by_dotted = {m.dotted: m for m in self.modules}
        self.functions: Dict[Tuple[str, str], _FuncInfo] = {}
        self.children: Dict[Tuple[str, str], Dict[str, Tuple[str, str]]] = {}
        self.modlevel: Dict[str, Dict[str, Tuple[str, str]]] = {}
        self.methods: Dict[Tuple[str, str, str], Tuple[str, str]] = {}
        for m in self.modules:
            self._index(m)
        self.reachable = self._reach(self._roots())

    # -- indexing ----------------------------------------------------------
    def _index(self, mod: ParsedModule):
        self.modlevel.setdefault(mod.path, {})

        def visit(node, qual, parent_key, cls):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{qual}.{child.name}" if qual else child.name
                    key = (mod.path, q)
                    self.functions[key] = _FuncInfo(key, child, mod, parent_key, cls)
                    if parent_key is not None:
                        self.children.setdefault(parent_key, {})[child.name] = key
                    elif cls is None:
                        self.modlevel[mod.path][child.name] = key
                    if cls is not None and parent_key is None:
                        self.methods[(mod.path, cls, child.name)] = key
                    visit(child, q, key, cls)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{qual}.{child.name}" if qual else child.name,
                          None, child.name)
                else:
                    visit(child, qual, parent_key, cls)

        visit(mod.tree, "", None, None)

    # -- resolution --------------------------------------------------------
    def resolve(self, expr: ast.AST, mod: ParsedModule,
                fkey: Optional[Tuple[str, str]]) -> Optional[Tuple[str, str]]:
        if isinstance(expr, ast.Name):
            k = fkey
            while k is not None:
                hit = self.children.get(k, {}).get(expr.id)
                if hit:
                    return hit
                k = self.functions[k].parent
            hit = self.modlevel.get(mod.path, {}).get(expr.id)
            if hit:
                return hit
            return self._cross(mod.imports.get(expr.id))
        if isinstance(expr, ast.Attribute):
            if (isinstance(expr.value, ast.Name) and expr.value.id == "self"
                    and fkey is not None):
                cls = self.functions[fkey].cls
                if cls:
                    return self.methods.get((mod.path, cls, expr.attr))
            return self._cross(_dotted(expr, mod.imports))
        return None

    def _cross(self, dotted: Optional[str]) -> Optional[Tuple[str, str]]:
        if not dotted or not dotted.startswith("repro."):
            return None
        mod_path, _, fname = dotted.rpartition(".")
        m = self.by_dotted.get(mod_path)
        if m:
            return self.modlevel.get(m.path, {}).get(fname)
        return None

    # -- roots + reachability ---------------------------------------------
    def _enclosing(self, mod: ParsedModule,
                   node: ast.AST) -> Optional[Tuple[str, str]]:
        n = node
        chain = []
        while n is not None:
            n = mod.parents.get(n)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                chain.append(n)
        for info in self.functions.values():
            if info.module is mod and chain and info.node is chain[0]:
                return info.key
        return None

    def _roots(self):
        roots = set()
        for mod in self.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        d = _dotted(dec, mod.imports)
                        if d is None and isinstance(dec, ast.Call):
                            d = _dotted(dec.func, mod.imports)
                            if d == "functools.partial" and dec.args:
                                d = _dotted(dec.args[0], mod.imports)
                        if d in self.TRACERS:
                            for info in self.functions.values():
                                if info.node is node:
                                    roots.add(info.key)
                elif isinstance(node, ast.Call):
                    d = _dotted(node.func, mod.imports)
                    if d not in self.TRACERS:
                        continue
                    fkey = self._enclosing(mod, node)
                    cands = list(node.args) + [k.value for k in node.keywords]
                    for arg in cands:
                        hit = self.resolve(arg, mod, fkey)
                        if hit:
                            roots.add(hit)
        return roots

    def _reach(self, roots):
        seen = set()
        work = list(roots)
        while work:
            key = work.pop()
            if key in seen or key not in self.functions:
                continue
            seen.add(key)
            info = self.functions[key]
            # nested defs trace with their parent
            work.extend(self.children.get(key, {}).values())
            for node in _region(info.node):
                if isinstance(node, (ast.Name, ast.Attribute)):
                    hit = self.resolve(node, info.module, key)
                    if hit and hit not in seen:
                        work.append(hit)
        return seen


# ------------------------------------------------------------------ driver
def iter_repo_files(src_root: Path = REPO_SRC) -> Iterator[Path]:
    yield from sorted((src_root / "repro").rglob("*.py"))


def lint_modules(modules: Sequence[ParsedModule],
                 rules: Optional[Sequence[Rule]] = None,
                 include_suppressed: bool = False) -> List[Finding]:
    ctx = RepoContext(modules)
    findings: List[Finding] = []
    for rule in (rules or RULES):
        for mod in modules:
            if not rule.applies(mod.path):
                continue
            for f in rule.check(mod, ctx):
                if include_suppressed or not mod.suppressed(f.rule, f.line):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_sources(named_sources: Dict[str, str],
                 rules: Optional[Sequence[Rule]] = None,
                 include_suppressed: bool = False) -> List[Finding]:
    """Lint in-memory sources (fixture tests): {virtual path: source}."""
    mods = [ParsedModule(p, s) for p, s in sorted(named_sources.items())]
    return lint_modules(mods, rules, include_suppressed)


def lint_repo(src_root: Path = REPO_SRC,
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    mods = []
    for p in iter_repo_files(src_root):
        rel = p.relative_to(src_root.parent).as_posix()
        mods.append(ParsedModule(rel, p.read_text()))
    return lint_modules(mods, rules)


# ------------------------------------------------------------------ baseline
def load_baseline(path: Path = BASELINE_PATH) -> set:
    if not path.exists():
        return set()
    out = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def write_baseline(findings: Iterable[Finding], path: Path = BASELINE_PATH):
    lines = [
        "# repro.analysis.lint baseline — grandfathered findings.",
        "# One `path::RULE::stripped source line` per entry; regenerate with",
        "#   python -m repro.analysis.lint --baseline",
        "# Keep this empty (or justified line-by-line): new findings fail CI.",
    ]
    lines += sorted({f.baseline_key for f in findings})
    path.write_text("\n".join(lines) + "\n")


# ------------------------------------------------------------------ README
README_BEGIN = "<!-- analysis-rules:begin (generated by repro.analysis.lint) -->"
README_END = "<!-- analysis-rules:end -->"


def rule_table_md() -> str:
    """Rule table rendered from the rule docstrings (first line =
    invariant, first body paragraph = rationale)."""
    rows = ["| ID | Invariant | Scope | Rationale |",
            "| --- | --- | --- | --- |"]
    for rule in RULES:
        doc = (type(rule).__doc__ or "").strip().splitlines()
        invariant = doc[0].strip().rstrip(".") if doc else ""
        body = [ln.strip() for ln in doc[1:]]
        para: List[str] = []
        for ln in body:
            if not ln and para:
                break
            if ln:
                para.append(ln)
        first = " ".join(para)
        rationale = first.split(". ")[0].rstrip(".") + "." if first else ""
        rows.append(f"| {rule.id} | {invariant}. | `{rule.scope_doc}` "
                    f"| {rationale} |")
    return "\n".join(rows)


def sync_readme(write: bool = False, path: Path = README_PATH) -> bool:
    """True iff the README's generated block byte-matches the rule table."""
    text = path.read_text() if path.exists() else ""
    block = f"{README_BEGIN}\n{rule_table_md()}\n{README_END}"
    if README_BEGIN in text and README_END in text:
        head, _, rest = text.partition(README_BEGIN)
        _, _, tail = rest.partition(README_END)
        new = head + block + tail
    else:
        new = text.rstrip() + "\n\n" + block + "\n"
    if write and new != text:
        path.write_text(new)
        return True
    return new == text


# ------------------------------------------------------------------ CLI
def _main(argv=None) -> int:
    # `python -m` runs this file as __main__ — delegate to the canonical
    # import so paths/rule identities come from one module instance
    from repro.analysis import lint as canonical

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Repo-invariant AST lint (REP001–REP005).")
    ap.add_argument("--baseline", action="store_true",
                    help="rewrite the grandfathered-findings baseline from "
                         "the current findings")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the README rule table in place")
    ap.add_argument("--json", type=Path, default=None,
                    help="also dump findings as JSON (CI artifact)")
    args = ap.parse_args(argv)

    if args.write:
        changed = canonical.sync_readme(write=True)
        print(f"README rule table {'updated' if changed else 'already current'}")
        return 0

    findings = canonical.lint_repo()
    baseline = canonical.load_baseline()
    if args.baseline:
        canonical.write_baseline(findings)
        print(f"baseline written: {len(findings)} grandfathered finding(s)")
        return 0

    new = [f for f in findings if f.baseline_key not in baseline]
    grandfathered = len(findings) - len(new)
    for f in new:
        print(f)
    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            [vars(f) | {"baselined": f.baseline_key in baseline}
             for f in findings], indent=2, default=str) + "\n")
    print(f"repro.analysis.lint: {len(new)} new finding(s), "
          f"{grandfathered} baselined, {len(canonical.RULES)} rules")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(_main())
