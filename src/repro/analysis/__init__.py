"""Static guarantees for the engine's conventions.

Two layers, one contract: the invariants that keep the federated engine
correct — registry-only algorithm dispatch, compat-routed jax APIs, no
host syncs inside the fused scan, disciplined RNG keying, f32
accumulation — are enforced as *code*, not reviewer folklore.

* :mod:`repro.analysis.lint` — Layer 1, an AST linter over ``src/repro``
  (rules REP001–REP005, inline ``# repro: noqa`` suppression, checked-in
  baseline).  ``python -m repro.analysis.lint``.
* :mod:`repro.analysis.trace` — Layer 2, a traced-program contract
  checker that lowers the real round programs and asserts donation
  aliasing, transfer-guard cleanliness, the retrace budget, scan-carry
  dtypes, and the ordered scattered fold.
  ``python -m repro.analysis.trace --quick``.

Both are CI-blocking (the ``static-analysis`` job in
``.github/workflows/ci.yml``).
"""
