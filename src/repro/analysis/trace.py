"""Layer 2 — traced-program contract checker for the round engine.

Where :mod:`repro.analysis.lint` reads source, this module lowers the
*actual* round programs (``run_rounds`` sync/async × jnp/kernel ×
resident/store × cohort-sharded) on tiny synthetic problems and asserts
the contracts that only exist after tracing:

(a) **donation aliased** — every leaf of the donated ``FedState`` carry
    must appear as a ``tf.aliasing_output`` input attribute in the
    lowered module.  Counting attributes in the lowering (not runtime
    buffers) makes the check platform-independent: an unusable donation
    (shape-mismatched carry, accidental de-donation) drops the attribute
    at lowering time on every backend.
(b) **zero host transfers** — the compiled program executes under
    ``jax.transfer_guard("disallow")``.  On the CPU test backend this
    proves no host→device transfer happens per call (e.g. numpy batches
    re-fed every round); device→host syncs are additionally covered
    statically by lint rule REP003 (on CPU, d2h is zero-copy and the
    guard cannot observe it).
(c) **retrace budget** — each (shapes, statics) path traces exactly
    ``TRACE_BUDGET`` times, measured by the engine's own trace counters
    (resident paths) or the per-piece jit cache sizes (store paths).
    ``tests/test_run_rounds.py`` pins its trace assertions through
    :func:`assert_trace_budget`, so the budget lives here, in ONE place.
(d) **scan-carry dtype audit** — with bf16 params and default (f32)
    momentum, no sub-f32 float aval may appear in any ``lax.scan`` carry:
    the f32 master planes, not the bf16 leaf views, must be what the
    round loop advances (the PR-3 bf16-master bug class).
(e) **ordered scattered fold** — the cohort-sharded program must contain
    ``all_to_all`` (the transpose-to-columns fold) and must NOT contain
    ``psum_scatter``, which would pre-reduce per device and re-associate
    the f32 sum (breaking the bitwise oracle).

The store (host-loop) entries run the same jitted round math as the
resident entries; their host↔device boundary (store gather/scatter,
host batch generation) transfers by design, so (a)/(b) are reported as
n/a there and (c) is checked through the jit caches.

CLI: ``python -m repro.analysis.trace [--quick] [--json PATH]`` — exits
non-zero if any contract fails.  ``--quick`` runs the two-entry subset
CI uses inside the tier-1 time budget.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

TRACE_BUDGET = 1  # traces per distinct (shapes, statics) path — THE pin

_ALIAS_RE = re.compile(r"tf\.aliasing_output")
SUB_F32 = ("bf16", "bfloat16", "f16", "float16")


# ------------------------------------------------------------------ report
@dataclass
class ContractReport:
    path: str
    donation: str = "n/a"
    donation_ok: Optional[bool] = None
    transfer_guard_ok: Optional[bool] = None
    trace_count: int = -1
    trace_ok: Optional[bool] = None
    carry_dtypes_ok: Optional[bool] = None
    collectives_ok: Optional[bool] = None
    notes: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        checks = (self.donation_ok, self.transfer_guard_ok, self.trace_ok,
                  self.carry_dtypes_ok, self.collectives_ok)
        return all(c is not False for c in checks)

    def summary(self) -> str:
        def mark(v):
            return "—" if v is None else ("ok" if v else "FAIL")

        return (f"{self.path:<24} donation={mark(self.donation_ok)}"
                f"({self.donation}) guard={mark(self.transfer_guard_ok)} "
                f"traces={self.trace_count}/{TRACE_BUDGET}"
                f"[{mark(self.trace_ok)}] carry={mark(self.carry_dtypes_ok)} "
                f"collectives={mark(self.collectives_ok)}"
                + (f"  # {'; '.join(self.notes)}" if self.notes else ""))


# ------------------------------------------------------------------ helpers
def tiny_problem(algo: str = "fedcm", *, bf16: bool = False, **cfg_kw):
    """A minimal engine + data + init-state factory (mirrors the
    tests/test_run_rounds.py setup, shrunk for lowering speed)."""
    import jax

    from repro.configs.base import FedConfig
    from repro.core import FederatedEngine
    from repro.data import FederatedData, make_synthetic_classification
    from repro.models.small import classification_loss, mlp_classifier

    x, y, *_ = make_synthetic_classification(
        n_classes=4, dim=8, n_train=400, n_test=8)
    base = dict(algo=algo, num_clients=8, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(cfg_kw)
    cfg = FedConfig(**base)
    model = mlp_classifier((8, 16, 4))
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)

    def fresh():
        params = model.init(jax.random.PRNGKey(0))
        if bf16:
            import jax.numpy as jnp

            from repro.utils.trees import tree_cast
            params = tree_cast(params, jnp.bfloat16)
        return eng.init(params, jax.random.PRNGKey(1))

    return eng, data, fresh


_MAIN_SIG_RE = re.compile(r"func\.func public @main\((.*?)\)\s*->", re.S)


def donation_alias_report(lowered_text: str,
                          n_nondonated: int) -> Tuple[bool, str]:
    """(ok, summary) from a lowered module's text.

    Every argument in the lowered entry signature beyond the
    ``n_nondonated`` undonated ones must carry a ``tf.aliasing_output``
    attribute.  Donated leaves that are *dead* (e.g. the bf16 param
    views a carried f32 master plane supersedes) are pruned from the
    signature by jax before lowering — freed at donation, strictly
    better than aliased — so they are exempt by construction."""
    m = _MAIN_SIG_RE.search(lowered_text)
    if not m:
        n = len(_ALIAS_RE.findall(lowered_text))
        return n >= 1, f"aliased {n}/? (entry signature not found)"
    args = m.group(1).split("%arg")[1:]
    aliased = sum(1 for a in args if "tf.aliasing_output" in a)
    expected = len(args) - n_nondonated
    return (aliased >= expected and aliased >= 1,
            f"aliased {aliased}/{expected} "
            f"({len(args)} live args, {n_nondonated} undonated)")


def check_engine_donation(eng, state, data, n_rounds: int = 3,
                          *, mode: str = "sync") -> Tuple[bool, str]:
    """Lower the engine's donated multi-round entry point and assert every
    live leaf of the carried state is buffer-aliased to an output."""
    import jax

    if mode == "sync":
        low = eng._run_rounds.lower(
            state, data.client_x, data.client_y, n_rounds=n_rounds)
    else:
        low = eng._run_rounds_async.lower(
            state, data.client_x, data.client_y, None, None, None,
            n_rounds=n_rounds, pipeline_depth=2, staleness=0, eval_every=0,
            predict_fn=None, scan_unroll=1)
    n_nondonated = len(jax.tree_util.tree_leaves((data.client_x, data.client_y)))
    return donation_alias_report(low.as_text(), n_nondonated)


def check_transfer_guard(run: Callable[[], object]) -> Tuple[bool, str]:
    """Execute ``run`` (already compiled, device-committed inputs) under
    ``transfer_guard("disallow")``."""
    import jax

    try:
        with jax.transfer_guard("disallow"):
            jax.block_until_ready(run())
        return True, ""
    except Exception as e:  # XlaRuntimeError: Disallowed …: the finding
        return False, f"{type(e).__name__}: {e}"


def assert_trace_budget(eng, counter: str, calls: Sequence[Callable[[], object]],
                        expected_paths: Sequence[int]) -> None:
    """Run ``calls`` in order, asserting the engine's ``counter`` equals
    ``expected_paths[i] * TRACE_BUDGET`` after each — the single place the
    per-path retrace budget is pinned (tests/test_run_rounds.py and the
    contract matrix both consume it)."""
    assert getattr(eng, counter) == 0, (
        f"{counter} = {getattr(eng, counter)} before first call")
    for i, (call, paths) in enumerate(zip(calls, expected_paths)):
        call()
        got = getattr(eng, counter)
        want = paths * TRACE_BUDGET
        assert got == want, (
            f"retrace budget: {counter} = {got} after call {i}, "
            f"expected {want} ({paths} path(s) × budget {TRACE_BUDGET})")


# ------------------------------------------------------- jaxpr inspection
def iter_eqns(jaxpr):
    """All equations, recursing into sub-jaxprs (scan/cond/shard_map/…)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for u in (v if isinstance(v, (list, tuple)) else [v]):
                core = getattr(u, "jaxpr", None)
                if core is not None and hasattr(core, "eqns"):
                    yield from iter_eqns(core)
                elif hasattr(u, "eqns"):
                    yield from iter_eqns(u)


def scan_carry_violations(closed_jaxpr) -> List[str]:
    """Sub-f32 avals carried by any ``lax.scan`` in the program."""
    bad: List[str] = []
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name != "scan":
            continue
        nc, nk = eqn.params["num_consts"], eqn.params["num_carry"]
        for v in eqn.invars[nc:nc + nk]:
            s = str(v.aval)
            if any(t in s for t in SUB_F32):
                bad.append(s)
    return bad


def collective_primitives(closed_jaxpr) -> set:
    return {e.primitive.name for e in iter_eqns(closed_jaxpr.jaxpr)}


def _round_jaxpr(eng, state, data, n_rounds: int = 2):
    import jax

    return jax.make_jaxpr(
        lambda s, x, y: eng._run_rounds_impl(s, x, y, n_rounds=n_rounds)
    )(state, data.client_x, data.client_y)


# ------------------------------------------------------------------ matrix
@dataclass(frozen=True)
class MatrixEntry:
    name: str
    mode: str  # "sync" | "async"
    cfg: Dict[str, object]
    algo: str = "fedcm"
    store: bool = False
    sharded: bool = False
    bf16: bool = False


MATRIX: Sequence[MatrixEntry] = (
    MatrixEntry("sync/jnp/resident", "sync", {}),
    MatrixEntry("sync/kernel/resident", "sync", {"use_fused_kernel": True}),
    MatrixEntry("async/jnp/resident", "async", {}),
    MatrixEntry("async/kernel/resident", "async", {"use_fused_kernel": True}),
    MatrixEntry("sync/kernel/sharded", "sync",
                {"use_fused_kernel": True, "cohort_shard": 1}, sharded=True),
    MatrixEntry("sync/kernel/bf16", "sync", {"use_fused_kernel": True},
                bf16=True),
    MatrixEntry("sync/kernel/store", "sync",
                {"use_fused_kernel": True, "population_store": "host"},
                algo="scaffold", store=True),
    MatrixEntry("async/jnp/store", "async", {"population_store": "host"},
                algo="scaffold", store=True),
)

# the fast subset CI's static-analysis job runs inside the tier-1 budget
QUICK = ("sync/kernel/resident", "async/kernel/resident")


def _check_resident(entry: MatrixEntry) -> ContractReport:
    import jax

    rep = ContractReport(entry.name)
    eng, data, fresh = tiny_problem(entry.algo, bf16=entry.bf16, **entry.cfg)
    n = 3
    counter = ("run_rounds_traces" if entry.mode == "sync"
               else "run_rounds_async_traces")
    if entry.mode == "sync":
        def call():
            return eng.run_rounds(fresh(), data, n)
    else:
        def call():
            return eng.run_rounds_async(fresh(), data, n + 1,
                                        pipeline_depth=2, drain=False)

    # (c) retrace budget: two identical calls, one trace
    try:
        assert_trace_budget(eng, counter, [call, call], [1, 1])
        rep.trace_ok = True
    except AssertionError as e:
        rep.trace_ok = False
        rep.notes.append(str(e))
    rep.trace_count = getattr(eng, counter)

    # (b) compiled execution under transfer_guard (fresh state: the prior
    # calls donated theirs)
    st = fresh()
    if entry.mode == "sync":
        def guarded():
            return eng._run_rounds(st, data.client_x, data.client_y, n_rounds=n)
    else:
        def guarded():
            return eng._run_rounds_async(
                st, data.client_x, data.client_y, None, None, None,
                n_rounds=n + 1, pipeline_depth=2, staleness=0, eval_every=0,
                predict_fn=None, scan_unroll=1)
    rep.transfer_guard_ok, why = check_transfer_guard(guarded)
    if why:
        rep.notes.append(why)

    # (a) donation aliasing from the lowered module
    rep.donation_ok, rep.donation = check_engine_donation(
        eng, fresh(), data, n, mode=entry.mode)

    # (d)/(e) jaxpr audits on the sync path (the async program shares the
    # round body; the bf16 entry exists exactly for (d))
    if entry.mode == "sync":
        jx = _round_jaxpr(eng, fresh(), data)
        bad = scan_carry_violations(jx)
        rep.carry_dtypes_ok = not bad
        if bad:
            rep.notes.append(f"sub-f32 scan carries: {bad[:4]}")
        if entry.sharded:
            prims = collective_primitives(jx)
            rep.collectives_ok = ("all_to_all" in prims
                                  and "psum_scatter" not in prims)
            if not rep.collectives_ok:
                rep.notes.append(f"collectives seen: "
                                 f"{sorted(p for p in prims if 'all' in p or 'psum' in p)}")
    return rep


def _check_store(entry: MatrixEntry) -> ContractReport:
    import jax

    rep = ContractReport(entry.name)
    rep.donation = "n/a (host-loop store path)"
    rep.notes.append("store boundary transfers by design; device round "
                     "math is the resident entries' (shared jits)")
    eng, data, fresh = tiny_problem(entry.algo, **entry.cfg)
    n = 2
    if entry.mode == "sync":
        def call(st):
            return eng.run_rounds(st, data, n)
    else:
        def call(st):
            return eng.run_rounds_async(st, data, n, pipeline_depth=2)

    st, _ = call(fresh())
    st, _ = call(st)
    # (c) via the per-FlatSpec jit cache: every piece the loop used traced
    # at most TRACE_BUDGET times across both calls
    caches = {
        name: jit._cache_size()
        for jits in getattr(eng, "_store_jit_cache", {}).values()
        for name, jit in jits.items()
    }
    used = {k: v for k, v in caches.items() if v > 0}
    rep.trace_count = max(caches.values(), default=-1)
    rep.trace_ok = bool(used) and all(v <= TRACE_BUDGET for v in caches.values())
    if not rep.trace_ok:
        rep.notes.append(f"store jit cache sizes: {caches}")
    return rep


def run_matrix(quick: bool = False,
               entries: Optional[Sequence[MatrixEntry]] = None) -> List[ContractReport]:
    todo = entries if entries is not None else [
        e for e in MATRIX if not quick or e.name in QUICK]
    return [(_check_store if e.store else _check_resident)(e) for e in todo]


def quick_contracts(*, use_async: bool = False,
                    use_fused_kernel: bool = True) -> Dict[str, object]:
    """One-path contract summary for the ``fed_train --dryrun`` artifact.

    Memoized per path: dry-runs in one process (the CLI test suite) pay
    the tiny compile once."""
    mode = "async" if use_async else "sync"
    kern = "kernel" if use_fused_kernel else "jnp"
    name = f"{mode}/{kern}/resident"
    if name not in _QUICK_CACHE:
        entry = next(e for e in MATRIX if e.name == name)
        rep = _check_resident(entry)
        _QUICK_CACHE[name] = {
            "path": name,
            "donation_ok": bool(rep.donation_ok),
            "donation": rep.donation,
            "transfer_guard_ok": bool(rep.transfer_guard_ok),
            "trace_count": rep.trace_count,
            "trace_budget": TRACE_BUDGET,
        }
    return dict(_QUICK_CACHE[name])


_QUICK_CACHE: Dict[str, Dict[str, object]] = {}


# ------------------------------------------------------------------ CLI
def _main(argv=None) -> int:
    from repro.analysis import trace as canonical

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.trace",
        description="Traced-program contract checker (Layer 2).")
    ap.add_argument("--quick", action="store_true",
                    help="fast two-entry subset (CI tier-1 budget)")
    ap.add_argument("--json", type=__import__("pathlib").Path, default=None,
                    help="dump the reports as JSON (CI artifact)")
    args = ap.parse_args(argv)

    reports = canonical.run_matrix(quick=args.quick)
    for r in reports:
        print(r.summary())
    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            [vars(r) for r in reports], indent=2, default=str) + "\n")
    bad = [r for r in reports if not r.ok]
    print(f"repro.analysis.trace: {len(reports) - len(bad)}/{len(reports)} "
          f"contract entries clean")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(_main())
