"""Generalized fused local-step kernel: x ← x − η_l·v(algo) on the flat plane."""
from repro.kernels.fed_direction.ops import INTERPRET, flat_direction_step
from repro.kernels.fed_direction.ref import fed_direction_ref

__all__ = ["INTERPRET", "flat_direction_step", "fed_direction_ref"]
