"""Pallas TPU kernel: generalized fused federated local step.

Every algorithm's per-local-step direction is an affine combination of the
same streams — the minibatch gradient g, the current iterate x, and up to
two broadcast/per-client buffers (momentum Δ_t, control variates c_i/c, the
round anchor x_t):

    v = c_g·g + c_x·x + Σ_j c_j·aux_j          x ← x − η_l·v

* fedcm / mimelite : aux = (Δ_t,)      v = α·g + (1−α)·Δ_t
* scaffold         : aux = (c_i, c)    v = g − c_i + c
* feddyn           : aux = (λ_i, x_t)  v = g + a·x − λ_i − a·x_t
* fedavg / fedadam : aux = ()          v = g

One kernel body per aux arity streams each operand through VMEM exactly
once and writes x once — 3 + n_aux HBM transfers/element total, the
roofline floor for the op (AI ≈ 0.5 flop/byte; it is purely memory-bound).

Tiling mirrors kernels/fedcm_update: the flat plane is padded to a multiple
of ``block_elems`` and viewed as (padded//LANE, LANE) so every BlockSpec
tile is a VMEM-resident (rows, 128) slab.  The coefficient vector
(η_l, c_g, c_x, c_aux...) rides in SMEM as a (1, 3+n_aux) row — η_l decays
per round and several coefficients are traced, so baking them as python
constants would force a recompile per round.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK = 64 * 1024  # elements per grid step: 64k f32 = 256 KiB/input


def _make_kernel(n_aux: int):
    def kernel(coef_ref, x_ref, g_ref, *refs):
        aux_refs, out_ref = refs[:n_aux], refs[n_aux]
        eta = coef_ref[0, 0]
        x = x_ref[...].astype(jnp.float32)
        g = g_ref[...].astype(jnp.float32)
        v = coef_ref[0, 1] * g + coef_ref[0, 2] * x
        for j in range(n_aux):
            v = v + coef_ref[0, 3 + j] * aux_refs[j][...].astype(jnp.float32)
        out_ref[...] = (x - eta * v).astype(out_ref.dtype)

    return kernel


@partial(jax.jit, static_argnames=("block_elems", "interpret"))
def fed_direction_flat(x, g, auxes, coefs, *, block_elems: int = DEFAULT_BLOCK,
                       interpret: bool = True):
    """x, g, auxes[j]: 1-D arrays of equal length; coefs: (3 + len(auxes),)
    f32 vector (η_l, c_g, c_x, c_aux...).  Returns updated x (x.dtype)."""
    n = x.shape[0]
    rows = block_elems // LANE
    padded = pl.cdiv(n, block_elems) * block_elems
    pad = padded - n

    def prep(a):
        a = jnp.pad(a, (0, pad))
        return a.reshape(padded // LANE, LANE)

    xr, gr = prep(x), prep(g)
    aux_r = [prep(a) for a in auxes]
    nblocks = padded // block_elems

    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    smem = pl.BlockSpec((1, 3 + len(auxes)), lambda i: (0, 0))
    out = pl.pallas_call(
        _make_kernel(len(auxes)),
        grid=(nblocks,),
        in_specs=[smem, spec, spec] + [spec] * len(auxes),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(coefs.astype(jnp.float32).reshape(1, -1), xr, gr, *aux_r)
    return out.reshape(padded)[:n]
