"""Pure-jnp oracle for the generalized fused local step.

    v = c_g·g + c_x·x + Σ_j c_j·aux_j
    x_new = x − η_l·v

with coefs = (η_l, c_g, c_x, c_aux...) exactly as the kernel consumes them.
"""
from __future__ import annotations

import jax.numpy as jnp


def fed_direction_ref(x, g, auxes, coefs):
    coefs = coefs.astype(jnp.float32)
    v = coefs[1] * g.astype(jnp.float32) + coefs[2] * x.astype(jnp.float32)
    for j, a in enumerate(auxes):
        v = v + coefs[3 + j] * a.astype(jnp.float32)
    return (x.astype(jnp.float32) - coefs[0] * v).astype(x.dtype)
