"""jit'd dispatch from algorithm name to the generalized direction kernel.

``flat_direction_step`` is the flat engine's fused local step: given the
flat plane buffers it builds the (η_l, c_g, c_x, c_aux...) coefficient
vector for the algorithm and launches ONE kernel pass — no per-step
concatenate/split, the buffers already ARE flat.

Coverage: fedcm, mimelite (blend), scaffold (control variates), feddyn
(proximal + dual), fedavg/fedadam (plain SGD step).  The affine forms are
documented in kernel.py; feddyn's is distributed (``a·x − a·x_t`` instead
of ``a·(x − x_t)``), a tolerance-level reassociation covered by its sweep
test.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fed_direction.kernel import fed_direction_flat

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def _coefs(eta_l, c_g, c_x, *c_aux):
    return jnp.stack(
        [jnp.asarray(c, jnp.float32) for c in (eta_l, c_g, c_x, *c_aux)]
    )


def flat_direction_step(algo_name, cfg, x, g, m, cst, x0, eta_l):
    """One fused local step x ← x − η_l·v on flat (P,) buffers.

    ``m`` is the broadcast buffer (Δ_t for fedcm/mimelite, c for scaffold
    rides inside ``cst``), ``cst`` the per-client state ((c_i, c) tuple for
    scaffold, λ_i for feddyn, None otherwise), ``x0`` the round anchor x_t.
    """
    if algo_name in ("fedcm", "mimelite"):
        auxes = (m,)
        coefs = _coefs(eta_l, cfg.alpha, 0.0, 1.0 - cfg.alpha)
    elif algo_name == "scaffold":
        c_i, c = cst
        auxes = (c_i, c)
        coefs = _coefs(eta_l, 1.0, 0.0, -1.0, 1.0)
    elif algo_name == "feddyn":
        auxes = (cst, x0)
        a = cfg.feddyn_alpha
        coefs = _coefs(eta_l, 1.0, a, -1.0, -a)
    elif algo_name in ("fedavg", "fedadam"):
        auxes = ()
        coefs = _coefs(eta_l, 1.0, 0.0)
    else:
        raise KeyError(f"no fused direction form for algorithm {algo_name!r}")
    return fed_direction_flat(x, g, auxes, coefs, interpret=INTERPRET)
