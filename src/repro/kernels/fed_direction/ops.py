"""jit'd dispatch from an ``AlgorithmSpec`` to the generalized direction kernel.

``flat_direction_step`` is the flat engine's fused local step: given the
flat plane buffers it resolves the spec's declarative ``DirectionRow``
(``repro.core.registry``) into the (η_l, c_g, c_x, c_aux...) SMEM
coefficient vector and launches ONE kernel pass — no per-step
concatenate/split, the buffers already ARE flat, and no per-algorithm
branching: the row's named streams (``"momentum"``, ``"client_state"``)
map onto the kernel's auxiliary operands, and a nonzero proximal
coefficient ``c_x`` on ``(x − x_t)`` is distributed onto the kernel's
``c_x·x`` slot plus an ``−c_x·x_t`` auxiliary (a tolerance-level
reassociation covered by the feddyn sweep test).

Statically-zero coefficients drop their stream entirely — FedCM at α = 1
launches the same zero-aux kernel as FedAvg.  Specs with an escape-hatch
``direction_fn`` (non-affine directions) bypass the kernel: the callable
is array-polymorphic and runs on the flat buffers directly.

shard_map compatibility (cohort-parallel engine): this launch runs
INSIDE ``shard_map`` over the ``"clients"`` mesh axis, vmapped over each
device's local clients.  Every operand is either per-client ``(P,)``
(x, g, the client-state row) or replicated ``(P,)`` broadcast state
(x_t, Δ_t) — the full plane, never a shard — so the launch shapes are
IDENTICAL at every shard width and the kernel needs no grid-stability
floor (unlike ``server_update``, which launches on plane-column chunks);
no collective ever enters the local-step loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fed_direction.kernel import fed_direction_flat

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def _coefs(eta_l, c_g, c_x, *c_aux):
    return jnp.stack(
        [jnp.asarray(c, jnp.float32) for c in (eta_l, c_g, c_x, *c_aux)]
    )


def flat_direction_step(algo, cfg, x, g, m, cst, x0, eta_l):
    """One fused local step x ← x − η_l·v on flat (P,) buffers.

    ``algo`` is an ``AlgorithmSpec`` or a registered name.  ``m`` is the
    broadcast buffer (Δ_t for fedcm/mimelite, c for scaffold), ``cst`` the
    per-client state plane (c_i / λ_i, or None), ``x0`` the round anchor
    x_t — the spec's row picks the streams it consumes by name.
    """
    # deferred import: repro.core.engine imports this module at package
    # init, so a module-level registry import would be circular
    from repro.core.registry import _dir_coef, get_algorithm

    spec = get_algorithm(algo) if isinstance(algo, str) else algo
    if spec.direction_row is None:
        # escape hatch: non-affine direction, pure jnp on the flat buffers
        v = spec.direction(cfg, m, cst, x, x0, g)
        return (x - eta_l * v).astype(x.dtype)
    row = spec.direction_row
    c_g = _dir_coef(row.c_g, cfg)
    c_x = _dir_coef(row.c_x, cfg)
    streams = {"momentum": m, "client_state": cst}
    auxes, aux_coefs = [], []
    for stream, c in row.aux:
        c = _dir_coef(c, cfg)
        if c != 0.0:  # static zero: the stream never reaches the kernel
            auxes.append(streams[stream])
            aux_coefs.append(c)
    if c_x != 0.0:
        # distribute c_x·(x − x_t) onto the kernel's c_x·x slot + a −c_x·x_t aux
        auxes.append(x0)
        aux_coefs.append(-c_x)
    coefs = _coefs(eta_l, c_g, c_x, *aux_coefs)
    return fed_direction_flat(x, g, tuple(auxes), coefs, interpret=INTERPRET)
