"""Pallas TPU kernels for the compute hot spots (DESIGN.md §3).

* ``fedcm_update``    — fused FedCM client step  v = α·g + (1−α)·Δ; x ← x − η·v
* ``flash_attention`` — blocked online-softmax attention (GQA, sliding window)
* ``ssd_scan``        — chunked Mamba2 SSD scan with VMEM-carried state

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True on CPU), ref.py (pure-jnp oracle used by tests).
"""
