"""Pallas TPU kernels for the compute hot spots (DESIGN.md §3).

* ``fed_direction``   — generalized fused local step (affine family covers
  fedcm/mimelite blend, scaffold, feddyn, plain SGD; coefficients in SMEM)
* ``server_update``   — fused round-close: masked (C,)·(C,P) cohort mean +
  staleness-discounted momentum EMA + param step in one pass
* ``flash_attention`` — blocked online-softmax attention (GQA, sliding window)
* ``ssd_scan``        — chunked Mamba2 SSD scan with VMEM-carried state
* ``fedcm_update``    — RETIRED to oracle-only: ref.py pins the FedCM blend

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True on CPU), ref.py (pure-jnp oracle used by tests).
"""
