"""Public flash attention wrapper: (B, S, H, hd) layout, GQA, causal/window."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd

INTERPRET = jax.default_backend() != "tpu"


def flash_attention(
    q,  # (B, Sq, H, hd)
    k,  # (B, Skv, Hkv, hd)
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    bq: int = 128,
    bkv: int = 128,
):
    qt = jnp.swapaxes(q, 1, 2)  # (B, H, Sq, hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, scale=scale,
        q_offset=q_offset, bq=bq, bkv=bkv, interpret=INTERPRET,
    )
    return jnp.swapaxes(out, 1, 2)
