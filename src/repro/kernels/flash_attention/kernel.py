"""Pallas TPU flash attention (blocked online softmax), GQA + sliding window.

TPU adaptation notes (DESIGN.md §3): this is not a port of the CUDA
FlashAttention tiling.  The grid is (B, H, n_q_blocks, n_kv_blocks) with the
KV axis innermost — on TPU the innermost grid dimension executes
*sequentially* on a core, so the running (m, l, acc) online-softmax state
lives in VMEM scratch and persists across KV steps (the Pallas-TPU analogue
of a CUDA persistent-CTA loop).  Block shapes default to (128, head_dim) —
MXU-aligned on the 128 lane dimension.

Causal / windowed blocks that are fully masked are skipped with
``pl.when`` (compute skipped; the DMA for that tile still lands — the
next-level optimization on real hardware is a data-dependent grid, noted in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # (1, 1, bq, hd)
    k_ref,  # (1, 1, bkv, hd)
    v_ref,  # (1, 1, bkv, hd)
    out_ref,  # (1, 1, bq, hd)
    m_scr,  # (bq, 1) f32
    l_scr,  # (bq, 1) f32
    acc_scr,  # (bq, hd) f32
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    bq: int,
    bkv: int,
    n_kv: int,
    q_offset: int,
    sq_valid: int,
    skv_valid: int,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + q_offset
    kpos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # block-level skip: is any (q, kv) pair in this tile unmasked?
    q_last = iq * bq + bq - 1 + q_offset
    q_first = iq * bq + q_offset
    kv_first = ikv * bkv
    kv_last = ikv * bkv + bkv - 1
    live = True
    if causal:
        live = q_last >= kv_first  # else the whole tile is above the diagonal
    if window is not None:
        live = jnp.logical_and(live, q_first - kv_last < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bkv)

        mask = (qpos - q_offset < sq_valid) & (kpos < skv_valid)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        out_ref[0, 0] = (acc_scr[...] / l).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "bq", "bkv", "interpret"),
)
def flash_attention_bhsd(
    q,  # (B, H, Sq, hd)   — head-major layout (ops.py transposes)
    k,  # (B, Hkv, Skv, hd)
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
):
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = float(scale if scale is not None else hd**-0.5)  # repro: noqa REP003 -- scale is a static Python float by kernel contract

    bq = min(bq, max(8, 1 << (Sq - 1).bit_length()))
    bkv = min(bkv, max(8, 1 << (Skv - 1).bit_length()))
    n_q = pl.cdiv(Sq, bq)
    n_kv = pl.cdiv(Skv, bkv)
    q_pad = n_q * bq - Sq
    kv_pad = n_kv * bkv - Skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, causal=causal, window=window, bq=bq, bkv=bkv,
        n_kv=n_kv, q_offset=q_offset, sq_valid=Sq, skv_valid=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq] if q_pad else out
