"""Pure-jnp oracle for the flash attention kernel.

Materializes the full (Sq, Skv) score matrix — O(S²) memory, only for
test-sized shapes.  Supports GQA grouping, causal masking and sliding
window, matching the kernel's contract exactly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q,  # (B, Sq, H, hd)
    k,  # (B, Skv, Hkv, hd)
    v,  # (B, Skv, Hkv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,  # absolute position of q[0] (decode/prefill continuation)
):
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else hd**-0.5

    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s.reshape(B, H, Sq, Skv) * scale

    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    pg = p.reshape(B, Hkv, G, Sq, Skv)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pg, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
