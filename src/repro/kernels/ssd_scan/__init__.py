from repro.kernels.ssd_scan.ops import ssd

__all__ = ["ssd"]
