"""Pure-jnp oracles for the SSD scan kernel.

``ssd_sequential_ref`` is the definitionally-correct O(S) recurrence
(the SSM semantics the chunked algorithm must match):

    h_t = exp(dt_t · A) · h_{t−1} + dt_t · x_t ⊗ B_t
    y_t = C_t · h_t

``ssd_chunked`` (repro.models.mamba2) is the chunked restatement; the Pallas
kernel mirrors the chunked algorithm's block structure.  Tests close the
triangle: kernel ≈ chunked ≈ sequential.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential_ref(x, dt, A, Bm, Cm):
    """x (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm, Cm (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        dA = jnp.exp(dt_t.astype(jnp.float32) * A[None, :])  # (B,H)
        upd = (dt_t[..., None].astype(jnp.float32) * x_t.astype(jnp.float32))[..., None] \
            * B_t[:, None, None, :].astype(jnp.float32)  # (B,H,P,N)
        h = dA[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1).astype(x.dtype), hT
