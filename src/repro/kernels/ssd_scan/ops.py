"""Public SSD wrapper (matches repro.models.mamba2.ssd_chunked's contract)."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_chunked_pallas

INTERPRET = jax.default_backend() != "tpu"


def ssd(x, dt, A, Bm, Cm, chunk: int = 128):
    """x (B,S,H,P); dt (B,S,H) post-softplus; A (H,); Bm/Cm (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    return ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=INTERPRET)
