"""Pallas TPU kernel: chunked SSD (state-space duality) scan, mamba2 style.

TPU adaptation (DESIGN.md §3): the Mamba2 CUDA kernel leans on warp-level
shuffles for the intra-chunk scan; the TPU-native restatement keeps the SSD
*block* decomposition — a quadratic (L×L) intra-chunk part that is pure MXU
matmul work, plus an inter-chunk rank-N state recurrence — and maps the
sequential chunk recurrence onto the innermost grid dimension, carrying the
(P, N) running state in VMEM scratch across grid steps (same persistence
trick as the flash kernel's online-softmax state).

Grid: (B, H, n_chunks).  Per step the kernel loads (L,P) inputs, (L,N) B/C
blocks and the per-head decay row, does three small matmuls
(C·Bᵀ → L×L masked by the decay triangle; scores·(x·dt) → L×P diag output;
C·state → L×P off-diag output) and one rank-update of the state.  L=chunk
defaults to 128 (lane-aligned); P=64/128 keeps every matmul MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, 1, L, P)
    dt_ref,  # (1, 1, L, 1)
    a_ref,  # (1, 1)  A for this head (negative)
    b_ref,  # (1, 1, L, N)
    c_ref,  # (1, 1, L, N)
    y_ref,  # (1, 1, L, P)
    st_ref,  # (1, 1, P, N)  final-state output (written at last chunk)
    state_scr,  # (P, N) f32 running state
    *,
    L: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (L, 1)
    A = a_ref[0, 0]
    Bm = b_ref[0, 0].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (L, N)

    dA = dt * A  # (L, 1) negative increments
    dAcs = jnp.cumsum(dA, axis=0)  # (L, 1) inclusive

    # ---- intra-chunk: masked quadratic attention-like matmul ----
    seg = dAcs - dAcs.T  # (L, L): dAcs[i] − dAcs[j]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)  # decay triangle
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    scores = CB * Lmat
    xdt = x * dt  # (L, P)
    y_diag = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # ---- off-diagonal: contribution of the state entering this chunk ----
    state_in = state_scr[...]  # (P, N)
    y_off = jax.lax.dot_general(
        Cm, state_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(dAcs)  # (L, P)

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # ---- state update: s ← exp(ΣdA)·s + Σ_l decay_to_end·dt·x_l ⊗ B_l ----
    decay_to_end = jnp.exp(dAcs[-1:] - dAcs)  # (L, 1)
    weighted_x = xdt * decay_to_end  # (L, P)
    s_chunk = jax.lax.dot_general(
        weighted_x, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    chunk_decay = jnp.exp(dAcs[-1, 0])
    state_scr[...] = chunk_decay * state_in + s_chunk

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        st_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """x (B,S,H,P); dt (B,S,H); A (H,); Bm, Cm (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N) f32)."""
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    n_chunks = pl.cdiv(S, L)
    pad = n_chunks * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 ⇒ identity steps
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad

    # head-major chunked layouts
    xc = x.transpose(0, 2, 1, 3)  # (B, H, Sp, P)
    dtc = dt.transpose(0, 2, 1)[..., None]  # (B, H, Sp, 1)
    a2 = A.reshape(H, 1).astype(jnp.float32)
    # B/C are head-shared (G=1): broadcast to heads so the (b,h,c) grid can
    # tile them uniformly.  (On real HW you'd index-map the shared array
    # instead; broadcast keeps the interpret path simple and the bytes
    # accounting explicit.)
    bc = jnp.broadcast_to(Bm[:, None], (Bsz, H, Sp, N))
    cc = jnp.broadcast_to(Cm[:, None], (Bsz, H, Sp, N))

    kernel = functools.partial(_ssd_kernel, L=L, n_chunks=n_chunks)
    y, st = pl.pallas_call(
        kernel,
        grid=(Bsz, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, L, Pd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, Pd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Pd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Sp, Pd), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, a2, bc, cc)
    y = y.transpose(0, 2, 1, 3)[:, :S]  # (B, S, H, P)
    return y, st
