"""Pallas TPU kernel: fused server update over the (C, P) delta plane.

The server's round-close is three chained reductions/maps over
cohort-stacked flat planes:

    mean  = Σ_c wn_c · Δ_c            (masked cohort mean; wn = mask/|S|)
    m'    = c_mm·m + c_md·mean        (momentum EMA / pseudo-grad store)
    x'    = x + c_xd·mean             (server param step)

Unfused that is one pass over the (C, P) plane for the mean plus two more
params-sized read/write pairs with the mean materialized in between; this
kernel streams the plane once per element-column, keeps the mean in VMEM,
and writes (x', m', mean) in the same pass — the whole server phase becomes
one roofline-memory-term trip over C+2 reads and 3 writes per plane column.

A fourth SMEM scalar γ (``staleness discount``, FedACG-style lookahead
weighting) scales the folded mean before the EMA/step consume it:

    m'    = c_mm·m + c_md·(γ·mean)
    x'    = x + c_xd·(γ·mean)

The async pipelined engine (``FederatedEngine.run_rounds_async``) folds
cohorts whose deltas are ``pipeline_depth − 1`` rounds stale and passes
γ = staleness_discount^(depth−1); the sync path passes γ = 1.0 (exact —
a f32 multiply by 1.0 is the identity).  The emitted ``mean`` output stays
UNdiscounted so delta-norm metrics report the cohort's actual update.

Coefficient mapping (see core/engine.py):
* fedavg/fedcm : c_mm=0, c_md=−1/(η_l·K), c_xd=η_g      (m' := Δ_{t+1})
* scaffold     : params pass (1, 0, η_g) over Δ, then the c-EMA pass
  (1, |S|/N, 0) over Δc — the x/m slots carry whichever buffer updates.
* mimelite     : params pass (1, 0, η_g) over Δ, momentum pass
  (1−α, α, 0) over the full-batch-grad plane.

Tiling: planes are padded to a multiple of ``block_elems`` and viewed as
(padded//LANE, LANE); the delta plane blocks as (C, rows, LANE) — the whole
cohort column is resident per grid step (C is a cohort, 8–64, so a block is
C·256 KiB of VMEM at the default; shrink ``block_elems`` for huge cohorts).
``wn`` is lane-padded to (C, LANE) (column 0 live) instead of an unaligned
(C, 1) operand; coefficients ride in SMEM as a (1, 4) row
(c_mm, c_md, c_xd, γ) since several of them are traced per-round values.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK = 16 * 1024  # per-client elements per grid step


def _make_kernel(write_x: bool, write_m: bool):
    """Kernel body emitting only the adopted outputs (and reading only the
    buffers they need): a pass with a statically-zero param step never
    reads x or writes x' — the skip is a real HBM-bandwidth skip, not a
    discarded output XLA can't DCE out of a pallas_call."""

    def kernel(coef_ref, wn_ref, d_ref, *refs):
        c_mm = coef_ref[0, 0]
        c_md = coef_ref[0, 1]
        c_xd = coef_ref[0, 2]
        gamma = coef_ref[0, 3]  # staleness discount on the folded mean
        wn = wn_ref[...][:, 0].astype(jnp.float32)  # (C,) mask/|S| weights
        d = d_ref[...].astype(jnp.float32)  # (C, rows, LANE)
        mean = jnp.sum(d * wn[:, None, None], axis=0)  # (rows, LANE)
        dmean = gamma * mean
        refs = list(refs)
        x_ref = refs.pop(0) if write_x else None
        m_ref = refs.pop(0) if write_m else None
        if write_x:
            newx_ref = refs.pop(0)
        if write_m:
            newm_ref = refs.pop(0)
        mean_ref = refs.pop(0)
        if write_x:
            x = x_ref[...].astype(jnp.float32)
            newx_ref[...] = (x + c_xd * dmean).astype(newx_ref.dtype)
        if write_m:
            m = m_ref[...].astype(jnp.float32)
            newm_ref[...] = (c_mm * m + c_md * dmean).astype(newm_ref.dtype)
        mean_ref[...] = mean

    return kernel


@partial(jax.jit, static_argnames=("m_dtype", "block_elems", "interpret",
                                   "write_x", "write_m"))
def server_update_flat(deltas, wn, x, m, coefs, *, m_dtype=None,
                       block_elems: int = DEFAULT_BLOCK, interpret: bool = True,
                       write_x: bool = True, write_m: bool = True):
    """deltas: (C, P); wn: (C,) premultiplied mask/|S| weights; x, m: (P,);
    coefs: (4,) f32 (c_mm, c_md, c_xd, γ) where γ is the staleness
    discount applied to the mean before the EMA/step (1.0 = sync exact).
    Returns (new_x, new_m, mean) with new_m in ``m_dtype`` (default
    m.dtype) and mean in f32 (UNdiscounted).

    ``write_x``/``write_m`` (static) drop the param-step / momentum-EMA
    outputs — AND their input reads — from the launch entirely; the
    corresponding return slot is ``None``.  Multi-pass folds (scaffold's
    c-EMA pass, the post-step algorithms' c_xd=0 passes) use this so a
    structurally-skipped update costs zero plane traffic."""
    C, n = deltas.shape
    m_dt = jnp.dtype(m_dtype) if m_dtype is not None else m.dtype
    rows = block_elems // LANE
    # grid floor of 2: a single-step grid gets its loop collapsed and
    # re-fused into the surrounding program, where XLA:CPU may contract
    # the EMA's mul+add chains into FMAs differently per calling program —
    # a 1-ulp divergence between e.g. the sharded (plane-column chunk) and
    # unsharded launches of the SAME fold (measured; the cohort-parallel
    # bitwise tests pin it).  A ≥2-step grid keeps the body an isolated,
    # shape-stable loop computation; the extra block is pure padding.
    nblocks = max(2, pl.cdiv(n, block_elems))
    padded = nblocks * block_elems
    pad = padded - n

    def prep(a):
        a = jnp.pad(a, (0, pad))
        return a.reshape(padded // LANE, LANE)

    dr = jnp.pad(deltas, ((0, 0), (0, pad))).reshape(C, padded // LANE, LANE)
    wn_l = jnp.zeros((C, LANE), jnp.float32).at[:, 0].set(wn.astype(jnp.float32))

    vec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    plane = pl.BlockSpec((C, rows, LANE), lambda i: (0, i, 0))
    smem = pl.BlockSpec((1, 4), lambda i: (0, 0))
    wspec = pl.BlockSpec((C, LANE), lambda i: (0, 0))
    operands = [coefs.astype(jnp.float32).reshape(1, 4), wn_l, dr]
    in_specs = [smem, wspec, plane]
    out_specs, out_shape = [], []
    if write_x:
        xr = prep(x)
        operands.append(xr)
        in_specs.append(vec)
        out_specs.append(vec)
        out_shape.append(jax.ShapeDtypeStruct(xr.shape, x.dtype))
    if write_m:
        mr = prep(m)
        operands.append(mr)
        in_specs.append(vec)
        out_specs.append(vec)
        out_shape.append(jax.ShapeDtypeStruct(mr.shape, m_dt))
    out_specs.append(vec)
    out_shape.append(jax.ShapeDtypeStruct((padded // LANE, LANE), jnp.float32))
    outs = pl.pallas_call(
        _make_kernel(write_x, write_m),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    outs = [o.reshape(padded)[:n] for o in outs]
    new_x = outs.pop(0) if write_x else None
    new_m = outs.pop(0) if write_m else None
    return new_x, new_m, outs[0]


def _make_dequant_kernel(write_x: bool, write_m: bool):
    """The compressed-uplink fold: dequantize → masked-weighted accumulate
    → EMA/param step, fused in ONE pass over the compressed plane.

        d_c   = scale_c · q_c              (per-row dequant, in VMEM)
        mean  = Σ_c wn_c · d_c
        m'    = c_mm·m + c_md·(γ·mean)
        x'    = x + c_xd·(γ·mean)

    The f32 ``(C, P)`` cohort plane NEVER exists in HBM — the kernel
    streams the int8/bf16 blocks and dequantizes in registers, so the
    fold's plane traffic shrinks 4x (int8) / 2x (bf16) with it.  ``q``
    may be int8 (stochastic-rounded, scale = absmax/127) or bf16
    (scale ≡ 1.0, exact under f32).  Same grid/output structure as
    ``_make_kernel`` — the uncompressed kernel stays byte-identical, and
    the ≥2-step grid floor that makes sharded column launches bitwise
    applies unchanged."""

    def kernel(coef_ref, wn_ref, sc_ref, q_ref, *refs):
        c_mm = coef_ref[0, 0]
        c_md = coef_ref[0, 1]
        c_xd = coef_ref[0, 2]
        gamma = coef_ref[0, 3]  # staleness discount on the folded mean
        wn = wn_ref[...][:, 0].astype(jnp.float32)  # (C,) mask/|S| weights
        sc = sc_ref[...][:, 0].astype(jnp.float32)  # (C,) dequant scales
        # dequantize in-register: (C, rows, LANE) f32 exists only in VMEM
        d = q_ref[...].astype(jnp.float32) * sc[:, None, None]
        mean = jnp.sum(d * wn[:, None, None], axis=0)  # (rows, LANE)
        dmean = gamma * mean
        refs = list(refs)
        x_ref = refs.pop(0) if write_x else None
        m_ref = refs.pop(0) if write_m else None
        if write_x:
            newx_ref = refs.pop(0)
        if write_m:
            newm_ref = refs.pop(0)
        mean_ref = refs.pop(0)
        if write_x:
            x = x_ref[...].astype(jnp.float32)
            newx_ref[...] = (x + c_xd * dmean).astype(newx_ref.dtype)
        if write_m:
            m = m_ref[...].astype(jnp.float32)
            newm_ref[...] = (c_mm * m + c_md * dmean).astype(newm_ref.dtype)
        mean_ref[...] = mean

    return kernel


@partial(jax.jit, static_argnames=("m_dtype", "block_elems", "interpret",
                                   "write_x", "write_m"))
def dequant_update_flat(q, scale, wn, x, m, coefs, *, m_dtype=None,
                        block_elems: int = DEFAULT_BLOCK,
                        interpret: bool = True,
                        write_x: bool = True, write_m: bool = True):
    """Fused dequantize-fold launch: ``q`` (C, P) int8 or bf16, ``scale``
    (C,) or (C, 1) per-row f32 dequant scales, the rest exactly
    ``server_update_flat``'s contract (wn premultiplied mask/|S|, coefs =
    (c_mm, c_md, c_xd, γ)).  Returns (new_x, new_m, mean) with the mean
    of the DEQUANTIZED plane, f32, undiscounted.

    Layout matches the uncompressed launch: the compressed plane blocks
    as (C, rows, LANE) with the whole cohort column resident per grid
    step, and ``scale`` rides lane-padded (C, LANE) next to ``wn``
    instead of an unaligned (C, 1) operand.  (On real TPUs int8 tiles
    want (32, 128) minimum — the ``rows``-sized second axis satisfies it
    for every block_elems ≥ 32·LANE; interpret mode is layout-agnostic.)
    """
    C, n = q.shape
    m_dt = jnp.dtype(m_dtype) if m_dtype is not None else m.dtype
    rows = block_elems // LANE
    # same ≥2-step grid floor as server_update_flat (bitwise rationale
    # in that docstring: a collapsed 1-step grid re-fuses per-program)
    nblocks = max(2, pl.cdiv(n, block_elems))
    padded = nblocks * block_elems
    pad = padded - n

    def prep(a):
        a = jnp.pad(a, (0, pad))
        return a.reshape(padded // LANE, LANE)

    qr = jnp.pad(q, ((0, 0), (0, pad))).reshape(C, padded // LANE, LANE)
    wn_l = jnp.zeros((C, LANE), jnp.float32).at[:, 0].set(wn.astype(jnp.float32))
    sc_l = jnp.zeros((C, LANE), jnp.float32).at[:, 0].set(
        scale.astype(jnp.float32).reshape(C)
    )

    vec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    plane = pl.BlockSpec((C, rows, LANE), lambda i: (0, i, 0))
    smem = pl.BlockSpec((1, 4), lambda i: (0, 0))
    wspec = pl.BlockSpec((C, LANE), lambda i: (0, 0))
    operands = [coefs.astype(jnp.float32).reshape(1, 4), wn_l, sc_l, qr]
    in_specs = [smem, wspec, wspec, plane]
    out_specs, out_shape = [], []
    if write_x:
        xr = prep(x)
        operands.append(xr)
        in_specs.append(vec)
        out_specs.append(vec)
        out_shape.append(jax.ShapeDtypeStruct(xr.shape, x.dtype))
    if write_m:
        mr = prep(m)
        operands.append(mr)
        in_specs.append(vec)
        out_specs.append(vec)
        out_shape.append(jax.ShapeDtypeStruct(mr.shape, m_dt))
    out_specs.append(vec)
    out_shape.append(jax.ShapeDtypeStruct((padded // LANE, LANE), jnp.float32))
    outs = pl.pallas_call(
        _make_dequant_kernel(write_x, write_m),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    outs = [o.reshape(padded)[:n] for o in outs]
    new_x = outs.pop(0) if write_x else None
    new_m = outs.pop(0) if write_m else None
    return new_x, new_m, outs[0]
