"""Fused server round-close: masked cohort mean + momentum EMA + param step."""
from repro.kernels.server_update.ops import INTERPRET, fused_server_step
from repro.kernels.server_update.ref import server_update_ref

__all__ = ["INTERPRET", "fused_server_step", "server_update_ref"]
