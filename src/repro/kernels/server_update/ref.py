"""Pure-jnp oracle for the fused server update.

    mean = Σ_c wn_c · Δ_c
    m'   = c_mm·m + c_md·mean
    x'   = x + c_xd·mean
"""
from __future__ import annotations

import jax.numpy as jnp


def server_update_ref(deltas, wn, x, m, coefs, m_dtype=None):
    coefs = coefs.astype(jnp.float32)
    mean = jnp.sum(
        deltas.astype(jnp.float32) * wn.astype(jnp.float32)[:, None], axis=0
    )
    new_m = coefs[0] * m.astype(jnp.float32) + coefs[1] * mean
    new_x = (x.astype(jnp.float32) + coefs[2] * mean).astype(x.dtype)
    return new_x, new_m.astype(m_dtype or m.dtype), mean
