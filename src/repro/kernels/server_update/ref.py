"""Pure-jnp oracle for the fused server update.

    mean = Σ_c wn_c · Δ_c
    m'   = c_mm·m + c_md·(γ·mean)
    x'   = x + c_xd·(γ·mean)

γ (coefs[3]) is the staleness discount the async pipelined engine applies
to folds of in-flight cohorts; the sync path passes γ = 1.0.  The emitted
``mean`` stays undiscounted (it feeds the delta-norm metric).
"""
from __future__ import annotations

import jax.numpy as jnp


def server_update_ref(deltas, wn, x, m, coefs, m_dtype=None):
    coefs = coefs.astype(jnp.float32)
    mean = jnp.sum(
        deltas.astype(jnp.float32) * wn.astype(jnp.float32)[:, None], axis=0
    )
    dmean = coefs[3] * mean
    new_m = coefs[0] * m.astype(jnp.float32) + coefs[1] * dmean
    new_x = (x.astype(jnp.float32) + coefs[2] * dmean).astype(x.dtype)
    return new_x, new_m.astype(m_dtype or m.dtype), mean


def dequant_server_update_ref(q, scale, wn, x, m, coefs, m_dtype=None):
    """Oracle for the fused dequant fold: dequantize the compressed
    ``(C, P)`` plane (int8 or bf16 ``q`` × per-row f32 ``scale``) to f32,
    then the standard masked-mean/EMA/step — the exact op order the
    ``_make_dequant_kernel`` body uses."""
    deltas = q.astype(jnp.float32) * scale.astype(jnp.float32).reshape(-1, 1)
    return server_update_ref(deltas, wn, x, m, coefs, m_dtype)
