"""jit'd public wrapper for the fused server round-close kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.server_update.kernel import server_update_flat

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def fused_server_step(deltas, wn, x, m, c_mm, c_md, c_xd, m_dtype=None,
                      discount=1.0):
    """Masked cohort mean + momentum EMA + param step, one pass over (C, P).

    deltas (C, P), wn (C,) = mask/|S|, x (P,), m (P,).  Coefficients may be
    traced per-round scalars.  ``discount`` is the staleness weight γ the
    async engine applies to folded in-flight cohorts (rides SMEM with the
    other coefficients; 1.0 = sync, exact).  Returns
    (new_x, new_m, mean_delta) with mean_delta UNdiscounted.
    """
    coefs = jnp.stack([
        jnp.asarray(c_mm, jnp.float32),
        jnp.asarray(c_md, jnp.float32),
        jnp.asarray(c_xd, jnp.float32),
        jnp.asarray(discount, jnp.float32),
    ])
    return server_update_flat(
        deltas, wn, x, m, coefs, m_dtype=m_dtype, interpret=INTERPRET
    )
