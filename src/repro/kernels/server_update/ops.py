"""jit'd public wrappers for the fused server round-close kernel.

``fused_server_step`` launches one coefficient-row pass; ``fused_fold``
executes ALL of an ``AlgorithmSpec``'s declarative fold rows
(``repro.core.registry.FoldPass``) against the cohort's uplink planes —
the registry-driven replacement for the old per-algorithm dispatch.

``scatter_fold`` is the shard_map form of ``fused_fold`` for the
cohort-parallel engine: called INSIDE a ``shard_map`` over the
``"clients"`` mesh axis, it lowers the masked cohort mean to an explicit
reduce-scatter (``all_to_all`` to plane-column shards + device-local
full-cohort reduce — NOT ``psum_scatter``, whose per-device partial sums
would re-associate the f32 reduction and break bitwise equality with the
unsharded fold), runs the fold rows as kernel launches over each device's
``(C, P/num_shards)`` column block, and ``all_gather``s the updated
planes back to replicated form.

Launches are shard_map-compatible by construction — each device launches
on its LOCAL shapes — but interpret-mode bitwise stability across shard
counts needs one extra care: ``_auto_block`` floors the block size so the
grid loop keeps ≥ 2 steps whenever the plane allows it.  A single-step
grid gets its loop collapsed and re-fused into the surrounding program,
where XLA:CPU is free to contract the EMA's mul+add chains into FMAs
differently per program — a 1-ulp divergence between the sharded and
unsharded launches of the SAME math (measured); a real multi-step loop
body compiles shape-identically on both."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.server_update.kernel import (
    DEFAULT_BLOCK, LANE, dequant_update_flat, server_update_flat,
)

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def _auto_block(n: int, default: int = DEFAULT_BLOCK) -> int:
    """Largest LANE-multiple block ≤ ``default`` giving a ≥ 2-step grid.

    Keeps the interpret-mode grid loop a REAL loop for every plane length
    that allows it (n > 2·LANE): the loop body then compiles as its own
    shape-stable computation, and sharded / unsharded launches of the same
    fold stay bitwise (see module docstring).  Sub-2·LANE planes keep the
    single block — there is nothing to split."""
    half = (n // (2 * LANE)) * LANE
    return max(LANE, min(default, half)) if half else min(default, LANE)


def fused_server_step(deltas, wn, x, m, c_mm, c_md, c_xd, m_dtype=None,
                      discount=1.0, write_x=True, write_m=True):
    """Masked cohort mean + momentum EMA + param step, one pass over (C, P).

    deltas (C, P), wn (C,) = mask/|S|, x (P,), m (P,).  Coefficients may be
    traced per-round scalars.  ``discount`` is the staleness weight γ the
    async engine applies to folded in-flight cohorts (rides SMEM with the
    other coefficients; 1.0 = sync, exact).  Returns
    (new_x, new_m, mean_delta) with mean_delta UNdiscounted; a statically
    dropped output (``write_x``/``write_m`` False) comes back ``None`` and
    costs no plane traffic.

    Block size is ``_auto_block`` of the plane length, so the launch's
    grid loop keeps ≥ 2 steps — the same fold launched on a plane-column
    SHARD (cohort-parallel engine) then compiles bitwise-identically to
    the full-plane launch.
    """
    coefs = jnp.stack([
        jnp.asarray(c_mm, jnp.float32),
        jnp.asarray(c_md, jnp.float32),
        jnp.asarray(c_xd, jnp.float32),
        jnp.asarray(discount, jnp.float32),
    ])
    return server_update_flat(
        deltas, wn, x, m, coefs, m_dtype=m_dtype, interpret=INTERPRET,
        block_elems=_auto_block(deltas.shape[-1]),
        write_x=write_x, write_m=write_m,
    )


def dequant_server_step(q, scale, wn, x, m, c_mm, c_md, c_xd, m_dtype=None,
                        discount=1.0, write_x=True, write_m=True):
    """``fused_server_step`` over a COMPRESSED plane: dequantize (int8/bf16
    ``q`` × per-row ``scale``) → masked mean → EMA/step, one fused pass —
    the f32 ``(C, P)`` plane never materializes outside VMEM.  Contract
    otherwise identical to ``fused_server_step`` (same ``_auto_block``
    ≥2-step grid, so sharded column launches stay bitwise vs unsharded)."""
    coefs = jnp.stack([
        jnp.asarray(c_mm, jnp.float32),
        jnp.asarray(c_md, jnp.float32),
        jnp.asarray(c_xd, jnp.float32),
        jnp.asarray(discount, jnp.float32),
    ])
    return dequant_update_flat(
        q, scale, wn, x, m, coefs, m_dtype=m_dtype, interpret=INTERPRET,
        block_elems=_auto_block(q.shape[-1]),
        write_x=write_x, write_m=write_m,
    )


def fused_fold(spec, cfg, planes, wn, n_active, x, m, eta_l, discount=1.0):
    """Execute an ``AlgorithmSpec``'s fold rows as fused kernel passes.

    ``planes`` maps plane names ("delta"/"state_delta"/"extra") to the
    cohort's raw ``(C, P)`` uplink planes; ``wn`` = mask/|S|.  Each
    ``FoldPass`` becomes one ``fused_server_step`` launch; statically-zero
    coefficients skip the corresponding state adoption (a pass with
    ``c_xd == 0.0`` never rewrites params, a pass with ``c_md == 0.0,
    c_mm == 1.0`` never re-rounds the momentum buffer) — the same
    structural skips the jnp interpreter (``AlgorithmSpec.server_update``)
    applies, so the two routes stay step-for-step comparable.

    Honors ``cfg.aggregate_dtype`` exactly like the jnp paths: uplink
    planes are quantized BEFORE the reduction (the kernel body then
    accumulates in f32); only the reduction inputs are cast — the
    client-state scatter keeps the unquantized plane, as the tree oracle
    does.  Returns ``(new_x, new_m, mean_delta)`` with ``mean_delta`` the
    UNdiscounted mean of the "delta" pass (metrics + post-steps consume
    it).
    """
    # deferred import: repro.core.engine imports this module at package
    # init, so a module-level registry import would be circular
    from repro.core.compress import QPlane
    from repro.core.registry import _fold_coef, _is_static_one, _is_static_zero

    agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

    def q(plane):
        return plane if agg_dt == jnp.float32 else plane.astype(agg_dt)

    m_dt = (jnp.dtype(getattr(cfg, "momentum_dtype", "float32"))
            if spec.momentum_store == "momentum_dtype" else jnp.float32)
    mean_delta = None
    for p in spec.fold:
        c_mm = _fold_coef(p.c_mm, cfg, eta_l, n_active)
        c_md = _fold_coef(p.c_md, cfg, eta_l, n_active)
        c_xd = _fold_coef(p.c_xd, cfg, eta_l, n_active)
        adopt_x = not _is_static_zero(p.c_xd)
        adopt_m = not (_is_static_zero(p.c_md) and _is_static_one(p.c_mm))
        pv = planes[p.plane]
        if isinstance(pv, QPlane):
            # compressed uplink (repro.core.compress): the fused dequant
            # fold consumes the int8/bf16 representation directly — the
            # f32 (C, P) plane never materializes (aggregate_dtype
            # quantization does not compose; the rep IS the quantization)
            new_x, new_m, mean = dequant_server_step(
                pv.q, pv.scale, wn, x, m, c_mm, c_md, c_xd,
                m_dtype=m_dt, discount=discount,
                write_x=adopt_x, write_m=adopt_m,
            )
        else:
            new_x, new_m, mean = fused_server_step(
                q(pv), wn, x, m, c_mm, c_md, c_xd,
                m_dtype=m_dt, discount=discount,
                write_x=adopt_x, write_m=adopt_m,
            )
        if p.plane == "delta":
            mean_delta = mean
        if adopt_x:
            x = new_x
        if adopt_m:
            m = new_m
    return x, m, mean_delta


def scatter_fold(spec, cfg, planes, wn, n_active, x, m, eta_l, discount=1.0,
                 *, axis_name: str, n_shards: int):
    """``fused_fold`` under cohort sharding — call INSIDE ``shard_map``.

    ``planes`` maps plane names to the device-LOCAL ``(C/n_shards, P)``
    shards of the cohort uplink (each device computed its own clients
    end-to-end); ``wn`` is the full replicated ``(C,)`` mask/|S| row; ``x``
    and ``m`` are the replicated ``(P,)`` server planes.  Three steps:

    1. reduce-scatter, decomposed bitwise-safely: ``all_to_all`` turns
       client-sharding into plane-column sharding — each device now holds
       ``(C, P/n_shards)``, the COMPLETE cohort for its columns — so the
       fold's masked reduce runs device-locally in exactly the unsharded
       reduction order.  The D−1 rounds of latency the async ring gives
       this collective are what hide it behind the next cohort's compute.
    2. the spec's fold rows execute as ``fused_fold`` kernel launches over
       the column block, updating each device's ``x``/``m`` chunk.
    3. ``all_gather`` rebuilds the replicated ``(P,)`` planes (the next
       round broadcasts them to every client anyway).

    Returns ``(new_x, new_m, mean_delta)`` — replicated, ``mean_delta``
    UNdiscounted, exactly ``fused_fold``'s contract.  The collective
    decomposition lives in ``repro.core.flat`` (``cohort_to_columns`` /
    ``plane_chunk`` / ``gather_plane``) — shared with the scattered-mean
    path so the bitwise-load-bearing layout has one definition.
    """
    from repro.core.compress import QPlane
    from repro.core.flat import cohort_to_columns, gather_plane, plane_chunk

    def to_cols(v):
        if isinstance(v, QPlane):
            # the all_to_all moves the COMPRESSED payload (int8/bf16) —
            # the cross-device wire win of this whole PR; the per-row f32
            # scales (C/n_shards, 1) all_gather to the full (C, 1) row
            # every column shard's dequant needs (C·4 bytes, negligible)
            return QPlane(
                q=cohort_to_columns(v.q, axis_name, n_shards),
                scale=jax.lax.all_gather(v.scale, axis_name, tiled=True),
            )
        return cohort_to_columns(v, axis_name, n_shards)

    Pn = x.shape[-1]
    cols = {k: to_cols(v)
            for k, v in planes.items() if k in spec.fold_planes}
    new_x, new_m, mean = fused_fold(
        spec, cfg, cols, wn, n_active,
        plane_chunk(x, axis_name, n_shards),
        plane_chunk(m, axis_name, n_shards),
        eta_l, discount=discount,
    )
    return (gather_plane(new_x, axis_name, Pn),
            gather_plane(new_m, axis_name, Pn),
            gather_plane(mean, axis_name, Pn))
