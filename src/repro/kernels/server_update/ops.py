"""jit'd public wrappers for the fused server round-close kernel.

``fused_server_step`` launches one coefficient-row pass; ``fused_fold``
executes ALL of an ``AlgorithmSpec``'s declarative fold rows
(``repro.core.registry.FoldPass``) against the cohort's uplink planes —
the registry-driven replacement for the old per-algorithm dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.server_update.kernel import server_update_flat

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def fused_server_step(deltas, wn, x, m, c_mm, c_md, c_xd, m_dtype=None,
                      discount=1.0, write_x=True, write_m=True):
    """Masked cohort mean + momentum EMA + param step, one pass over (C, P).

    deltas (C, P), wn (C,) = mask/|S|, x (P,), m (P,).  Coefficients may be
    traced per-round scalars.  ``discount`` is the staleness weight γ the
    async engine applies to folded in-flight cohorts (rides SMEM with the
    other coefficients; 1.0 = sync, exact).  Returns
    (new_x, new_m, mean_delta) with mean_delta UNdiscounted; a statically
    dropped output (``write_x``/``write_m`` False) comes back ``None`` and
    costs no plane traffic.
    """
    coefs = jnp.stack([
        jnp.asarray(c_mm, jnp.float32),
        jnp.asarray(c_md, jnp.float32),
        jnp.asarray(c_xd, jnp.float32),
        jnp.asarray(discount, jnp.float32),
    ])
    return server_update_flat(
        deltas, wn, x, m, coefs, m_dtype=m_dtype, interpret=INTERPRET,
        write_x=write_x, write_m=write_m,
    )


def fused_fold(spec, cfg, planes, wn, n_active, x, m, eta_l, discount=1.0):
    """Execute an ``AlgorithmSpec``'s fold rows as fused kernel passes.

    ``planes`` maps plane names ("delta"/"state_delta"/"extra") to the
    cohort's raw ``(C, P)`` uplink planes; ``wn`` = mask/|S|.  Each
    ``FoldPass`` becomes one ``fused_server_step`` launch; statically-zero
    coefficients skip the corresponding state adoption (a pass with
    ``c_xd == 0.0`` never rewrites params, a pass with ``c_md == 0.0,
    c_mm == 1.0`` never re-rounds the momentum buffer) — the same
    structural skips the jnp interpreter (``AlgorithmSpec.server_update``)
    applies, so the two routes stay step-for-step comparable.

    Honors ``cfg.aggregate_dtype`` exactly like the jnp paths: uplink
    planes are quantized BEFORE the reduction (the kernel body then
    accumulates in f32); only the reduction inputs are cast — the
    client-state scatter keeps the unquantized plane, as the tree oracle
    does.  Returns ``(new_x, new_m, mean_delta)`` with ``mean_delta`` the
    UNdiscounted mean of the "delta" pass (metrics + post-steps consume
    it).
    """
    # deferred import: repro.core.engine imports this module at package
    # init, so a module-level registry import would be circular
    from repro.core.registry import _fold_coef, _is_static_one, _is_static_zero

    agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

    def q(plane):
        return plane if agg_dt == jnp.float32 else plane.astype(agg_dt)

    m_dt = (jnp.dtype(getattr(cfg, "momentum_dtype", "float32"))
            if spec.momentum_store == "momentum_dtype" else jnp.float32)
    mean_delta = None
    for p in spec.fold:
        c_mm = _fold_coef(p.c_mm, cfg, eta_l, n_active)
        c_md = _fold_coef(p.c_md, cfg, eta_l, n_active)
        c_xd = _fold_coef(p.c_xd, cfg, eta_l, n_active)
        adopt_x = not _is_static_zero(p.c_xd)
        adopt_m = not (_is_static_zero(p.c_md) and _is_static_one(p.c_mm))
        new_x, new_m, mean = fused_server_step(
            q(planes[p.plane]), wn, x, m, c_mm, c_md, c_xd,
            m_dtype=m_dt, discount=discount,
            write_x=adopt_x, write_m=adopt_m,
        )
        if p.plane == "delta":
            mean_delta = mean
        if adopt_x:
            x = new_x
        if adopt_m:
            m = new_m
    return x, m, mean_delta
