"""Pure-jnp oracle for the fused FedCM client update (Algorithm 2 line 8–9).

    v      = α·g + (1−α)·Δ
    x_new  = x − η_l·v

Unfused this is 3 HBM reads (x, g, Δ) + 2 writes (v, x) per element plus an
intermediate v materialization; the kernel does 3 reads + 1 write in one
pass (the whole point — the op is purely memory-bound, AI ≈ 0.4 flop/byte).
"""
from __future__ import annotations

import jax.numpy as jnp


def fedcm_step_ref(x, g, delta, alpha, eta_l):
    v = alpha * g.astype(jnp.float32) + (1.0 - alpha) * delta.astype(jnp.float32)
    return (x.astype(jnp.float32) - eta_l * v).astype(x.dtype)
