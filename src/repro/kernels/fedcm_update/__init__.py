from repro.kernels.fedcm_update.ops import fedcm_step, fedcm_step_tree

__all__ = ["fedcm_step", "fedcm_step_tree"]
