"""Legacy FedCM whole-tree client-step kernel — RETIRED to oracle-only.

The per-local-step blend ``x ← x − η_l·(α·g + (1−α)·Δ)`` now launches
through the generalized ``kernels/fed_direction`` kernel on the flat
parameter plane (coefficients ``(η_l, α, 0, 1−α)``); the whole-tree
``fedcm_step_tree`` wrapper — which paid a concatenate/split round-trip
per local step — and its dedicated Pallas body are deleted.  Only the
pure-jnp oracle ``ref.fedcm_step_ref`` remains: tests use it to pin
``fed_direction``'s blend form to Algorithm 2 line 8–9 independently of
``fed_direction``'s own reference.
"""
from repro.kernels.fedcm_update.ref import fedcm_step_ref

__all__ = ["fedcm_step_ref"]
