"""jit'd public wrappers for the fused FedCM update kernel.

``fedcm_step`` operates on a single array (any shape); ``fedcm_step_tree``
ravels an entire parameter pytree into ONE flat kernel launch — for
ResNet/transformer-sized clients this turns dozens of small elementwise ops
into a single bandwidth-saturating pass (small leaves would otherwise never
amortize kernel launch + tiling overheads).

Dtype fidelity: ``g``/``delta`` are passed to the kernel in THEIR OWN
dtypes — the kernel body upcasts to f32, blends, and only the output is
downcast to the params dtype.  (Pre-casting the f32 momentum to bf16 params
before the launch, as an earlier revision did, silently truncated the
momentum the body was about to upcast anyway; tests/test_kernels.py keeps a
bf16 regression for it.)

These wrappers remain the tree-path kernel route.  The flat engine
(``repro.core.flat``) never calls them inside the local-step scan — the
plane is ravelled once per ``run_rounds`` and ``fed_direction`` runs
directly on it, so the per-step concatenate/split here disappears from the
hot path entirely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedcm_update.kernel import fedcm_step_flat
from repro.utils.trees import ravel_leaves, split_flat

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def fedcm_step(x, g, delta, alpha, eta_l):
    """x ← x − η_l·(α·g + (1−α)·Δ) for one array (any shape/dtype)."""
    shape = x.shape
    out = fedcm_step_flat(
        x.reshape(-1), g.reshape(-1), delta.reshape(-1),
        alpha, eta_l, interpret=INTERPRET,
    )
    return out.reshape(shape)


def fedcm_step_tree(params, grads, momentum, alpha, eta_l):
    """Whole-pytree fused update via one flat kernel launch."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(momentum)
    flat_x = ravel_leaves(leaves, dtype=jnp.float32)
    # momentum/grads keep full f32 precision into the kernel regardless of
    # the params dtype; only the output is rounded back per leaf
    flat_g = ravel_leaves(g_leaves, dtype=jnp.float32)
    flat_m = ravel_leaves(m_leaves, dtype=jnp.float32)
    out = fedcm_step_flat(flat_x, flat_g, flat_m, alpha, eta_l, interpret=INTERPRET)
    news = split_flat(out, [l.shape for l in leaves], [l.dtype for l in leaves])
    return jax.tree_util.tree_unflatten(treedef, news)
