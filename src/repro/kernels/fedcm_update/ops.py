"""jit'd public wrappers for the fused FedCM update kernel.

``fedcm_step`` operates on a single array (any shape); ``fedcm_step_tree``
ravels an entire parameter pytree into ONE flat kernel launch — for
ResNet/transformer-sized clients this turns dozens of small elementwise ops
into a single bandwidth-saturating pass (small leaves would otherwise never
amortize kernel launch + tiling overheads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedcm_update.kernel import fedcm_step_flat

# CPU container: interpret mode (executes the kernel body in python).
# On a real TPU runtime set INTERPRET=False.
INTERPRET = jax.default_backend() != "tpu"


def fedcm_step(x, g, delta, alpha, eta_l):
    """x ← x − η_l·(α·g + (1−α)·Δ) for one array (any shape/dtype)."""
    shape = x.shape
    out = fedcm_step_flat(
        x.reshape(-1), g.reshape(-1).astype(x.dtype), delta.reshape(-1).astype(x.dtype),
        alpha, eta_l, interpret=INTERPRET,
    )
    return out.reshape(shape)


def fedcm_step_tree(params, grads, momentum, alpha, eta_l):
    """Whole-pytree fused update via one flat kernel launch."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(momentum)
    flat_x = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat_g = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in g_leaves])
    flat_m = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in m_leaves])
    out = fedcm_step_flat(flat_x, flat_g, flat_m, alpha, eta_l, interpret=INTERPRET)
    news = []
    off = 0
    for l in leaves:
        n = l.size
        news.append(out[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, news)
