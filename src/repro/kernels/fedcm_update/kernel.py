"""Pallas TPU kernel: fused FedCM client-momentum parameter update.

The paper's only new compute is the per-local-step blend
``x ← x − η_l·(α·g + (1−α)·Δ_t)``.  On TPU this is a pure HBM-bandwidth op;
fusing the blend and the SGD step streams each of (x, g, Δ) through VMEM
exactly once and writes x once — 4 HBM transfers/element instead of 6 for
the unfused pair of ops (≈1.5× on the roofline's memory term for the update
phase).

Tiling: inputs are flattened and padded to a multiple of the block
(``block_elems``), then viewed as (n_blocks, 8, block_elems//8) so each
BlockSpec tile is a (8, L) VMEM-resident vector-lane-aligned slab.  α and
η_l arrive in SMEM as (1,1) scalars (they change every round — η_l decays —
so baking them as Python constants would force a recompile per round).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
DEFAULT_BLOCK = 64 * 1024  # elements per grid step: 64k f32 = 256 KiB/input


def _kernel(alpha_ref, eta_ref, x_ref, g_ref, d_ref, out_ref):
    alpha = alpha_ref[0, 0]
    eta = eta_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    v = alpha * g + (1.0 - alpha) * d
    out_ref[...] = (x - eta * v).astype(out_ref.dtype)


@partial(jax.jit, static_argnames=("block_elems", "interpret"))
def fedcm_step_flat(x, g, delta, alpha, eta_l, *, block_elems: int = DEFAULT_BLOCK,
                    interpret: bool = True):
    """x, g, delta: 1-D arrays of equal length.  Returns updated x."""
    n = x.shape[0]
    rows = block_elems // LANE
    padded = pl.cdiv(n, block_elems) * block_elems
    pad = padded - n

    def prep(a):
        a = jnp.pad(a, (0, pad))
        return a.reshape(padded // LANE, LANE)

    xr, gr, dr = prep(x), prep(g), prep(delta)
    nblocks = padded // block_elems

    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    smem = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _kernel,
        grid=(nblocks,),
        in_specs=[smem, smem, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(
        jnp.asarray(alpha, jnp.float32).reshape(1, 1),
        jnp.asarray(eta_l, jnp.float32).reshape(1, 1),
        xr, gr, dr,
    )
    return out.reshape(padded)[:n]
