"""Uniform model API over all architecture families.

``build_model(cfg)`` returns a ``Model`` whose members are pure functions:

    params                  = model.init(rng)
    loss, metrics           = model.loss_fn(params, batch, ...)
    logits, cache, aux      = model.apply(params, tokens, ...)
    cache                   = model.init_cache(params, batch, max_len, batch_ctx)
    logits, cache           = model.decode_step(params, token, cache, pos, ...)
    batch                   = model.dummy_batch(shape)   # concrete, for smoke tests
    specs                   = model.input_specs(shape)   # ShapeDtypeStruct, for dry-run

The federated layer (repro.core) only ever sees ``loss_fn`` — FedCM is
optimizer-level and architecture-agnostic (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.layers import ParallelContext


def cross_entropy(logits, labels, z_reg: float = 0.0):
    """Mean token cross entropy in f32. logits (B,S,V), labels (B,S) int32.

    Sharding-friendly formulation: the label log-prob is a one-hot einsum
    (partial-sums + psum when V is model-sharded) instead of
    ``take_along_axis`` — a gather over a sharded axis makes GSPMD
    all-gather the full f32 logits (≈8 GiB/chip at llama3 vocab), which
    dominated both the memory AND collective roofline terms.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    logz = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=lf.dtype)
    ll = jnp.einsum("...v,...v->...", lf, onehot)
    loss = jnp.mean(logz - ll)
    if z_reg:
        loss = loss + z_reg * jnp.mean(jnp.square(logz))
    return loss


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]
    loss_fn: Callable[..., Any]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., Any]
    dummy_batch: Callable[[ShapeConfig], Dict[str, Any]]
    input_specs: Callable[[ShapeConfig], Dict[str, Any]]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        return _build_encdec(cfg)
    return _build_decoder_only(cfg)


# ----------------------------------------------------------------------
# decoder-only (dense / moe / ssm / hybrid / vlm)
# ----------------------------------------------------------------------


def _build_decoder_only(cfg: ModelConfig) -> Model:
    def init(rng):
        return transformer.init_params(rng, cfg)

    def apply(params, tokens, *, cache=None, cache_pos=None, parallel=None,
              kv_spec=None, remat="none", use_kernels=False, return_cache=False,
              scan_unroll=1):
        return transformer.forward(
            params, tokens, cfg=cfg, cache=cache, cache_pos=cache_pos,
            parallel=parallel, kv_spec=kv_spec, remat=remat, use_kernels=use_kernels,
            return_cache=return_cache, scan_unroll=scan_unroll,
        )

    def loss_fn(params, batch, *, parallel=None, remat="none", use_kernels=False,
                scan_unroll=1):
        logits, _, aux = apply(
            params, batch["tokens"], parallel=parallel, remat=remat,
            use_kernels=use_kernels, scan_unroll=scan_unroll,
        )
        xe = cross_entropy(logits, batch["labels"])
        return xe + aux, {"xent": xe, "aux": aux}

    def init_cache(params, batch, max_len):
        return transformer.init_cache(cfg, batch, max_len)

    def decode_step(params, token, cache, pos, *, parallel=None, kv_spec=None,
                    scan_unroll=1):
        logits, new_cache, _ = apply(
            params, token, cache=cache, cache_pos=pos, parallel=parallel,
            kv_spec=kv_spec, scan_unroll=scan_unroll,
        )
        return logits, new_cache

    def dummy_batch(shape: ShapeConfig):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, size=(shape.global_batch, shape.seq_len))
        return {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(np.roll(toks, -1, axis=1), jnp.int32),
        }

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        # decode: one new token against an S-deep cache
        cache = jax.eval_shape(lambda: transformer.init_cache(cfg, B, S))
        return {
            "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    return Model(cfg, init, apply, loss_fn, init_cache, decode_step, dummy_batch, input_specs)


# ----------------------------------------------------------------------
# encoder-decoder (seamless)
# ----------------------------------------------------------------------


def _build_encdec(cfg: ModelConfig) -> Model:
    def init(rng):
        return encdec.init_params(rng, cfg)

    def apply(params, batch, *, parallel=None, remat="none", use_kernels=False,
              scan_unroll=1):
        enc_out = encdec.encode(params, batch["src_embeds"], cfg=cfg,
                                parallel=parallel, remat=remat, scan_unroll=scan_unroll)
        logits = encdec.decode_train(
            params, batch["tgt_tokens"], enc_out, cfg=cfg, parallel=parallel,
            remat=remat, scan_unroll=scan_unroll,
        )
        return logits, None, jnp.float32(0.0)

    def loss_fn(params, batch, *, parallel=None, remat="none", use_kernels=False,
                scan_unroll=1):
        logits, _, _ = apply(params, batch, parallel=parallel, remat=remat,
                             scan_unroll=scan_unroll)
        xe = cross_entropy(logits, batch["labels"])
        return xe, {"xent": xe, "aux": jnp.float32(0.0)}

    def init_cache(params, batch, max_len, enc_out=None):
        if enc_out is None:
            raise ValueError("encdec cache needs enc_out")
        return encdec.init_decode_cache(params, cfg, batch, max_len, enc_out)

    def decode_step(params, token, cache, pos, *, parallel=None, kv_spec=None,
                    scan_unroll=1):
        return encdec.decode_step(
            params, token, cache, pos, cfg=cfg, parallel=parallel, kv_spec=kv_spec,
            scan_unroll=scan_unroll,
        )

    def dummy_batch(shape: ShapeConfig):
        rng = np.random.default_rng(0)
        B, S = shape.global_batch, shape.seq_len
        toks = rng.integers(0, cfg.vocab_size, size=(B, S))
        return {
            "src_embeds": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.dtype(cfg.dtype)
            ),
            "tgt_tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(np.roll(toks, -1, axis=1), jnp.int32),
        }

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        adt = jnp.dtype(cfg.dtype)
        if shape.kind in ("train", "prefill"):
            return {
                "src_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), adt),
                "tgt_tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        hd = cfg.resolved_head_dim
        L = cfg.n_layers
        cache = {
            "k": jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, hd), adt),
            "v": jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, hd), adt),
            "cross_k": jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, hd), adt),
            "cross_v": jax.ShapeDtypeStruct((L, B, S, cfg.n_kv_heads, hd), adt),
        }
        return {
            "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    return Model(cfg, init, apply, loss_fn, init_cache, decode_step, dummy_batch, input_specs)
