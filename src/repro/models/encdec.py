"""Encoder-decoder backbone (SeamlessM4T family).

The audio frontend (mel + conformer conv feature extractor) is the
assignment's allowed stub: the encoder consumes precomputed frame
embeddings (B, S_src, D).  Everything after that is real: a bidirectional
encoder stack, a causal decoder stack with cross-attention, teacher-forced
training, and incremental decode with a self-attention KV cache plus
per-layer precomputed cross-attention K/V.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import (
    ParallelContext,
    embed_init,
    encode_kv,
    cross_attention,
    init_attention,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    self_attention,
    shard,
)


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 8)

    def enc_layer(r):
        k1, k2 = jax.random.split(r)
        return {
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(k1, cfg, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(k2, cfg, dtype=dtype),
        }

    def dec_layer(r):
        k1, k2, k3 = jax.random.split(r, 3)
        return {
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "self_attn": init_attention(k1, cfg, dtype),
            "norm_x": init_rmsnorm(cfg.d_model, dtype),
            "cross_attn": init_attention(k2, cfg, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(k3, cfg, dtype=dtype),
        }

    return {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "unembed": embed_init(ks[1], (cfg.d_model, cfg.padded_vocab), dtype),
        "enc": jax.vmap(enc_layer)(jax.random.split(ks[2], cfg.n_encoder_layers)),
        "dec": jax.vmap(dec_layer)(jax.random.split(ks[3], cfg.n_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "dec_norm": init_rmsnorm(cfg.d_model, dtype),
    }


def encode(params, src_embeds, *, cfg: ModelConfig, parallel=None, remat: str = "none", scan_unroll: int = 1):
    """src_embeds: (B, S_src, D) from the (stubbed) frontend → (B, S_src, D)."""
    h = src_embeds.astype(jnp.dtype(cfg.dtype))
    if parallel is not None:
        h = shard(h, P(parallel.data_axes, None, None), parallel)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body_bidir(h, lp):
        # bidirectional attention: no causal mask
        from repro.models.layers import attend_direct, attend_blocked, apply_rope

        x = rmsnorm(h, lp["norm1"])
        p = lp["attn"]
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        hd = cfg.resolved_head_dim
        from repro.models.layers import BLOCKED_ATTENTION_THRESHOLD
        if S >= BLOCKED_ATTENTION_THRESHOLD:
            a = attend_blocked(
                q, k, v, causal=False, window=None, scale=hd**-0.5,
                q_positions=positions, kv_positions=positions,
            )
        else:
            a = attend_direct(q, k, v, jnp.ones((1, 1, S, S), bool), hd**-0.5)
        a = jnp.einsum("bshk,hkd->bsd", a, p["wo"].astype(x.dtype))
        h = h + a
        h = h + mlp(lp["mlp"], rmsnorm(h, lp["norm2"]), cfg=cfg, parallel=parallel)
        return h, None

    fn = body_bidir
    if remat in ("full", "dots"):
        fn = jax.checkpoint(fn, prevent_cse=False)
    h, _ = jax.lax.scan(fn, h, params["enc"],
                        unroll=min(scan_unroll, cfg.n_encoder_layers) if scan_unroll > 1 else 1)
    return rmsnorm(h, params["enc_norm"])


def decode_train(params, tgt_tokens, enc_out, *, cfg: ModelConfig, parallel=None, remat="none", scan_unroll: int = 1):
    """Teacher-forced decoder: tgt_tokens (B, S_tgt) → logits (B, S_tgt, V)."""
    adtype = jnp.dtype(cfg.dtype)
    h = params["embed"][tgt_tokens].astype(adtype) * (cfg.d_model**0.5)
    if parallel is not None:
        h = shard(h, P(parallel.data_axes, None, None), parallel)
    S = tgt_tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(h, lp):
        a, _ = self_attention(
            lp["self_attn"], rmsnorm(h, lp["norm1"]), cfg=cfg,
            positions=positions, is_global=True, parallel=parallel,
        )
        h = h + a
        kv = encode_kv(lp["cross_attn"], enc_out, cfg=cfg)
        h = h + cross_attention(lp["cross_attn"], rmsnorm(h, lp["norm_x"]), kv, cfg=cfg, parallel=parallel)
        h = h + mlp(lp["mlp"], rmsnorm(h, lp["norm2"]), cfg=cfg, parallel=parallel)
        return h, None

    fn = body
    if remat in ("full", "dots"):
        fn = jax.checkpoint(fn, prevent_cse=False)
    h, _ = jax.lax.scan(fn, h, params["dec"],
                        unroll=min(scan_unroll, cfg.n_layers) if scan_unroll > 1 else 1)
    h = rmsnorm(h, params["dec_norm"])
    return jnp.einsum("bsd,dv->bsv", h, params["unembed"].astype(adtype))  # repro: noqa REP005 -- activation-dtype logits projection is a model precision choice


def init_decode_cache(params, cfg: ModelConfig, batch: int, max_len: int, enc_out):
    """Self-attn KV cache (L, B, S, Hkv, hd) + precomputed cross K/V."""
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    cross = jax.vmap(lambda lp: encode_kv(lp["cross_attn"], enc_out, cfg=cfg))(params["dec"])
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "cross_k": cross["k"],  # (L, B, S_src, Hkv, hd)
        "cross_v": cross["v"],
    }


def decode_step(params, token, cache, pos, *, cfg: ModelConfig, parallel=None, kv_spec=None, scan_unroll: int = 1):
    """token (B,1) int32; pos scalar.  Returns (logits (B,1,V), new_cache)."""
    adtype = jnp.dtype(cfg.dtype)
    h = params["embed"][token].astype(adtype) * (cfg.d_model**0.5)

    def body(h, xs):
        lp, kc, vc, xk, xv = xs
        a, new_kv = self_attention(
            lp["self_attn"], rmsnorm(h, lp["norm1"]), cfg=cfg,
            positions=jnp.asarray(pos, jnp.int32)[None], is_global=True,
            cache={"k": kc, "v": vc}, cache_pos=pos, parallel=parallel, kv_spec=kv_spec,
        )
        h = h + a
        h = h + cross_attention(
            lp["cross_attn"], rmsnorm(h, lp["norm_x"]), {"k": xk, "v": xv}, cfg=cfg, parallel=parallel
        )
        h = h + mlp(lp["mlp"], rmsnorm(h, lp["norm2"]), cfg=cfg, parallel=parallel)
        return h, (new_kv["k"], new_kv["v"])

    h, (nk, nv) = jax.lax.scan(
        body, h, (params["dec"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        unroll=min(scan_unroll, cfg.n_layers) if scan_unroll > 1 else 1,
    )
    h = rmsnorm(h, params["dec_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"].astype(adtype))  # repro: noqa REP005 -- activation-dtype logits projection is a model precision choice
    new_cache = dict(cache, k=nk, v=nv)
    return logits, new_cache
