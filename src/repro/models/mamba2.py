"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Train/prefill path: chunked SSD scan (quadratic-in-chunk, linear across
chunks) in pure jnp — the oracle mirrored by ``repro.kernels.ssd_scan``.
Decode path: O(1) recurrent state update.

Shapes (single group, G=1, as in the released mamba2 configs):
  x_in   (B, S, D)
  z,x    (B, S, d_inner)            d_inner = expand * D
  B,C    (B, S, N)                  N = ssm_state
  dt     (B, S, H)                  H = d_inner / head_dim
  state  (B, H, P, N)               P = head_dim
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelContext, dense_init, shard


def init_mamba2(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    N = cfg.ssm_state
    H = cfg.ssm_heads
    k = cfg.ssm_conv
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(rng, 5)
    dt_bias = jnp.log(jnp.expm1(jnp.exp(  # softplus-inverse of dt in [1e-3, 1e-1]
        jax.random.uniform(ks[3], (H,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))
    )))
    return {
        # in_proj packs [z, x, B, C, dt]
        "w_in": dense_init(ks[0], (D, 2 * d_inner + 2 * N + H), dtype=dtype),
        "conv_w": dense_init(ks[1], (k, conv_ch), in_axis_size=k, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),  # (H,)
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, D), in_axis_size=d_inner, dtype=dtype),
        "norm_z": jnp.zeros((d_inner,), dtype),  # gated RMSNorm scale (-1 offset)
    }


def _split_proj(h, cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = cfg.ssm_heads
    z = h[..., :d_inner]
    xBC = h[..., d_inner : 2 * d_inner + 2 * N]
    dt = h[..., 2 * d_inner + 2 * N :]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, kernel k.  xBC: (B,S,C); conv_w: (k,C).

    If conv_state (B, k-1, C) is given (decode), prepend it; returns
    (out (B,S,C), new_conv_state)."""
    k = conv_w.shape[0]
    if conv_state is not None:
        xfull = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    else:
        xfull = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = jnp.zeros_like(xBC)
    for i in range(k):  # k is tiny (4); unrolled taps
        out = out + xfull[:, i : i + S, :] * conv_w[i][None, None].astype(xBC.dtype)
    out = out + conv_b[None, None].astype(xBC.dtype)
    new_state = xfull[:, -(k - 1):, :]  # last (k-1) raw inputs
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan (pure jnp oracle).

    x  (B,S,H,P)   inputs per head
    dt (B,S,H)     positive step sizes (already softplus'd)
    A  (H,)        negative decay rates (A = -exp(A_log))
    Bm (B,S,N)     input->state projection (shared across heads, G=1)
    Cm (B,S,N)     state->output projection
    returns y (B,S,H,P), final_state (B,H,P,N)
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    L = chunk
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // L

    xc = x.reshape(Bsz, nc, L, H, Pd)
    dtc = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, L, N)
    Cc = Cm.reshape(Bsz, nc, L, N)

    dA = dtc * A[None, None, None, :]  # (B,nc,L,H) negative
    dAcs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (masked quadratic) ----
    # decay(i,j) = exp(dAcs[i] - dAcs[j]) for i >= j  (note: uses inclusive
    # cumsum on both sides => decay over steps j+1..i, and input enters with
    # dt_j * B_j at step j)
    seg = dAcs[:, :, :, None, :] - dAcs[:, :, None, :, :]  # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)  # (B,nc,L,L,H)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    scores = CB[..., None] * Lmat  # (B,nc,i,j,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (B,nc,L,H,P)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dAcs[:, :, -1:, :] - dAcs)  # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc.astype(jnp.float32), decay_to_end * dtc, xc.astype(jnp.float32))

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dAcs[:, :, -1, :])  # (B,nc,H)

    def step(carry, inp):
        st_in = carry  # (B,H,P,N)
        st_chunk, dec = inp  # (B,H,P,N), (B,H)
        out = st_in  # state entering this chunk
        new = st_chunk + dec[:, :, None, None] * st_in
        return new, out

    final_state, state_in = jax.lax.scan(
        step,
        jnp.zeros((Bsz, H, Pd, N), jnp.float32),
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    state_in = state_in.swapaxes(0, 1)  # (B,nc,H,P,N) state at chunk start

    # ---- off-diagonal contribution ----
    in_decay = jnp.exp(dAcs)  # decay from chunk start to position i
    y_off = jnp.einsum("bcin,bchpn->bcihp", Cc.astype(jnp.float32), state_in) * in_decay[..., None]

    y = (y_diag + y_off).reshape(Bsz, Sp, H, Pd)
    return y[:, :S].astype(x.dtype), final_state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One recurrent step.  state (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    B_t,C_t (B,N).  Returns (y_t (B,H,P), new_state)."""
    dA = jnp.exp(dt_t.astype(jnp.float32) * A[None, :])  # (B,H)
    inp = (dt_t[..., None].astype(jnp.float32) * x_t.astype(jnp.float32))[..., None] * B_t[:, None, None, :].astype(jnp.float32)
    new_state = dA[..., None, None] * state + inp  # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(jnp.float32))
    return y, new_state


def mamba2_block(
    params: Dict[str, Any],
    x,
    *,
    cfg: ModelConfig,
    state: Optional[Dict[str, Any]] = None,  # decode: {"ssm": (B,H,P,N), "conv": (B,k-1,C)}
    parallel: Optional[ParallelContext] = None,
    use_kernel: bool = False,
    return_state: bool = False,  # prefill: emit the final recurrent state
) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """Returns (out (B,S,D), new_state or None)."""
    Bsz, S, D = x.shape
    d_inner = cfg.ssm_expand * D
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    h = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    if parallel is not None:
        h = shard(h, P(parallel.data_axes, None, parallel.model_axis), parallel)
    z, xBC, dt = _split_proj(h, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"])  # (H,)

    if state is None:
        xBC_raw = xBC
        xBC, _ = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        xs = xBC[..., :d_inner].reshape(Bsz, S, H, Pd)
        Bm = xBC[..., d_inner : d_inner + N]
        Cm = xBC[..., d_inner + N :]
        if parallel is not None:
            # heads → model axis: the SSD intra-chunk (L,L,H) tensors are the
            # memory hot spot; head-sharding bounds them per chip
            xs = shard(xs, P(parallel.data_axes, None, parallel.model_axis, None), parallel)
            dt = shard(dt, P(parallel.data_axes, None, parallel.model_axis), parallel)
        if use_kernel:
            from repro.kernels.ssd_scan import ops as ssd_ops

            y, final_state = ssd_ops.ssd(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
        else:
            y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
        new_state = None
        if return_state:
            k = cfg.ssm_conv
            # conv state = last (k-1) RAW xBC inputs (pre-activation), padded
            # on the left when the prefill segment is shorter than k-1
            tail = xBC_raw[:, max(0, S - (k - 1)) :]
            if tail.shape[1] < k - 1:
                tail = jnp.pad(tail, ((0, 0), (k - 1 - tail.shape[1], 0), (0, 0)))
            new_state = {"ssm": final_state, "conv": tail}
    else:
        xBC, conv_state = _causal_conv(
            xBC, params["conv_w"], params["conv_b"], conv_state=state["conv"]
        )
        xs = xBC[..., :d_inner].reshape(Bsz, S, H, Pd)
        Bm = xBC[..., d_inner : d_inner + N]
        Cm = xBC[..., d_inner + N :]
        # S == 1 in decode
        y, ssm_state = ssd_decode_step(
            state["ssm"], xs[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0]
        )
        y = y[:, None]  # (B,1,H,P)
        new_state = {"ssm": ssm_state, "conv": conv_state}

    y = y.astype(x.dtype) + params["D_skip"][None, None, :, None].astype(x.dtype) * xs
    y = y.reshape(Bsz, S, d_inner)
    # gated RMSNorm (mamba2 uses norm(y * silu(z)))
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(jnp.square(gf), axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_z"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", g, params["w_out"].astype(x.dtype))
    if parallel is not None:
        out = shard(out, P(parallel.data_axes, None, None), parallel)
    return out, new_state


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict[str, Any]:
    d_inner = cfg.ssm_expand * cfg.d_model
    conv_ch = d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }
