"""Decoder-only transformer assembly (dense / MoE / SSM / hybrid / VLM).

The layer stack is organized as a *period scan*: each architecture has a
static repeating period of slots (e.g. gemma3 = 5 local-attention slots +
1 global slot; llama4 = dense slot + MoE slot; zamba2 = 5 mamba slots + 1
shared-attention slot), parameters are stacked with a leading ``n_periods``
axis, and the stack is traversed with one ``lax.scan`` whose body statically
unrolls the slots.  This keeps the HLO small, keeps slot structure (window
size, MoE-ness) static — which is what makes sliding-window layers truly
sub-quadratic — and gives remat a natural boundary (the period).

Zamba2's signature shared attention block lives OUTSIDE the scanned stack
(one parameter set, applied at every shared slot); its KV caches are still
per-application and are threaded through the scan as xs/ys.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import mamba2 as m2
from repro.models.layers import (
    ParallelContext,
    embed_init,
    init_attention,
    init_mlp,
    init_moe,
    init_rmsnorm,
    mlp,
    moe_dropping,
    moe_ref,
    rmsnorm,
    self_attention,
    shard,
)


@dataclass(frozen=True)
class SlotSpec:
    kind: str  # "attn" | "mamba"
    is_global: bool = True  # attention: full vs sliding window
    is_moe: bool = False
    shared: bool = False  # params live in params["shared"], not the stack


def period_layout(cfg: ModelConfig) -> Tuple[List[SlotSpec], int, List[SlotSpec]]:
    """Returns (period_slots, n_periods, tail_slots)."""
    if cfg.family == "ssm":
        return [SlotSpec("mamba")], cfg.n_layers, []
    if cfg.family == "hybrid":
        period = cfg.attn_every
        slots = [SlotSpec("mamba")] * (period - 1) + [SlotSpec("attn", shared=True)]
        n_periods = cfg.n_layers // period
        n_tail = cfg.n_layers - n_periods * period
        return slots, n_periods, [SlotSpec("mamba")] * n_tail
    # dense / moe / vlm: attention+ffn slots
    slots: List[SlotSpec] = []
    if cfg.local_global_pattern is not None:
        n_local, n_global = cfg.local_global_pattern
        slots = [SlotSpec("attn", is_global=False)] * n_local + [
            SlotSpec("attn", is_global=True)
        ] * n_global
    elif cfg.sliding_window is not None:
        slots = [SlotSpec("attn", is_global=False)]
    else:
        slots = [SlotSpec("attn", is_global=True)]
    if cfg.n_experts > 0 and cfg.moe_every > 1:
        # expand the period so MoE-ness is static per slot
        base = slots
        reps = cfg.moe_every // len(base) if cfg.moe_every % len(base) == 0 else cfg.moe_every
        slots = []
        for i in range(cfg.moe_every):
            s = base[i % len(base)]
            slots.append(SlotSpec(s.kind, s.is_global, is_moe=(i == cfg.moe_every - 1)))
    elif cfg.n_experts > 0:
        slots = [SlotSpec(s.kind, s.is_global, is_moe=True) for s in slots]
    period = len(slots)
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    return slots, cfg.n_layers // period, []


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------


def _init_slot(rng, slot: SlotSpec, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(rng, 4)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if slot.kind == "mamba":
        p["mamba"] = m2.init_mamba2(ks[0], cfg, dtype)
        return p
    p["attn"] = init_attention(ks[0], cfg, dtype)
    p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
    if slot.is_moe:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
    return p


def _stack_init(rng, n: int, slots: List[SlotSpec], cfg: ModelConfig, dtype):
    """Init n periods of params, stacked on a leading axis per leaf."""

    def one(r):
        ks = jax.random.split(r, len(slots))
        return {
            f"slot{i}": _init_slot(ks[i], s, cfg, dtype)
            for i, s in enumerate(slots)
            if not s.shared
        }

    if n == 0:
        return {}
    return jax.vmap(one)(jax.random.split(rng, n))


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    slots, n_periods, tail = period_layout(cfg)
    ks = jax.random.split(rng, 6)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "periods": _stack_init(ks[1], n_periods, slots, cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[2], (cfg.d_model, cfg.padded_vocab), dtype)
    if tail:
        params["tail"] = _stack_init(ks[3], len(tail), [tail[0]], cfg, dtype)
    if any(s.shared for s in slots):
        params["shared"] = _init_slot(ks[4], SlotSpec("attn", is_global=True), cfg, dtype)
    return params


# ----------------------------------------------------------------------
# slot application
# ----------------------------------------------------------------------


def _apply_slot(
    slot: SlotSpec,
    p: Dict[str, Any],
    h,
    *,
    cfg: ModelConfig,
    positions,
    cache_slot: Optional[Dict[str, Any]],
    cache_pos,
    parallel,
    kv_spec,
    use_kernels: bool,
    emit_cache: bool = False,
):
    """One slot (attention+ffn or mamba).  Returns (h, new_cache_slot, aux)."""
    aux = jnp.float32(0.0)
    if slot.kind == "mamba":
        y, new_state = m2.mamba2_block(
            p["mamba"], rmsnorm(h, p["norm1"]), cfg=cfg, state=cache_slot,
            parallel=parallel, use_kernel=use_kernels, return_state=emit_cache,
        )
        return h + y, new_state, aux
    attn_out, new_kv = self_attention(
        p["attn"],
        rmsnorm(h, p["norm1"]),
        cfg=cfg,
        positions=positions,
        is_global=slot.is_global,
        cache=cache_slot,
        cache_pos=cache_pos,
        parallel=parallel,
        kv_spec=kv_spec,
        return_kv=emit_cache,
        use_kernel=use_kernels,
    )
    h = h + attn_out
    if slot.is_moe:
        moe_fn = moe_dropping  # ref for tests comes via moe_ref in oracles
        y, moe_aux = moe_fn(p["moe"], rmsnorm(h, p["norm2"]), cfg=cfg, parallel=parallel)
        aux = aux + moe_aux
    elif "mlp" in p:
        y = mlp(p["mlp"], rmsnorm(h, p["norm2"]), cfg=cfg, parallel=parallel)
    else:
        y = jnp.zeros_like(h)
    return h + y, new_kv, aux


def _period_body(
    slots: List[SlotSpec],
    cfg: ModelConfig,
    positions,
    cache_pos,
    parallel,
    kv_spec,
    use_kernels: bool,
    shared_params,
    emit_cache: bool = False,
):
    """Returns a scan body over ((h, aux), (period_params, period_cache))."""

    def body(carry, xs):
        h, aux = carry
        pp, cache_in = xs
        cache_out = {}
        for i, slot in enumerate(slots):
            key = f"slot{i}"
            p = shared_params if slot.shared else pp[key]
            cslot = None if cache_in is None else cache_in.get(key)
            h, new_c, a = _apply_slot(
                slot, p, h, cfg=cfg, positions=positions, cache_slot=cslot,
                cache_pos=cache_pos, parallel=parallel, kv_spec=kv_spec,
                use_kernels=use_kernels, emit_cache=emit_cache,
            )
            aux = aux + a
            if new_c is not None:
                cache_out[key] = new_c
        return (h, aux), (cache_out if cache_out else None)

    return body


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------


def forward(
    params: Dict[str, Any],
    tokens,  # (B, S) int32  (VLM image tokens share the vocab)
    *,
    cfg: ModelConfig,
    cache: Optional[Dict[str, Any]] = None,
    cache_pos=None,
    parallel: Optional[ParallelContext] = None,
    kv_spec=None,
    remat: str = "none",
    use_kernels: bool = False,
    return_cache: bool = False,  # prefill: emit per-layer K/V (+ SSM states)
    scan_unroll: int = 1,  # dry-run: unroll the period scan so XLA cost
                           # analysis counts every trip (execution uses 1)
):
    """Returns (logits (B,S,V), new_cache, aux_loss)."""
    slots, n_periods, tail = period_layout(cfg)
    adtype = jnp.dtype(cfg.dtype)
    h = params["embed"][tokens].astype(adtype) * (cfg.d_model**0.5)
    if parallel is not None:
        h = shard(h, P(parallel.data_axes, None, None), parallel)

    B, S = tokens.shape
    if cache is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    else:
        positions = jnp.asarray(cache_pos, jnp.int32)[None]

    shared_p = params.get("shared")
    emit = return_cache and cache is None
    body = _period_body(
        slots, cfg, positions, cache_pos, parallel, kv_spec, use_kernels, shared_p,
        emit_cache=emit,
    )
    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    aux0 = jnp.float32(0.0)
    cache_periods = None if cache is None else cache["periods"]
    (h, aux), new_cache_periods = jax.lax.scan(
        body, (h, aux0), (params["periods"], cache_periods),
        unroll=min(scan_unroll, n_periods) if scan_unroll > 1 else 1,
    )

    new_cache = None
    if tail:
        tail_body = _period_body(
            [tail[0]], cfg, positions, cache_pos, parallel, kv_spec, use_kernels,
            shared_p, emit_cache=emit,
        )
        cache_tail = None if cache is None else cache["tail"]
        (h, aux), new_cache_tail = jax.lax.scan(
            tail_body, (h, aux), (params["tail"], cache_tail),
            unroll=min(scan_unroll, len(tail)) if scan_unroll > 1 else 1,
        )
    if cache is not None or emit:
        new_cache = {"periods": new_cache_periods}
        if tail:
            new_cache["tail"] = new_cache_tail

    h = rmsnorm(h, params["final_norm"])
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(adtype)
    logits = jnp.einsum("bsd,dv->bsv", h, unembed)
    if parallel is not None:
        logits = shard(logits, P(parallel.data_axes, None, parallel.model_axis), parallel)
    return logits, new_cache, aux


# ----------------------------------------------------------------------
# cache
# ----------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=None
) -> Dict[str, Any]:
    """Cache pytree matching the scan layout: per-slot leaves stacked over
    periods.  Attention slots: {"k","v"} (n_periods, B, S, Hkv, hd); mamba
    slots: {"ssm","conv"} stacked likewise."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    slots, n_periods, tail = period_layout(cfg)

    def slot_cache(slot: SlotSpec, n: int):
        if slot.kind == "mamba":
            st = m2.init_mamba2_state(cfg, batch, dtype)
            return jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (n, *x.shape)), st)
        hd = cfg.resolved_head_dim
        # sliding-window slots only ever read the last `window` positions —
        # but the baseline allocates full length (ring-buffer variant is the
        # §Perf memory optimization).
        shape = (n, batch, max_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    cache = {
        "periods": {
            f"slot{i}": slot_cache(s, n_periods) for i, s in enumerate(slots)
        }
    }
    if tail:
        cache["tail"] = {"slot0": slot_cache(tail[0], len(tail))}
    return cache
