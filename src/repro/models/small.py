"""Small classifiers for the federated reproduction (paper §6.1 scaled).

The paper trains ResNet-18 with GroupNorm on CIFAR.  At container scale we
use (a) an MLP over synthetic feature vectors and (b) a small CNN with
GroupNorm (the paper's BN→GN substitution matters for federated averaging —
BN running stats break under client averaging, GN is stateless) over
synthetic images.  Both are pure-functional (init/apply) and are consumed by
the round engine through ``classification_loss``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class SmallModel(NamedTuple):
    init: Callable[[jax.Array], Any]
    apply: Callable[[Any, jax.Array], jax.Array]  # (params, x) -> logits


def mlp_classifier(dims: Sequence[int]) -> SmallModel:
    """dims = (in, hidden..., n_classes)."""

    def init(rng):
        ks = jax.random.split(rng, len(dims) - 1)
        return [
            {
                "w": dense_init(ks[i], (dims[i], dims[i + 1])),
                "b": jnp.zeros((dims[i + 1],), jnp.float32),
            }
            for i in range(len(dims) - 1)
        ]

    def apply(params, x):
        h = x
        for i, layer in enumerate(params):
            h = h @ layer["w"] + layer["b"]
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        return h

    return SmallModel(init, apply)


def _group_norm(x, scale, bias, groups: int, eps: float = 1e-5):
    """x: (B, H, W, C) NHWC."""
    B, H, W, C = x.shape
    g = x.reshape(B, H, W, groups, C // groups).astype(jnp.float32)
    mean = g.mean(axis=(1, 2, 4), keepdims=True)
    var = g.var(axis=(1, 2, 4), keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    out = g.reshape(B, H, W, C) * scale + bias
    return out.astype(x.dtype)


def cnn_classifier(
    channels: Sequence[int] = (32, 64),
    n_classes: int = 10,
    in_channels: int = 3,
    gn_groups: int = 8,
    hw: int = 8,
) -> SmallModel:
    """Conv(3x3)→GN→ReLU ×len(channels) with stride-2 downsampling, then FC.

    GroupNorm instead of BatchNorm per the paper (Hsieh+20 BN pathology in
    federated settings).
    """

    def init(rng):
        ks = jax.random.split(rng, len(channels) + 1)
        params: Dict[str, Any] = {}
        c_in = in_channels
        for i, c_out in enumerate(channels):
            fan_in = 3 * 3 * c_in
            params[f"conv{i}"] = {
                "w": dense_init(ks[i], (3, 3, c_in, c_out), in_axis_size=fan_in),
                "gn_scale": jnp.ones((c_out,), jnp.float32),
                "gn_bias": jnp.zeros((c_out,), jnp.float32),
            }
            c_in = c_out
        final_hw = hw // (2 ** len(channels))
        flat = max(final_hw, 1) ** 2 * c_in
        params["fc"] = {
            "w": dense_init(ks[-1], (flat, n_classes)),
            "b": jnp.zeros((n_classes,), jnp.float32),
        }
        return params

    def apply(params, x):
        h = x  # NHWC
        for i in range(len(channels)):
            p = params[f"conv{i}"]
            h = jax.lax.conv_general_dilated(
                h, p["w"], window_strides=(2, 2), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            h = _group_norm(h, p["gn_scale"], p["gn_bias"], gn_groups)
            h = jax.nn.relu(h)
        h = h.reshape(h.shape[0], -1)
        return h @ params["fc"]["w"] + params["fc"]["b"]

    return SmallModel(init, apply)


def classification_loss(apply_fn) -> Callable[[Any, Dict[str, jax.Array]], jax.Array]:
    """Mean softmax cross-entropy; batch = {"x": (B, …), "y": (B,) int}."""

    def loss(params, batch):
        logits = apply_fn(params, batch["x"]).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    return loss
