"""Layer zoo shared by every architecture family.

Pure functions over param pytrees.  Conventions:

* activations (B, S, D); attention heads (B, S, H, hd)
* norms and softmax accumulate in f32 regardless of activation dtype
* every layer takes ``parallel`` (a ParallelContext or None); with a mesh it
  applies sharding constraints / shard_map, otherwise it is plain jnp.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.compat import shard_map

# ----------------------------------------------------------------------
# parallel context
# ----------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelContext:
    """Mesh + logical axis names.  ``data_axes`` may be ("pod","data")."""

    mesh: Any
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]


def shard(x, spec: Optional[P], parallel: Optional[ParallelContext]):
    if parallel is None or spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(parallel.mesh, spec))


# ----------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------


def dense_init(rng, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Scaled normal init: std = 1/sqrt(fan_in)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (std * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return (0.02 * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def init_rmsnorm(d: int, dtype=jnp.float32):
    return jnp.zeros((d,), dtype=dtype)  # stored as (scale - 1)


# ----------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (hd/2,)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (S,) or (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    pos = positions.astype(jnp.float32)
    angles = pos[..., None] * freqs  # (S, hd/2) or (B, S, hd/2)
    if angles.ndim == 2:  # (S, hd/2) -> (1, S, 1, hd/2)
        angles = angles[None, :, None, :]
    else:  # (B, S, hd/2) -> (B, S, 1, hd/2)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), in_axis_size=D, dtype=dtype),
        "wk": dense_init(ks[1], (D, Hkv, hd), in_axis_size=D, dtype=dtype),
        "wv": dense_init(ks[2], (D, Hkv, hd), in_axis_size=D, dtype=dtype),
        "wo": dense_init(ks[3], (H, hd, D), in_axis_size=H * hd, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _gqa_scores(q, k):
    """q: (B,Sq,H,hd)  k: (B,Skv,Hkv,hd) -> (B,H,Sq,Skv) with GQA grouping.

    The dot runs in the INPUT dtype and upcasts after: with
    ``preferred_element_type=f32`` GSPMD materializes an f32 copy of the
    whole (sequence-sharded) K cache and gathers it per decode layer
    (measured 104 GB/step on zamba2 decode_32k — §Perf-B iter 5).  bf16
    MXU accumulation is f32 internally on TPU, so accuracy is unchanged;
    the explicit upcast happens on the small scores tensor instead."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    return s.reshape(B, Hkv * G, Sq, k.shape[1])


def _gqa_combine(probs, v):
    """probs: (B,H,Sq,Skv)  v: (B,Skv,Hkv,hd) -> (B,Sq,H,hd)."""
    B, H, Sq, Skv = probs.shape
    Hkv = v.shape[2]
    G = H // Hkv
    pg = probs.reshape(B, Hkv, G, Sq, Skv)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pg, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def attend_direct(q, k, v, mask, scale: float):
    """Reference attention.  mask: broadcastable to (B,H,Sq,Skv), True=keep."""
    s = _gqa_scores(q, k) * scale
    s = jnp.where(mask, s, jnp.float32(-1e30))
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return _gqa_combine(p, v)


def attend_blocked(q, k, v, *, causal: bool, window: Optional[int], scale: float,
                   q_positions, kv_positions, q_block: int = 512, kv_block: int = 1024,
                   causal_skip: bool = False):
    """Blocked online-softmax attention in pure jnp (the flash ref).

    Scans over q blocks; for each q block scans kv blocks with running
    (max, sum, acc).  Memory is O(q_block * kv_block) per step instead of
    O(Sq*Skv).

    * ``window`` (static int): each q block only visits a dynamic slice of
      K/V of static length window+q_block → true sub-quadratic FLOPs for
      sliding-window layers (starcoder2, gemma3 local).
    * ``causal_skip``: unroll the q-block loop in python so q block i only
      scans kv blocks [0, i] — halves causal-attention FLOPs at the cost of
      a bigger HLO (off by default; a §Perf hillclimb lever).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    nq = -(-Sq // q_block)
    q_pad = nq * q_block - Sq
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, q_pad), constant_values=-1)
    qb = qp.reshape(B, nq, q_block, H, hd)
    qposb = qpos.reshape(nq, q_block)

    def kv_inner(qblk, qpos_blk, kb, vb, kposb):
        """Online softmax of one q block over a stack of kv blocks.

        kb/vb: (n, kv_block, Hkv, hd); kposb: (n, kv_block)."""

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kpos_blk = ki
            s = _gqa_scores(qblk, kblk) * scale  # (B,H,qb,kvb) f32
            msk = jnp.ones((q_block, kv_block), dtype=bool)
            if causal:
                msk &= qpos_blk[:, None] >= kpos_blk[None, :]
            if window is not None:
                msk &= qpos_blk[:, None] - kpos_blk[None, :] < window
            msk &= (qpos_blk[:, None] >= 0) & (kpos_blk[None, :] < 2**30)
            s = jnp.where(msk[None, None], s, jnp.float32(-1e30))
            m_new = jnp.maximum(m, s.max(axis=-1))  # (B,H,qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = _gqa_combine(p.astype(qblk.dtype), vblk)  # (B,qb,H,hd)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, q_block, H, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kposb))
        lT = l.transpose(0, 2, 1)[..., None]  # (B,qb,H,1)
        return (acc / jnp.maximum(lT, 1e-30)).astype(qblk.dtype)

    if window is not None and causal:
        # --- sliding window: static-length kv slice per q block ---
        w_up = -(-window // kv_block) * kv_block
        span = w_up + q_block  # static slice length
        n_in = span // kv_block if span % kv_block == 0 else -(-span // kv_block)
        span = n_in * kv_block
        kv_pad_lo = w_up  # so the first q block's slice is in range
        kp = jnp.pad(k, ((0, 0), (kv_pad_lo, q_pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (kv_pad_lo, q_pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kv_positions, (kv_pad_lo, q_pad), constant_values=2**30)
        kpos = kpos.at[:kv_pad_lo].set(2**30)
        starts = jnp.arange(nq) * q_block  # slice start in padded coords

        def q_step(_, qi):
            qblk, qpos_blk, st = qi
            kslc = jax.lax.dynamic_slice_in_dim(kp, st, span, axis=1)
            vslc = jax.lax.dynamic_slice_in_dim(vp, st, span, axis=1)
            pslc = jax.lax.dynamic_slice_in_dim(kpos, st, span, axis=0)
            kb = jnp.moveaxis(kslc.reshape(B, n_in, kv_block, *kslc.shape[2:]), 1, 0)
            vb = jnp.moveaxis(vslc.reshape(B, n_in, kv_block, *vslc.shape[2:]), 1, 0)
            pb = pslc.reshape(n_in, kv_block)
            return None, kv_inner(qblk, qpos_blk, kb, vb, pb)

        _, outs = jax.lax.scan(q_step, None, (qb.swapaxes(0, 1), qposb, starts))
        out = outs.swapaxes(0, 1).reshape(B, nq * q_block, H, hd)
        return out[:, :Sq]

    nkv = -(-Skv // kv_block)
    kv_pad = nkv * kv_block - Skv
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    kpos = jnp.pad(kv_positions, (0, kv_pad), constant_values=2**30)
    kb_all = kp.reshape(B, nkv, kv_block, *kp.shape[2:])
    vb_all = vp.reshape(B, nkv, kv_block, *vp.shape[2:])
    kposb = kpos.reshape(nkv, kv_block)

    if causal and causal_skip:
        # python-unrolled q loop; q block i visits kv blocks [0, i_kv]
        outs = []
        kv_per_q = q_block // kv_block if q_block >= kv_block else 1
        for i in range(nq):
            hi = min(nkv, max(1, (i + 1) * q_block // kv_block + (1 if q_block % kv_block else 0)))
            outs.append(
                kv_inner(
                    qb[:, i], qposb[i],
                    jnp.moveaxis(kb_all[:, :hi], 1, 0),
                    jnp.moveaxis(vb_all[:, :hi], 1, 0),
                    kposb[:hi],
                )
            )
        out = jnp.stack(outs, axis=1).reshape(B, nq * q_block, H, hd)
        return out[:, :Sq]

    def q_step(_, qi):
        qblk, qpos_blk = qi
        return None, kv_inner(
            qblk, qpos_blk,
            jnp.moveaxis(kb_all, 1, 0), jnp.moveaxis(vb_all, 1, 0), kposb,
        )

    _, outs = jax.lax.scan(q_step, None, (qb.swapaxes(0, 1), qposb))
    out = outs.swapaxes(0, 1).reshape(B, nq * q_block, H, hd)
    return out[:, :Sq]


BLOCKED_ATTENTION_THRESHOLD = 4096


def self_attention(
    params: Dict[str, Any],
    x,
    *,
    cfg: ModelConfig,
    positions,  # (S,) int32 absolute positions of x's tokens
    is_global,  # python bool or traced bool: full attention vs sliding window
    cache: Optional[Dict[str, Any]] = None,  # {"k","v"}: (B, S_max, Hkv, hd)
    cache_pos: Optional[jax.Array] = None,  # scalar: write index for decode
    parallel: Optional[ParallelContext] = None,
    kv_spec: Optional[P] = None,
    use_flash: bool = True,
    return_kv: bool = False,  # prefill: emit this segment's K/V as a cache
    use_kernel: bool = False,  # Pallas flash kernel instead of the jnp path
):
    """Returns (out, new_cache).  Decode mode iff cache is not None."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if parallel is not None and cache is None:
        # train/prefill: shard heads over "model" (TP attention).  In DECODE
        # the cache is sequence-sharded over "model"; head-sharding q forces
        # GSPMD to all-gather the whole KV cache per layer to reconcile the
        # layouts (measured 104 GB/step on zamba2 decode_32k — §Perf-B
        # iter 4).  Leaving q replicated lets attention compute
        # sequence-parallel partials per S-shard (flash-decoding style) with
        # only a small psum to combine.
        hspec = P(parallel.data_axes, None, parallel.model_axis, None)
        q = shard(q, hspec, parallel)

    window = cfg.sliding_window if not _static_true(is_global) else None

    if cache is None:
        # ---- train/prefill: full self attention over x itself ----
        if use_kernel:
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(q, k, v, causal=True, window=window, scale=scale)
        elif use_flash and S >= BLOCKED_ATTENTION_THRESHOLD:
            out = attend_blocked(
                q, k, v, causal=True, window=window, scale=scale,
                q_positions=positions, kv_positions=positions,
            )
        else:
            msk = positions[:, None] >= positions[None, :]
            if window is not None:
                msk &= positions[:, None] - positions[None, :] < window
            out = attend_direct(q, k, v, msk[None, None], scale)
        new_cache = {"k": shard(k, kv_spec, parallel), "v": shard(v, kv_spec, parallel)} if return_kv else None
    else:
        # ---- decode: write this token's k/v into the cache, attend over it --
        S_max = cache["k"].shape[1]
        kc = _cache_write(cache["k"], k, cache_pos, kv_spec, parallel)
        vc = _cache_write(cache["v"], v, cache_pos, kv_spec, parallel)
        kv_positions = jnp.arange(S_max, dtype=jnp.int32)
        valid = kv_positions <= cache_pos
        if window is not None:
            valid &= kv_positions > cache_pos - window
        out = attend_direct(q, kc, vc, valid[None, None, None, :], scale)
        new_cache = {"k": kc, "v": vc}

    o = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if parallel is not None:
        o = shard(o, P(parallel.data_axes, None, None), parallel)
    return o, new_cache


def _cache_write(cache, kv_new, pos, kv_spec, parallel):
    """Write one token (B, 1, Hkv, hd) into cache (B, S, Hkv, hd) at ``pos``.

    dynamic_update_slice at a traced position on a sequence-sharded cache
    makes GSPMD gather/reshard the cache (≈104 GB/step measured on zamba2
    decode_32k, §Perf hillclimb B).  A one-hot ``where``-blend was tried and
    measured WORSE (2.05e11 B gathered — GSPMD replicated the ``where``
    output despite the trailing constraint).  The deployable fix is a
    shard_map-local cache update (each shard compares pos against its own
    slab and writes locally) — implemented below behind
    ``set_cache_write_mode("shardmap")``; DUS stays the default because the
    mode is selected per deployment (EXPERIMENTS.md §Perf-B iter 3).
    """
    if CACHE_WRITE_MODE == "shardmap" and parallel is not None and kv_spec is not None:
        return _cache_write_shardmap(cache, kv_new, pos, kv_spec, parallel)
    upd = jax.lax.dynamic_update_slice(
        cache, kv_new.astype(cache.dtype), (0, pos.astype(jnp.int32), 0, 0)
    )
    return shard(upd, kv_spec, parallel)


CACHE_WRITE_MODE = "dus"  # "dus" | "shardmap" (§Perf-B iter 3)


def set_cache_write_mode(mode: str) -> None:
    global CACHE_WRITE_MODE
    assert mode in ("dus", "shardmap")
    CACHE_WRITE_MODE = mode


def _cache_write_shardmap(cache, kv_new, pos, kv_spec, parallel):
    """Shard-local cache write: each shard compares ``pos`` against its own
    sequence slab and blends locally — zero cross-shard traffic by
    construction (vs GSPMD's gather-update-reshard of a sharded-dim DUS)."""
    mesh = parallel.mesh
    seq_entry = kv_spec[1]  # (B, S, Hkv, hd) → S sharding axes
    seq_axes = seq_entry if isinstance(seq_entry, tuple) else (seq_entry,)
    seq_axes = tuple(a for a in seq_axes if a is not None)
    if not seq_axes:  # sequence unsharded: DUS is already shard-local
        upd = jax.lax.dynamic_update_slice(
            cache, kv_new.astype(cache.dtype), (0, pos.astype(jnp.int32), 0, 0)
        )
        return shard(upd, kv_spec, parallel)
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    S_local = cache.shape[1] // n_shards
    kv_in_spec = P(kv_spec[0], None, None, None)

    def body(c_loc, kv_loc, pos_s):
        # flat shard index along the (possibly compound) sequence axes
        idx = 0
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        start = idx * S_local
        local = pos_s.astype(jnp.int32) - start
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, S_local, 1, 1), 1)
        mask = iota == local  # off-shard ⇒ never equal ⇒ no-op
        return jnp.where(mask, kv_loc.astype(c_loc.dtype), c_loc)

    return shard_map(
        body, mesh=mesh,
        in_specs=(kv_spec, kv_in_spec, P()),
        out_specs=kv_spec,
        check_vma=False,
    )(cache, kv_new, pos)


def _static_true(b) -> bool:
    """True iff ``b`` is a static python truth (global attention layer)."""
    return isinstance(b, bool) and b


# ----------------------------------------------------------------------
# cross attention (encoder-decoder)
# ----------------------------------------------------------------------


def cross_attention(params, x, enc_kv, *, cfg: ModelConfig, parallel=None):
    """x: (B, Sq, D) queries; enc_kv: {"k","v"}: (B, S_src, Hkv, hd).

    Long sources use the blocked online-softmax path — a direct (Sq, S_src)
    score matrix at 4k×4k dominated the enc-dec train-step peak memory."""
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    S_src = enc_kv["k"].shape[1]
    Sq = x.shape[1]
    k = enc_kv["k"].astype(x.dtype)
    v = enc_kv["v"].astype(x.dtype)
    if max(Sq, S_src) >= BLOCKED_ATTENTION_THRESHOLD:
        out = attend_blocked(
            q, k, v, causal=False, window=None, scale=scale,
            q_positions=jnp.arange(Sq, dtype=jnp.int32),
            kv_positions=jnp.arange(S_src, dtype=jnp.int32),
        )
    else:
        msk = jnp.ones((1, 1, Sq, S_src), dtype=bool)
        out = attend_direct(q, k, v, msk, scale)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def encode_kv(params, enc_out, *, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (done once)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    return {"k": k, "v": v}


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=jnp.float32):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.mlp_type == "gated_silu":
        return {
            "w_gate": dense_init(ks[0], (D, F), dtype=dtype),
            "w_up": dense_init(ks[1], (D, F), dtype=dtype),
            "w_down": dense_init(ks[2], (F, D), in_axis_size=F, dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (D, F), dtype=dtype),
        "w_down": dense_init(ks[1], (F, D), in_axis_size=F, dtype=dtype),
    }


def mlp(params, x, *, cfg: ModelConfig, parallel: Optional[ParallelContext] = None):
    if cfg.mlp_type == "gated_silu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype)))
    if parallel is not None:
        h = shard(h, P(parallel.data_axes, None, parallel.model_axis), parallel)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    if parallel is not None:
        out = shard(out, P(parallel.data_axes, None, None), parallel)
    return out


# ----------------------------------------------------------------------
# Mixture of Experts
# ----------------------------------------------------------------------


def init_moe(rng, cfg: ModelConfig, dtype=jnp.float32):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),  # router kept f32
        "w_gate": dense_init(ks[1], (E, D, F), in_axis_size=D, dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), in_axis_size=D, dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), in_axis_size=F, dtype=dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg, dtype=dtype)
    return p


def _router(params, x, cfg: ModelConfig):
    """Returns (gates (T,k), experts (T,k), probs (T,E), aux_loss scalar)."""
    T = x.shape[0]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)  # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # aux losses: load-balance (Switch) + router z-loss
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32)
    ce = ce.at[experts.reshape(-1)].add(1.0) / (T * cfg.top_k)
    lb = cfg.n_experts * jnp.sum(me * ce) * cfg.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_loss
    return gates, experts, probs, lb + z


def moe_ref(params, x, *, cfg: ModelConfig):
    """Dense reference MoE: every expert computed on every token, masked.

    O(T*E*D*F) — only for reduced configs / oracles.  Returns (out, aux).
    """
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    gates, experts, _, aux = _router(params, xt, cfg)
    # combine weight per expert per token: (T, E)
    comb = jnp.zeros((xt.shape[0], cfg.n_experts), x.dtype)
    comb = comb.at[jnp.arange(xt.shape[0])[:, None], experts].add(gates.astype(x.dtype))

    def one_expert(wg, wu, wd):
        h = jax.nn.silu(xt @ wg.astype(x.dtype)) * (xt @ wu.astype(x.dtype))
        return h @ wd.astype(x.dtype)  # (T, D)

    outs = jax.vmap(one_expert)(params["w_gate"], params["w_up"], params["w_down"])  # (E,T,D)
    out = jnp.einsum("te,etd->td", comb, outs)
    if cfg.shared_expert:
        out = out + mlp(params["shared"], x, cfg=cfg).reshape(-1, D)
    return out.reshape(B, S, D), aux


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_dropping(params, x, *, cfg: ModelConfig, parallel: Optional[ParallelContext] = None):
    """Capacity-based scatter/gather MoE (token-dropping, GShard-style slots,
    but WITHOUT the (T,E,C) one-hot dispatch tensor — slots are computed with
    a (T*k, E) cumsum and a scatter-add, which is what keeps dbrx-scale
    (E=16, top-4) feasible).

    With a mesh, runs under shard_map: tokens stay on their (pod,data) shard,
    experts are sharded over the model axis; each model shard computes its
    experts for the local tokens and the partial outputs are psum'd over the
    model axis (one (T_local, D) all-reduce per MoE layer — the same volume
    as a tensor-parallel MLP).
    """
    B, S, D = x.shape

    if parallel is None:
        out, aux = _moe_local(params, x.reshape(-1, D), cfg=cfg, e_lo=0)
        out = out.reshape(B, S, D)
        if cfg.shared_expert:
            out = out + mlp(params["shared"], x, cfg=cfg)
        return out, aux

    mesh = parallel.mesh
    maxis = parallel.model_axis
    msize = parallel.model_size
    e_per = cfg.n_experts // msize
    assert e_per * msize == cfg.n_experts, (
        f"n_experts={cfg.n_experts} must divide model axis {msize}"
    )

    def body(xl, router, wg, wu, wd):
        # xl: (B_l, S, D) local tokens; wg/wu/wd: (E_l, ...) local experts
        j = jax.lax.axis_index(maxis)
        xt = xl.reshape(-1, D)
        p_local = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        out, aux = _moe_local(p_local, xt, cfg=cfg, e_lo=j * e_per)
        out = jax.lax.psum(out, maxis)
        aux = jax.lax.psum(aux, maxis) / msize
        return out.reshape(xl.shape), aux

    specs_in = (
        P(parallel.data_axes, None, None),  # x
        P(None, None),  # router replicated
        P(maxis, None, None),
        P(maxis, None, None),
        P(maxis, None, None),
    )
    specs_out = (P(parallel.data_axes, None, None), P())
    out, aux = shard_map(
        body, mesh=mesh, in_specs=specs_in, out_specs=specs_out, check_vma=False
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    if cfg.shared_expert:
        out = out + mlp(params["shared"], x, cfg=cfg, parallel=parallel)
    return out, aux


def _moe_local(params, xt, *, cfg: ModelConfig, e_lo):
    """Tokens xt (T, D) through E_local experts starting at ``e_lo`` (may be
    a traced axis_index) with capacity slots.

    params["w_*"] hold exactly E_local experts (static, from the leaf
    shape).  Routing decisions are computed over ALL E experts (router is
    replicated); only choices landing in [e_lo, e_lo + E_local) run here.
    """
    T, D = xt.shape
    E_local = params["w_gate"].shape[0]
    e_hi = e_lo + E_local
    C = moe_capacity(cfg, T)
    gates, experts, _, aux = _router(params, xt, cfg)  # (T,k)

    flat_e = experts.reshape(-1)  # (T*k,)
    # position of each (token, choice) within its expert's queue — global
    # over all E so capacity semantics match the unsharded reference
    onehot = jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # (T*k, E)
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)

    local = (flat_e >= e_lo) & (flat_e < e_hi) & (pos < C)
    slot = jnp.where(local, (flat_e - e_lo) * C + pos, E_local * C)  # drop slot at end
    buf = jnp.zeros((E_local * C + 1, D), xt.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), cfg.top_k)
    buf = buf.at[slot].add(xt[tok_idx])
    buf = buf[:-1].reshape(E_local, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(xt.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(xt.dtype))
    h = jax.nn.silu(h) * u
    eout = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xt.dtype))
    eout = eout.reshape(E_local * C, D)
    eout = jnp.concatenate([eout, jnp.zeros((1, D), xt.dtype)], axis=0)

    gathered = eout[slot] * gates.reshape(-1)[:, None].astype(xt.dtype)  # (T*k, D)
    out = jnp.zeros((T, D), xt.dtype).at[tok_idx].add(gathered)
    return out, aux
