"""Append-only, fsync-atomic JSONL telemetry store — one row per round.

Production FL is a *service*: operators watch per-round health live
(rounds/s, cohort size, the PR-7 fault counters, eval curves at cadence,
checkpoint publications) rather than reading a benchmark artifact after
the fact.  This store is the machine-checkable record of that stream.

Layout (one JSON object per line):

  line 0   header   {"schema": TELEMETRY_SCHEMA, "kind": "fleet-telemetry",
                     "created_unix": ..., "meta": {...}}
  line 1+  rows     {"event": "round", "round": 3, ...}        (per round)
                    {"event": "publish", "version": 2, ...}    (per publish)
                    {"event": "health_probe", "status": 200, ...}
                    {"event": "serve_summary", "swaps": 3, ...} (at stop)

Durability contract: every ``append`` is ``write + flush + fsync`` of one
``\\n``-terminated line on an ``O_APPEND`` descriptor, so a kill at ANY
point leaves at most one torn final line.  ``replay`` tolerates exactly
that — a non-parsing or unterminated final line is dropped and reported
via ``truncated=True``, never raised — which is what makes the file a
valid resume/CI artifact after a preemption (same contract as ckpt.py's
tmp+fsync+rename, adapted to an append-only stream).

Schema versioning: readers MUST check ``header["schema"]``; bumping
``TELEMETRY_SCHEMA`` is the signal that row fields changed meaning.  The
per-round field set is exported as ``ROUND_FIELDS`` / ``FAULT_COUNTERS``
so the ``fed_train --dryrun`` artifact and tests can assert the rows a
run will emit without running it (telemetry and --dryrun must agree).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

#: bump on any change to row field semantics; replay() refuses mismatches
TELEMETRY_SCHEMA = 1

#: the PR-7 RoundMetrics degradation counters a round row carries —
#: surfaced off-device exactly once per fused chunk (REP003: no per-round
#: host syncs).  Kept in one place so fed_train's dryrun artifact, the
#: driver's rows, and the tests name the same set.
FAULT_COUNTERS: Tuple[str, ...] = (
    "n_clipped", "n_dropped", "n_quarantined", "n_retries", "quorum_skipped",
)

#: full per-round row schema (event == "round").  ``eval_acc`` is null on
#: off-cadence rounds; ``published_version`` is null on rounds without a
#: checkpoint publication.  ``uplink_bytes`` is the round's TOTAL uplink
#: payload in bytes (active clients × per-client wire bytes) AFTER wire
#: compression (repro.core.compress) — the operator-visible record that a
#: compression config actually shrank the wire.  Additive: rows stay
#: schema-1 (existing readers key by name).
ROUND_FIELDS: Tuple[str, ...] = (
    "event", "round", "t_unix", "rounds_per_s", "cohort", "loss",
    "eval_acc", "published_version", "uplink_bytes",
) + FAULT_COUNTERS


class TelemetryStore:
    """Writer half.  Create (or resume) a JSONL stream and append rows.

    ``resume=True`` appends to an existing file after validating its
    header (schema mismatch raises); otherwise an existing file is
    truncated and a fresh header written.  ``tail(n)`` returns the last
    ``n`` rows appended by THIS process (in-memory ring; the health
    endpoint serves it without touching the file)."""

    def __init__(self, path: str, *, meta: Optional[Dict[str, Any]] = None,
                 resume: bool = False, tail_size: int = 256) -> None:
        self.path = str(path)
        self._tail: deque = deque(maxlen=tail_size)
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        if resume and os.path.exists(self.path):
            header, _, _ = replay(self.path)
            self.header = header
            self._fd = os.open(self.path, os.O_WRONLY | os.O_APPEND)
        else:
            self.header = {
                "schema": TELEMETRY_SCHEMA,
                "kind": "fleet-telemetry",
                "created_unix": time.time(),
                "meta": dict(meta or {}),
            }
            self._fd = os.open(
                self.path, os.O_WRONLY | os.O_CREAT | os.O_TRUNC | os.O_APPEND,
                0o644,
            )
            self._write_line(self.header)

    def _write_line(self, obj: Dict[str, Any]) -> None:
        data = (json.dumps(obj, separators=(",", ":"),
                           allow_nan=False) + "\n").encode()
        os.write(self._fd, data)
        os.fsync(self._fd)

    def append(self, row: Dict[str, Any]) -> None:
        """Append one row durably (write+fsync of a single line)."""
        if self._fd is None:
            raise ValueError("telemetry store is closed")
        self._write_line(row)
        self._tail.append(row)

    def round_row(self, **kw: Any) -> Dict[str, Any]:
        """Build + append a schema-complete per-round row: every field in
        ``ROUND_FIELDS`` present (missing → None), unknown kwargs refused
        so the row schema cannot silently drift from the exported one."""
        unknown = set(kw) - set(ROUND_FIELDS)
        if unknown:
            raise ValueError(f"unknown round-row fields: {sorted(unknown)}")
        row = {f: kw.get(f) for f in ROUND_FIELDS}
        row["event"] = "round"
        if row.get("t_unix") is None:
            row["t_unix"] = time.time()
        self.append(row)
        return row

    def event(self, kind: str, **kw: Any) -> Dict[str, Any]:
        row = {"event": kind, "t_unix": time.time(), **kw}
        self.append(row)
        return row

    def tail(self, n: int = 32) -> List[Dict[str, Any]]:
        return list(self._tail)[-max(0, int(n)):]

    def close(self) -> None:
        if self._fd is not None:
            os.fsync(self._fd)
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "TelemetryStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def replay(path: str) -> Tuple[Dict[str, Any], List[Dict[str, Any]], bool]:
    """Read a telemetry stream back → ``(header, rows, truncated)``.

    Tolerant of exactly the failure the writer can leave behind: a torn
    FINAL line (unterminated, or terminated-but-unparseable after a torn
    write raced a kill) is dropped and reported as ``truncated=True``.  A
    torn line anywhere ELSE — or a header with the wrong schema/kind —
    is corruption, not preemption, and raises."""
    with open(path, "rb") as f:
        raw = f.read()
    lines = raw.split(b"\n")
    # a well-formed file ends "…}\n" → final split element is empty; a
    # torn final line shows up as a non-empty last element
    unterminated = lines and lines[-1] != b""
    if lines and lines[-1] == b"":
        lines = lines[:-1]
    if not lines:
        raise ValueError(f"{path}: empty telemetry file (no header)")
    parsed: List[Dict[str, Any]] = []
    truncated = False
    for i, line in enumerate(lines):
        is_last = i == len(lines) - 1
        try:
            parsed.append(json.loads(line))
        except (json.JSONDecodeError, UnicodeDecodeError):
            if is_last:
                truncated = True
                break
            raise ValueError(
                f"{path}: corrupt (non-final) telemetry line {i}"
            ) from None
        if is_last and unterminated:
            # parsed but never fsync-terminated: the durability contract
            # only covers complete lines — treat it as torn
            parsed.pop()
            truncated = True
    if not parsed:
        raise ValueError(f"{path}: header line is torn — nothing to replay")
    header, rows = parsed[0], parsed[1:]
    if header.get("kind") != "fleet-telemetry":
        raise ValueError(f"{path}: not a fleet telemetry stream: {header!r}")
    if header.get("schema") != TELEMETRY_SCHEMA:
        raise ValueError(
            f"{path}: telemetry schema {header.get('schema')!r} != "
            f"reader schema {TELEMETRY_SCHEMA}"
        )
    return header, rows, truncated


def round_rows(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """The per-round subset of a replayed stream, in append order."""
    return [r for r in rows if r.get("event") == "round"]


def events(rows: List[Dict[str, Any]], kind: str) -> List[Dict[str, Any]]:
    return [r for r in rows if r.get("event") == kind]
