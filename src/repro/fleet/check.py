"""Replay-based telemetry checker — the fleet CI smoke's assertion half.

``fed_train --serve`` leaves a telemetry JSONL behind; this CLI replays
it (through the same ``replay()`` reader operators would use) and asserts
the invariants the subsystem promises:

  * header schema matches, stream replays (torn final line tolerated),
  * >= ``--min-rounds`` round rows with strictly increasing round index,
  * published versions strictly monotone,
  * >= ``--min-swaps`` hot-swaps taken WHILE DECODE WAS ACTIVE
    (``serve_summary.swaps_mid_session`` — a swap at step>0 of a serving
    session, i.e. between two decode steps of a live session),
  * with ``--require-health``: the in-run /healthz self-probe returned
    200 with a last-round age inside the liveness deadline.

Exit 0 when everything holds, 1 with a named failure otherwise::

    PYTHONPATH=src python -m repro.fleet.check telemetry.jsonl \
        --min-rounds 6 --min-swaps 2 --require-health
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from repro.fleet.telemetry import events, replay, round_rows


def check(path: str, *, min_rounds: int = 1, min_swaps: int = 0,
          require_health: bool = False) -> List[str]:
    """Returns a list of human-readable failures (empty = pass)."""
    fails: List[str] = []
    try:
        header, rows, truncated = replay(path)
    except (OSError, ValueError) as e:
        return [f"replay failed: {e}"]
    rnds = round_rows(rows)
    if len(rnds) < min_rounds:
        fails.append(f"only {len(rnds)} round rows (need >= {min_rounds})")
    idx = [r["round"] for r in rnds]
    if any(b <= a for a, b in zip(idx, idx[1:])):
        fails.append(f"round indices not strictly increasing: {idx}")
    if any(r.get("rounds_per_s") is None or r["rounds_per_s"] <= 0
           for r in rnds):
        fails.append("round row missing a positive rounds_per_s")
    # wire accounting: every round must report what it actually shipped
    # per client — None or 0 means the engine's payload accounting broke
    # (a compression regression would also show up here as f32-sized rows)
    if any(r.get("uplink_bytes") is None or r["uplink_bytes"] <= 0
           for r in rnds):
        fails.append("round row missing positive uplink_bytes")
    pubs = [e["version"] for e in events(rows, "publish")]
    if any(b <= a for a, b in zip(pubs, pubs[1:])):
        fails.append(f"published versions not strictly monotone: {pubs}")
    summaries = events(rows, "serve_summary")
    if min_swaps > 0:
        if not summaries:
            fails.append("no serve_summary row (serving never ran?)")
        else:
            s = summaries[-1]
            live = s.get("swaps_mid_session", 0)
            if live < min_swaps:
                fails.append(
                    f"{live} hot-swaps under decode load "
                    f"(need >= {min_swaps}; total swaps: {s.get('swaps', 0)})"
                )
            versions = s.get("versions", [])
            if any(b <= a for a, b in zip(versions, versions[1:])):
                fails.append(f"served versions not strictly monotone: {versions}")
    if require_health:
        probes = events(rows, "health_probe")
        ok = [p for p in probes if p.get("status") == 200]
        if not ok:
            fails.append(
                f"no 200 health probe (probes: "
                f"{[p.get('status') for p in probes]})"
            )
        else:
            age = ok[-1].get("last_round_age_s")
            deadline = header.get("meta", {}).get("deadline_s")
            if age is None:
                fails.append("health probe carried no last-round age")
            elif deadline is not None and age >= deadline:
                fails.append(
                    f"health probe age {age}s is past the {deadline}s deadline"
                )
    if truncated:
        # informational, not a failure — a preempted run's artifact is
        # still valid up to its last complete row
        print(f"note: {path} ends in a torn final line (tolerated)",
              file=sys.stderr)
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--min-rounds", type=int, default=1)
    ap.add_argument("--min-swaps", type=int, default=0)
    ap.add_argument("--require-health", action="store_true")
    ap.add_argument("--json", default="",
                    help="also write a {passed, failures, n_rounds} report")
    args = ap.parse_args(argv)
    fails = check(args.path, min_rounds=args.min_rounds,
                  min_swaps=args.min_swaps,
                  require_health=args.require_health)
    if args.json:
        header, rows, _ = replay(args.path)
        with open(args.json, "w") as f:
            json.dump({"passed": not fails, "failures": fails,
                       "n_rounds": len(round_rows(rows)),
                       "rev": header.get("meta", {}).get("rev")}, f, indent=1)
    if fails:
        for msg in fails:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"ok: {args.path} replays clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
