"""Fleet subsystem: continuous rounds → serving, with telemetry + health.

The round-to-serving loop of the production FL service:

  * ``telemetry``  — append-only fsync-atomic JSONL store, one row/round
  * ``publisher``  — atomic versioned model publication + watch()
  * ``health``     — /healthz, /metrics, /telemetry/tail HTTP endpoint
  * ``driver``     — FleetDriver wiring it into ``fed_train --serve``
  * ``check``      — CLI asserting a replayed telemetry stream's invariants
"""
from repro.fleet.health import FleetStatus, HealthServer, probe
from repro.fleet.publisher import (
    ModelPublisher,
    ParamsWatch,
    load_published,
    read_pointer,
    watch,
)
from repro.fleet.telemetry import (
    FAULT_COUNTERS,
    ROUND_FIELDS,
    TELEMETRY_SCHEMA,
    TelemetryStore,
    events,
    replay,
    round_rows,
)

__all__ = [
    "FAULT_COUNTERS",
    "FleetStatus",
    "HealthServer",
    "ModelPublisher",
    "ParamsWatch",
    "ROUND_FIELDS",
    "TELEMETRY_SCHEMA",
    "TelemetryStore",
    "events",
    "load_published",
    "probe",
    "read_pointer",
    "replay",
    "round_rows",
    "watch",
]
