"""Operator-facing health endpoint for a running fleet (stdlib only).

A tiny ``http.server`` served from a daemon thread; three routes:

  ``/healthz``          round liveness: 200 when the last completed round
                        is younger than the deadline, 503 when the driver
                        has gone quiet (or no round finished yet).  JSON
                        body either way.
  ``/metrics``          Prometheus text exposition of the counters an
                        operator alerts on (fault counters, rounds/s,
                        served/published model versions, swap count).
  ``/telemetry/tail``   last N telemetry rows as JSON (``?n=K``, default
                        32) — served from the store's in-memory ring, no
                        file reads on the request path.

State flows one way: the driver (and the serving thread) push updates
into a ``FleetStatus`` under its lock; request handlers only ever read a
consistent snapshot.  Nothing here touches jax — the endpoint can never
perturb the traced round program (telemetry is observation-only).
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional, Tuple
from urllib.parse import parse_qs, urlparse


class FleetStatus:
    """Thread-safe mutable status snapshot shared driver ↔ endpoint."""

    #: counter fields exported to /metrics (monotone totals over the run)
    COUNTERS = ("n_clipped", "n_dropped", "n_quarantined", "n_retries",
                "quorum_skipped")

    def __init__(self, *, deadline_s: float = 120.0) -> None:
        self._lock = threading.Lock()
        self.started_unix = time.time()
        self.deadline_s = float(deadline_s)
        self.last_round: Optional[int] = None
        self.last_round_unix: Optional[float] = None
        self.rounds_total = 0
        self.rounds_per_s: Optional[float] = None
        self.cohort: Optional[int] = None
        self.counters: Dict[str, float] = {k: 0.0 for k in self.COUNTERS}
        self.published_version = 0
        self.served_version = 0
        self.swaps = 0
        self.serve_steps = 0
        self.eval_acc: Optional[float] = None
        #: last round's total uplink bytes (cohort × per-client wire
        #: bytes) AFTER wire compression — what a fleet operator alerts
        #: on when a compression config regresses (silently shipping f32)
        self.uplink_bytes: Optional[float] = None

    def update(self, **kw: Any) -> None:
        with self._lock:
            for k, v in kw.items():
                if not hasattr(self, k):
                    raise AttributeError(f"unknown status field {k!r}")
                setattr(self, k, v)

    def bump_counters(self, deltas: Dict[str, float]) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.counters[k] = self.counters.get(k, 0.0) + float(v)

    def round_done(self, rnd: int, **kw: Any) -> None:
        with self._lock:
            self.last_round = int(rnd)
            self.last_round_unix = time.time()
            self.rounds_total += 1
            for k, v in kw.items():
                setattr(self, k, v)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            now = time.time()
            age = (now - self.last_round_unix
                   if self.last_round_unix is not None else None)
            fresh = age is not None and age < self.deadline_s
            return {
                "status": "ok" if fresh else "stale",
                "uptime_s": round(now - self.started_unix, 3),
                "last_round": self.last_round,
                "last_round_age_s": round(age, 3) if age is not None else None,
                "round_deadline_s": self.deadline_s,
                "rounds_total": self.rounds_total,
                "rounds_per_s": self.rounds_per_s,
                "cohort": self.cohort,
                "eval_acc": self.eval_acc,
                "uplink_bytes": self.uplink_bytes,
                "counters": dict(self.counters),
                "published_version": self.published_version,
                "served_version": self.served_version,
                "swaps": self.swaps,
                "serve_steps": self.serve_steps,
            }


def _prometheus(snap: Dict[str, Any]) -> str:
    lines = []

    def emit(name: str, value, help_: str) -> None:
        if value is None:
            return
        lines.append(f"# HELP fleet_{name} {help_}")
        lines.append(f"# TYPE fleet_{name} gauge")
        lines.append(f"fleet_{name} {float(value)}")

    emit("up", 1.0 if snap["status"] == "ok" else 0.0,
         "1 when the last round is within the liveness deadline")
    emit("rounds_total", snap["rounds_total"], "completed training rounds")
    emit("last_round_age_seconds", snap["last_round_age_s"],
         "seconds since the last completed round")
    emit("rounds_per_second", snap["rounds_per_s"],
         "round throughput of the most recent fused chunk")
    emit("cohort_size", snap["cohort"], "active cohort of the last round")
    emit("eval_accuracy", snap["eval_acc"], "last cadence eval accuracy")
    emit("uplink_bytes", snap["uplink_bytes"],
         "total uplink payload bytes of the last round, after wire compression")
    for k, v in snap["counters"].items():
        emit(f"{k}_total", v, f"cumulative RoundMetrics.{k} over the run")
    emit("published_model_version", snap["published_version"],
         "latest version published to the serving ring")
    emit("served_model_version", snap["served_version"],
         "version the serving loop currently decodes against")
    emit("hot_swaps_total", snap["swaps"],
         "checkpoint hot-swaps taken by the serving loop")
    emit("serve_steps_total", snap["serve_steps"],
         "decode steps executed by the serving loop")
    return "\n".join(lines) + "\n"


class _Handler(BaseHTTPRequestHandler):
    # injected by make_health_server via type()
    status: FleetStatus
    tail_fn: Callable[[int], List[Dict[str, Any]]]

    def log_message(self, fmt, *args):  # silence per-request stderr spam
        pass

    def _send(self, code: int, body: str, ctype: str) -> None:
        data = body.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        url = urlparse(self.path)
        snap = self.status.snapshot()
        if url.path == "/healthz":
            code = 200 if snap["status"] == "ok" else 503
            self._send(code, json.dumps(snap, indent=1), "application/json")
        elif url.path == "/metrics":
            self._send(200, _prometheus(snap), "text/plain; version=0.0.4")
        elif url.path == "/telemetry/tail":
            q = parse_qs(url.query)
            try:
                n = int(q.get("n", ["32"])[0])
            except ValueError:
                self._send(400, '{"error": "n must be an integer"}',
                           "application/json")
                return
            self._send(200, json.dumps(self.tail_fn(n), indent=1),
                       "application/json")
        else:
            self._send(404, '{"error": "unknown route", "routes": '
                            '["/healthz", "/metrics", "/telemetry/tail"]}',
                       "application/json")


class HealthServer:
    """``ThreadingHTTPServer`` on a daemon thread; ``port=0`` binds an
    ephemeral port (read it back from ``.port`` — how tests and the
    driver's self-probe find the endpoint)."""

    def __init__(self, status: FleetStatus,
                 tail_fn: Optional[Callable[[int], List[Dict]]] = None,
                 *, host: str = "127.0.0.1", port: int = 0) -> None:
        handler = type("FleetHandler", (_Handler,), {
            "status": status, "tail_fn": tail_fn or (lambda n: []),
        })
        self._server = ThreadingHTTPServer((host, port), handler)
        self._server.daemon_threads = True
        self.host = host
        self.port = int(self._server.server_address[1])
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="fleet-health", daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5)


def probe(url: str, route: str = "/healthz",
          timeout: float = 5.0) -> Tuple[int, Dict[str, Any]]:
    """GET ``url + route`` → ``(http_status, parsed_body)``.  Accepts the
    503-stale response without raising (that IS the signal)."""
    import urllib.error
    import urllib.request

    try:
        with urllib.request.urlopen(url + route, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())
