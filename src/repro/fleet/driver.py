"""Fleet driver: glue between the fused training loop and the serving path.

``fed_train --serve`` owns the main thread (the fused-scan round chunks —
the compute); a ``FleetDriver`` owns everything around it:

  * the append-only telemetry store (one row per round, derived from the
    chunk's stacked ``RoundMetrics`` in ONE host transfer — REP003: no
    per-round host syncs are added to the training path),
  * atomic model publication (``ModelPublisher``: versioned payloads +
    LATEST pointer + bounded retention ring),
  * a serving thread running ``repro.launch.serve.serve_loop`` that
    continuously decodes against the latest published params, hot-swapping
    new versions at decode-step boundaries,
  * the operator health endpoint (``/healthz``, ``/metrics``,
    ``/telemetry/tail``) fed from a shared ``FleetStatus``.

Everything here is observation-only: the driver never touches FedState or
the engine's traced programs, so a ``--serve`` run's training trajectory
is bit-identical to the same run without ``--serve``.

Lifecycle::

    fleet = FleetDriver(ckpt_dir=..., meta={...})
    fleet.publish(0, state.params)              # version 1: the init params
    fleet.start_serving(model.apply, template=state.params, batch_x=xb)
    for each chunk:
        state, ms = engine.run_rounds(...)
        fleet.record_chunk(start_round=r0, ms=ms, seconds=dt, eval_acc=a)
        at ckpt boundaries: fleet.publish(r, state.params)
    summary = fleet.stop()                      # drains swaps, self-probes
                                                # /healthz, writes the
                                                # serve_summary row, closes
"""
from __future__ import annotations

import os
import subprocess
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import metrics_to_host
from repro.fleet.health import FleetStatus, HealthServer, probe
from repro.fleet.publisher import ModelPublisher, ParamsWatch
from repro.fleet.telemetry import FAULT_COUNTERS, TelemetryStore
from repro.launch.serve import serve_loop


def _git_rev() -> Optional[str]:
    """Best-effort short rev for telemetry header stamping (the BENCH
    trajectory fold uses it to refuse stale artifacts)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


class FleetDriver:
    """See module docstring.  All methods are called from the training
    (main) thread except the serving loop, which runs on its own daemon
    thread and shares only ``FleetStatus`` (locked) and the publisher
    directory (atomic pointer protocol) with it."""

    def __init__(
        self,
        *,
        ckpt_dir: str,
        telemetry_path: Optional[str] = None,
        publish_dir: Optional[str] = None,
        retain: int = 4,
        deadline_s: float = 120.0,
        health_port: int = 0,
        meta: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.ckpt_dir = str(ckpt_dir)
        self.publish_dir = publish_dir or os.path.join(self.ckpt_dir, "publish")
        self.telemetry = TelemetryStore(
            telemetry_path or os.path.join(self.ckpt_dir, "telemetry.jsonl"),
            meta={"rev": _git_rev(), "deadline_s": float(deadline_s),
                  **(meta or {})},
        )
        self.publisher = ModelPublisher(self.publish_dir, retain=retain)
        self.status = FleetStatus(deadline_s=deadline_s)
        self.health = HealthServer(self.status, self.telemetry.tail,
                                   port=health_port)
        self._stop = threading.Event()
        self._serve_thread: Optional[threading.Thread] = None
        self._serve_result: Dict[str, Any] = {}
        self._closed = False

    # ------------------------------------------------------------- publish
    def publish(self, step: int, params: Any) -> int:
        """Atomically publish ``params`` (a model pytree — NOT the whole
        FedState; the serving thread restores it through the model
        template) and record the publication."""
        version = self.publisher.publish(params, step=int(step))
        self.status.update(published_version=version)
        self.telemetry.event("publish", version=version, step=int(step))
        return version

    # ------------------------------------------------------------- serving
    def start_serving(
        self,
        apply_fn: Any,
        *,
        template: Any,
        batch_x: Any,
        steps_per_session: int = 256,
        step_sleep_s: float = 0.002,
        idle_sleep_s: float = 0.0,
    ) -> None:
        """Start the serving thread: continuous inference ("decode") steps
        of ``apply_fn`` on ``batch_x`` against the latest published
        params.  Requires at least one prior ``publish`` (the provider
        must have a complete version to serve — random init never serves).
        """
        if self._serve_thread is not None:
            raise RuntimeError("serving thread already started")
        watcher = ParamsWatch(self.publish_dir, template=template)
        got = watcher.poll()
        if got is None:
            raise FileNotFoundError(
                f"{self.publish_dir}: publish() the initial params before "
                "start_serving()"
            )
        version, params, _ = got
        self.status.update(served_version=version)
        x = jnp.asarray(batch_x)
        step = jax.jit(lambda p, xb: jnp.argmax(apply_fn(p, xb), axis=-1))

        def decode_step(p, st, i):
            return step(p, x)

        def end_session(p, st):
            # bound the dispatch queue: one sync per session, not per step
            jax.block_until_ready(st)

        def on_swap(v: int, stats) -> None:
            self.status.update(served_version=v, swaps=stats.swaps,
                               serve_steps=stats.steps)

        def run() -> None:
            final_params, stats = serve_loop(
                params, decode_step,
                end_session=end_session,
                params_provider=watcher,
                steps_per_session=int(steps_per_session),
                max_sessions=None,
                stop_event=self._stop,
                on_swap=on_swap,
                idle_sleep_s=float(idle_sleep_s),
                step_sleep_s=float(step_sleep_s),
                version=version,
            )
            self.status.update(served_version=stats.served_version,
                               swaps=stats.swaps, serve_steps=stats.steps)
            self._serve_result["stats"] = stats

        self._serve_thread = threading.Thread(
            target=run, name="fleet-serve", daemon=True
        )
        self._serve_thread.start()

    # ----------------------------------------------------------- telemetry
    def record_chunk(
        self,
        *,
        start_round: int,
        host: Optional[Dict[str, np.ndarray]] = None,
        ms: Any = None,
        seconds: float,
        eval_acc: Optional[float] = None,
        published_version: Optional[int] = None,
    ) -> Dict[str, np.ndarray]:
        """Fold one fused chunk's stacked ``RoundMetrics`` into telemetry,
        one row per round.  Pass ``host`` when the caller already fetched
        the chunk's metrics (``metrics_to_host`` — fed_train does, so its
        single per-chunk transfer is shared between its own logging and
        telemetry); else pass the device-side ``ms`` tuple and the one
        transfer happens here.  ``eval_acc`` (the chunk-end cadence eval)
        and ``published_version`` (the publication that followed the
        chunk, if any) attach to the chunk's LAST round.  Returns the
        host-side metrics dict."""
        if host is None:
            host = metrics_to_host(ms)
        n = len(host["loss"])
        rps = round(n / max(seconds, 1e-9), 4)
        for i in range(n):
            last = i == n - 1
            counters = {
                k: float(host[k][i]) for k in FAULT_COUNTERS if k in host
            }
            self.telemetry.round_row(
                round=start_round + i + 1,
                rounds_per_s=rps,
                cohort=int(host["n_active"][i]),
                loss=round(float(host["loss"][i]), 6),
                eval_acc=(round(float(eval_acc), 6)
                          if (last and eval_acc is not None) else None),
                published_version=published_version if last else None,
                uplink_bytes=(float(host["bytes_up"][i])
                              if "bytes_up" in host else None),
                **counters,
            )
        self.status.bump_counters({
            k: float(np.sum(host[k])) for k in FAULT_COUNTERS if k in host
        })
        self.status.round_done(
            start_round + n,
            rounds_per_s=rps,
            cohort=int(host["n_active"][-1]),
            eval_acc=(float(eval_acc) if eval_acc is not None
                      else self.status.eval_acc),
            uplink_bytes=(float(host["bytes_up"][-1])
                          if "bytes_up" in host else None),
        )
        return host

    # ------------------------------------------------------------ shutdown
    def drain_swaps(self, timeout_s: float = 10.0) -> bool:
        """Wait until the serving thread has swapped onto the newest
        published version (so a publish in the run's final chunk is
        observed under decode load before shutdown)."""
        target = self.publisher.version
        deadline = time.time() + timeout_s
        while time.time() < deadline:
            if self.status.snapshot()["served_version"] >= target:
                return True
            time.sleep(0.01)
        return False

    def stop(self) -> Dict[str, Any]:
        """Drain, stop serving, self-probe /healthz while the endpoint is
        live, write the ``serve_summary`` + ``health_probe`` telemetry
        rows, and close everything.  Returns the summary dict."""
        if self._closed:
            raise RuntimeError("fleet driver already stopped")
        drained = self.drain_swaps()
        stats = None
        if self._serve_thread is not None:
            self._stop.set()
            self._serve_thread.join(timeout=30)
            stats = self._serve_result.get("stats")
        summary: Dict[str, Any] = {"drained": drained}
        if stats is not None:
            summary.update(
                steps=stats.steps, sessions=stats.sessions,
                swaps=stats.swaps, swaps_mid_session=stats.swaps_mid_session,
                swap_steps=stats.swap_steps[:128],
                versions=stats.versions[:128],
                served_version=stats.served_version,
                t_active_s=round(stats.t_active_s, 3),
            )
        self.telemetry.event("serve_summary", **summary)
        code, body = probe(self.health.url)
        self.telemetry.event(
            "health_probe", status=code,
            last_round_age_s=body.get("last_round_age_s"),
            rounds_total=body.get("rounds_total"),
            served_version=body.get("served_version"),
        )
        summary["health_status"] = code
        summary["health"] = body
        self.telemetry.close()
        self.health.stop()
        self._closed = True
        return summary
