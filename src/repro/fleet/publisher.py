"""Atomic model publication: versioned payloads + a "latest" pointer.

Built on PR 7's atomicity primitive (``ckpt.save_checkpoint``'s
tmp + fsync + rename + dir-fsync): a publish writes the COMPLETE payload
file first, then atomically renames the ``LATEST.json`` pointer over the
old one.  A reader therefore either sees the previous complete version or
the new complete version — never a torn payload and never a pointer to a
half-written file.  Versions are strictly monotone.

Retention is a bounded ring: only the newest ``retain`` payload files are
kept (``retain >= 2`` enforced, so the version a reader just resolved
from the pointer survives at least one further publish).  A reader that
lags MORE than ``retain`` publishes behind can race a retention unlink —
``ParamsWatch.poll`` handles that by re-reading the pointer and loading
the (newer) version it now names, preserving monotonicity.

Layout::

    <dir>/step_<version>.msgpack   payload: {"params": <pytree>} (+ meta)
    <dir>/LATEST.json              {"schema", "version", "file", "step", ...}

``watch(dir)`` / ``ParamsWatch`` is the subscriber half the serving loop
polls between decode steps: ``poll()`` returns ``None`` while the
published version is unchanged, else ``(version, params, meta)`` for the
new one — the hot-swap seam of ``repro.launch.serve.serve_loop``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint import latest_step, load_checkpoint, load_flat, save_checkpoint

POINTER = "LATEST.json"
POINTER_SCHEMA = 1


def _fsync_dir(directory: str) -> None:
    try:
        dfd = os.open(directory, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass


def read_pointer(directory: str) -> Optional[Dict[str, Any]]:
    """The current ``LATEST.json`` contents, or None before any publish.
    The pointer is only ever replaced atomically, so a successful read is
    always a complete pointer."""
    try:
        with open(os.path.join(directory, POINTER), "rb") as f:
            return json.loads(f.read())
    except FileNotFoundError:
        return None


class ModelPublisher:
    """Writer half: ``publish(params, step=...)`` → new monotone version.

    Reopening an existing directory continues its version sequence (from
    the pointer, falling back to the newest payload file on disk)."""

    def __init__(self, directory: str, *, retain: int = 4) -> None:
        if retain < 2:
            raise ValueError(
                f"retain={retain}: the ring must keep >= 2 versions so a "
                "reader's just-resolved version survives the next publish"
            )
        self.directory = str(directory)
        self.retain = int(retain)
        os.makedirs(self.directory, exist_ok=True)
        ptr = read_pointer(self.directory)
        self._version = int(ptr["version"]) if ptr else (
            latest_step(self.directory) or 0
        )

    @property
    def version(self) -> int:
        """Last published version (0 = nothing published yet)."""
        return self._version

    def publish(self, params: Any, *, step: int,
                meta: Optional[Dict[str, Any]] = None) -> int:
        """Atomically publish ``params`` as the next version.

        Order is load-bearing: (1) payload file lands complete+fsynced
        under its final name, (2) pointer renames over LATEST.json,
        (3) retention unlinks ring overflow.  A kill between any two
        steps leaves a consistent directory (worst case: an unreferenced
        payload file, reclaimed by the next publish's retention pass)."""
        version = self._version + 1
        # save_checkpoint claims the "step" meta key for its own step —
        # the VERSION here — so the training round travels as "fed_step"
        # (load_published normalizes it back to meta["step"])
        save_checkpoint(
            self.directory, version, {"params": params},
            meta=dict(meta or {}, fed_step=int(step), version=version),
        )
        pointer = {
            "schema": POINTER_SCHEMA,
            "version": version,
            "file": f"step_{version}.msgpack",
            "step": int(step),
            "t_unix": time.time(),
        }
        tmp = os.path.join(self.directory, POINTER + ".tmp")
        with open(tmp, "w") as f:
            f.write(json.dumps(pointer))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.directory, POINTER))
        _fsync_dir(self.directory)
        self._version = version
        self._retire(version)
        return version

    def _retire(self, version: int) -> None:
        floor = version - self.retain
        for name in os.listdir(self.directory):
            if name.startswith("step_") and name.endswith(".msgpack"):
                try:
                    v = int(name[len("step_"):-len(".msgpack")])
                except ValueError:
                    continue
                if v <= floor:
                    try:
                        os.unlink(os.path.join(self.directory, name))
                    except FileNotFoundError:
                        pass


def load_published(directory: str, template: Any = None,
                   version: Optional[int] = None) -> Tuple[int, Any, Dict]:
    """Load a published version → ``(version, params, meta)``.

    ``version=None`` resolves the pointer.  With ``template`` the params
    restore through the typed template path (structure + dtypes fixed);
    without one they come back as the raw ``{path: array}`` map."""
    if version is None:
        ptr = read_pointer(directory)
        if ptr is None:
            raise FileNotFoundError(f"{directory}: nothing published yet")
        version = int(ptr["version"])
    if template is not None:
        tree, meta = load_checkpoint(directory, version, {"params": template})
        return version, tree["params"], _norm_meta(meta)
    flat, meta = load_flat(directory, version)
    params = {k.split("/", 1)[1]: v for k, v in flat.items()
              if k.startswith("params/")}
    return version, params, _norm_meta(meta)


def _norm_meta(meta: Dict[str, Any]) -> Dict[str, Any]:
    """Surface the training round as ``meta["step"]`` (stored as
    ``fed_step`` because ckpt.py's own ``step`` slot holds the version)."""
    meta = dict(meta)
    if "fed_step" in meta:
        meta["step"] = meta["fed_step"]
    return meta


class ParamsWatch:
    """Subscriber half: detect + load new versions without racing a
    concurrent publish.  ``poll()`` is cheap when nothing changed (one
    pointer read)."""

    def __init__(self, directory: str, template: Any = None,
                 *, max_retries: int = 8) -> None:
        self.directory = str(directory)
        self.template = template
        self.max_retries = int(max_retries)
        self.version = 0  # last version returned (0 = none yet)

    def poll(self) -> Optional[Tuple[int, Any, Dict]]:
        """``None`` if the published version is unchanged; else
        ``(version, params, meta)`` for the new latest.  Versions returned
        across calls are strictly increasing."""
        ptr = read_pointer(self.directory)
        if ptr is None or int(ptr["version"]) <= self.version:
            return None
        # the pointer may advance (and retention may unlink the version we
        # just resolved) between reading LATEST.json and opening the
        # payload — on FileNotFoundError, re-resolve and try the newer one
        for _ in range(self.max_retries):
            version = int(ptr["version"])
            try:
                version, params, meta = load_published(
                    self.directory, self.template, version
                )
            except FileNotFoundError:
                nxt = read_pointer(self.directory)
                if nxt is None or int(nxt["version"]) <= version:
                    raise
                ptr = nxt
                continue
            self.version = version
            return version, params, meta
        raise RuntimeError(
            f"{self.directory}: publisher outran the watcher "
            f"{self.max_retries}x in one poll — raise retain or max_retries"
        )


def watch(directory: str, template: Any = None) -> ParamsWatch:
    return ParamsWatch(directory, template)
