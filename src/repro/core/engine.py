"""The federated round engine.

One communication round (Algorithm 2 of the paper) is a single jitted —
and, on a mesh, pjit-sharded — program:

    sample cohort  →  broadcast (x_t, Δ_t)  →  vmap over clients of
    [lax.scan over K local steps]  →  masked-mean aggregate  →  server update

The engine is architecture-agnostic: it only sees ``loss_fn(params, batch)``
(DESIGN.md §7 — FedCM is optimizer-level).  On a TPU mesh the cohort axis is
sharded over ("pod","data") and each client's parameters may additionally be
tensor-sharded on "model"; the aggregation mean lowers to an all-reduce over
the cohort axes — the server/client message pattern of the paper becomes
collectives (DESIGN.md §3).

Participation models (§6.1 of the paper):

* ``fixed``      — exactly ``cohort_size`` clients, uniform w/o replacement.
* ``bernoulli``  — every client independently with prob cohort_size/N.  For a
  jit-static shape we draw the cohort count s ~ Binomial(N, p) (clipped to a
  capacity), take the first s entries of a random permutation, and mask the
  rest; conditioned on s this equals independent-Bernoulli participation.

Payload accounting mirrors §4.2: FedCM doubles only the DOWNLINK (x_t plus
Δ_t); uplink is one delta — unchanged from FedAvg.  SCAFFOLD pays both ways
(c down, Δc_i up); MimeLite pays an extra full-batch gradient up.

Fused multi-round engine (``run_rounds``): the paper's headline results
(Table 1, §6.1) need hundreds to thousands of rounds, and dispatching each
round as its own jit call — with host-side cohort sampling in between —
makes round *dispatch* the wall-clock bottleneck long before the math is.
``run_rounds(state, data, n_rounds)`` therefore executes N rounds as a
single ``jax.lax.scan`` whose body does everything a round needs on-device:

* cohort sampling (``sample_cohort``) from the carried rng,
* synthetic-data minibatch gathers (``repro.data.pipeline.gather_round_batches``,
  pure array-in/array-out so it traces),
* the round step itself (the same ``_round_step_impl`` the per-round path
  jits, so the two paths are numerically one implementation).

The carried ``FedState`` is donated (``donate_argnums``), so server params/
momentum/client-state buffers are updated in place across all N rounds, and
per-round ``RoundMetrics`` come back stacked ``(n_rounds, ...)``.  The
``client_sharding`` constructor arg pins the cohort axis of batches and
client states via sharding constraints in both the per-round and fused
paths.  ``cfg.use_fused_kernel`` additionally routes the per-local-step
FedCM blend through the Pallas ``fedcm_step_tree`` kernel (kernels/
fedcm_update; ``ref.py`` is the oracle).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import FedConfig
from repro.core.algorithms import (
    Algorithm,
    ClientOutputs,
    ServerState,
    client_state_init,
    get_algorithm,
    server_init,
)
from repro.data.pipeline import gather_full_client_batch, gather_round_batches
from repro.kernels.fedcm_update.ops import fedcm_step_tree
from repro.utils.trees import (
    tree_axpy,
    tree_bytes,
    tree_scale,
    tree_zeros_like,
)


class FedState(NamedTuple):
    params: Any
    server: ServerState
    client_states: Any  # stacked (N, …) or None
    rng: jax.Array


class RoundMetrics(NamedTuple):
    loss: jax.Array  # mean local training loss over cohort × K steps
    n_active: jax.Array
    delta_norm: jax.Array  # ‖mean Δ_i‖
    momentum_norm: jax.Array  # ‖Δ_t‖ (server momentum entering the round)
    eta_l: jax.Array
    bytes_down: jax.Array  # server→clients this round (f32 elements × 4)
    bytes_up: jax.Array  # clients→server this round


def cohort_capacity(cfg: FedConfig) -> int:
    """Static cohort axis length. ``fixed``: exactly S. ``bernoulli``: a
    Binomial(N, p) tail bound — mean + 5σ, clipped to N (p(overflow) < 3e-7;
    overflow clips the round's cohort, a negligible bias at these sizes)."""
    if cfg.participation == "fixed":
        return cfg.cohort_size
    p = cfg.cohort_size / cfg.num_clients
    sd = math.sqrt(cfg.num_clients * p * (1 - p))
    return min(cfg.num_clients, int(math.ceil(cfg.cohort_size + 5 * sd)))


def sample_cohort(rng, cfg: FedConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (client_ids (C,), active_mask (C,)) with C = cohort_capacity."""
    cap = cohort_capacity(cfg)
    k_perm, k_n = jax.random.split(rng)
    ids = jax.random.choice(k_perm, cfg.num_clients, (cap,), replace=False)
    if cfg.participation == "fixed":
        return ids, jnp.ones((cap,), bool)
    p = cfg.cohort_size / cfg.num_clients
    draws = jax.random.bernoulli(k_n, p, (cfg.num_clients,))
    s = jnp.clip(jnp.sum(draws).astype(jnp.int32), 1, cap)
    return ids, jnp.arange(cap) < s


def local_learning_rate(cfg: FedConfig, t) -> jax.Array:
    """Appendix C.2: exponential per-round decay of η_l."""
    return jnp.float32(cfg.eta_l) * jnp.float32(cfg.eta_l_decay) ** t.astype(jnp.float32)


# ----------------------------------------------------------------------
# client update
# ----------------------------------------------------------------------


def client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    params,  # x_t (broadcast)
    bcast_momentum,  # Δ_t (or c for scaffold; zeros otherwise)
    client_state,  # this client's c_i / λ_i slice (or zeros pytree)
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
) -> Tuple[ClientOutputs, jax.Array]:
    """One client's K local steps.  Returns (outputs, mean local loss)."""
    x0 = params
    cst = (client_state, bcast_momentum) if algo.name == "scaffold" else client_state
    # fedcm and mimelite share the blend form v = α·g + (1−α)·m, which is
    # exactly what the fused Pallas kernel computes in one HBM pass
    use_kernel = cfg.use_fused_kernel and algo.name in ("fedcm", "mimelite")

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        if use_kernel:
            x = fedcm_step_tree(x, g, bcast_momentum, cfg.alpha, eta_l)
            return x, loss
        v = algo.direction(cfg, bcast_momentum, cst, x, x0, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0, batches,
                              unroll=cfg.local_steps if unroll else 1)

    full_grad = tree_zeros_like(x0)
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0, full_grad_batch)

    outs = algo.client_finalize(cfg, x0, xK, cst, eta_l, full_grad)
    return outs, jnp.mean(losses)


# ----------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------


class FederatedEngine:
    """Builds the jitted round step for (algorithm, loss_fn, data layout).

    Usage::

        eng = FederatedEngine(cfg, loss_fn)
        state = eng.init(params, rng)
        state, metrics = eng.run_rounds(state, data, n_rounds)   # fused scan
        state, metrics = eng.run_round(state, data)     # one round at a time
        # or, lower-level / dry-runnable:
        state, metrics = eng.round_step(state, batches, ids, mask, full_batches)

    ``client_sharding`` (a ``NamedSharding`` whose spec names the mesh axes
    for the cohort dimension, e.g. ``NamedSharding(mesh, P(("pod","data")))``)
    is applied as a sharding constraint to the leading axis of every
    cohort-stacked array — minibatches, gathered client states, and the
    MimeLite full batches — in both the per-round and fused paths.
    """

    def __init__(
        self,
        cfg: FedConfig,
        loss_fn: Callable[[Any, Any], jax.Array],
        batch_size: int = 50,
        client_sharding: Optional[Any] = None,  # NamedSharding for the cohort axis
    ) -> None:
        self.cfg = cfg
        self.algo = get_algorithm(cfg.algo)
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.client_sharding = client_sharding
        self.analysis_unroll = False  # dry-run analysis form
        self._round_step = jax.jit(self._round_step_impl)
        # traced once per (shapes, n_rounds) — the compile-count regression
        # test asserts a 100-round run is ONE trace, not 100
        self.run_rounds_traces = 0
        self._run_rounds = jax.jit(
            self._run_rounds_impl,
            static_argnames=("n_rounds",),
            donate_argnums=(0,),
        )

    # -------------------------------------------------- init
    def init(self, params, rng) -> FedState:
        return FedState(
            params=params,
            server=server_init(params, self.cfg.momentum_dtype),
            client_states=client_state_init(params, self.cfg),
            rng=rng,
        )

    # -------------------------------------------------- payload accounting
    def payload_bytes(self, params) -> Dict[str, int]:
        """Per-client per-round communication in bytes (§4.2 discussion)."""
        P = tree_bytes(params)
        down = P  # x_t always goes down
        up = P  # Δ_i always goes up
        if self.algo.needs_momentum_broadcast:
            down += P  # Δ_t (fedcm/mimelite) or c (scaffold)
        if self.algo.name == "scaffold":
            up += P  # Δc_i — feddyn's λ_i, by contrast, never leaves the client
        if self.algo.needs_full_grad:
            up += P  # MimeLite full-batch gradient
        return {"down_per_client": down, "up_per_client": up}

    # -------------------------------------------------- cohort sharding
    def _constrain_cohort(self, tree):
        """Pin the leading (cohort) axis of every leaf to ``client_sharding``."""
        if self.client_sharding is None or tree is None:
            return tree
        mesh = self.client_sharding.mesh
        spec = self.client_sharding.spec
        cohort_axes = spec[0] if len(spec) else None

        def pin(a):
            s = NamedSharding(mesh, P(cohort_axes, *([None] * (a.ndim - 1))))
            return jax.lax.with_sharding_constraint(a, s)

        return jax.tree_util.tree_map(pin, tree)

    # -------------------------------------------------- round
    def _round_step_impl(self, state: FedState, batches, ids, mask, full_batches):
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, state.server.round)

        batches = self._constrain_cohort(batches)
        full_batches = self._constrain_cohort(full_batches)

        # gather per-client states for the cohort (stale entries untouched)
        if algo.needs_client_state:
            cohort_cst = jax.tree_util.tree_map(lambda a: a[ids], state.client_states)
        else:
            cohort_cst = jax.tree_util.tree_map(
                lambda p: jnp.zeros((ids.shape[0], *p.shape), p.dtype), state.params
            )
        cohort_cst = self._constrain_cohort(cohort_cst)

        def one_client(cst_i, batches_i, full_i):
            return client_update(
                algo, cfg, self.loss_fn, state.params, state.server.momentum,
                cst_i, batches_i, eta_l, full_grad_batch=full_i,
                unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst, batches, full_batches)

        # masked cohort mean (bernoulli: only active entries count)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)

        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def mmean(tree):
            return jax.tree_util.tree_map(
                lambda a: (
                    jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                    .astype(jnp.float32) / n_active
                ),
                tree,
            )

        mean_delta = mmean(outs.delta)
        mean_sd = mmean(outs.state_delta)
        mean_extra = mmean(outs.extra)

        new_params, new_server = algo.server_update(
            cfg, state.params, state.server, mean_delta, mean_sd, mean_extra,
            n_active, eta_l,
        )

        # scatter updated client states back (only active cohort members)
        new_cst = state.client_states
        if algo.needs_client_state:
            def scatter(a, d):
                upd = a[ids] + d * w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                return a.at[ids].set(upd)

            new_cst = jax.tree_util.tree_map(scatter, state.client_states, outs.state_delta)

        pay = self.payload_bytes(state.params)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * w) / n_active,
            n_active=n_active,
            delta_norm=_tree_norm(mean_delta),
            momentum_norm=_tree_norm(state.server.momentum),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
        )
        return FedState(new_params, new_server, new_cst, state.rng), metrics

    def round_step(self, state, batches, ids, mask, full_batches=None):
        if full_batches is None:
            # zero-size placeholder with the right treedef for vmap
            full_batches = jax.tree_util.tree_map(
                lambda b: b[:, 0], batches
            )  # (C, B, …) dummy; unused unless needs_full_grad
        return self._round_step(state, batches, ids, mask, full_batches)

    # -------------------------------------------------- data-driven round
    def _prepare_round(self, state: FedState, client_x, client_y):
        """Per-round setup shared VERBATIM by ``run_round`` and the
        ``run_rounds`` scan body: rng threading, cohort sampling, minibatch
        and (MimeLite) full-batch gathers.  One implementation is what
        makes the two paths' trajectories identical — don't fork it.

        Returns (state-with-advanced-rng, batches, ids, mask, full).
        """
        rng, k_cohort, k_batch = jax.random.split(state.rng, 3)
        ids, mask = sample_cohort(k_cohort, self.cfg)
        raw = gather_round_batches(
            client_x, client_y, k_batch, ids, self.cfg.local_steps, self.batch_size
        )
        batches = self._to_loss_batches(raw)
        if self.algo.needs_full_grad:
            full = self._to_loss_batches(
                gather_full_client_batch(client_x, client_y, ids)
            )
        else:
            # (C, B, ...) dummy with the right treedef for vmap; unused
            # unless needs_full_grad
            full = jax.tree_util.tree_map(lambda b: b[:, 0], batches)
        return state._replace(rng=rng), batches, ids, mask, full

    def run_round(self, state: FedState, data) -> Tuple[FedState, RoundMetrics]:
        """Samples cohort + minibatches from a FederatedData and steps."""
        state, batches, ids, mask, full = self._prepare_round(
            state, data.client_x, data.client_y
        )
        return self.round_step(state, batches, ids, mask, full)

    # -------------------------------------------------- fused multi-round
    def run_rounds(self, state: FedState, data, n_rounds: int) -> Tuple[FedState, RoundMetrics]:
        """Execute ``n_rounds`` communication rounds as ONE jitted lax.scan.

        Cohort sampling and minibatch drawing happen inside the scan body
        (no host round-trips), the carried ``FedState`` is donated, and the
        per-round metrics come back stacked with a leading ``(n_rounds,)``
        axis.  Numerically equivalent to calling ``run_round`` ``n_rounds``
        times (same rng threading, same ``_round_step_impl``); the
        equivalence test in tests/test_run_rounds.py holds all algorithms
        to that.

        The input ``state`` may be donated to the computation — use the
        returned state, not the argument, afterwards.
        """
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        return self._run_rounds(state, data.client_x, data.client_y, n_rounds=n_rounds)

    def _run_rounds_impl(self, state: FedState, client_x, client_y, n_rounds: int):
        self.run_rounds_traces += 1  # python side effect: counts traces only

        def body(st, _):
            st, batches, ids, mask, full = self._prepare_round(st, client_x, client_y)
            return self._round_step_impl(st, batches, ids, mask, full)

        return jax.lax.scan(body, state, None, length=n_rounds)

    @staticmethod
    def _to_loss_batches(raw):
        """{"x","y"} → loss_fn batch dict (pass-through for custom dicts).

        Must stay traceable: ``run_rounds`` calls it inside a jitted scan.
        """
        return raw


def _tree_norm(t):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(t)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.float32(0.0)


# ----------------------------------------------------------------------
# evaluation
# ----------------------------------------------------------------------


def make_eval_fn(predict_fn: Callable[[Any, Any], jax.Array], batch_size: int = 1000):
    """predict_fn(params, x) -> logits.  Returns eval(params, x, y) -> acc."""

    @jax.jit
    def eval_batch(params, x, y):
        logits = predict_fn(params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    def evaluate(params, x, y):
        n = x.shape[0]
        accs, ws = [], []
        for i in range(0, n, batch_size):
            xb, yb = x[i : i + batch_size], y[i : i + batch_size]
            accs.append(float(eval_batch(params, xb, yb)))
            ws.append(len(xb))
        return float(sum(a * w for a, w in zip(accs, ws)) / sum(ws))

    return evaluate
