"""The federated round engine.

One communication round (Algorithm 2 of the paper) is a single jitted —
and, on a mesh, pjit-sharded — program:

    sample cohort  →  broadcast (x_t, Δ_t)  →  vmap over clients of
    [lax.scan over K local steps]  →  masked-mean aggregate  →  server update

The engine is architecture-agnostic: it only sees ``loss_fn(params, batch)``
(DESIGN.md §7 — FedCM is optimizer-level).  On a TPU mesh the cohort axis is
sharded over ("pod","data") and each client's parameters may additionally be
tensor-sharded on "model"; the aggregation mean lowers to an all-reduce over
the cohort axes — the server/client message pattern of the paper becomes
collectives (DESIGN.md §3).

Participation models (§6.1 of the paper):

* ``fixed``      — exactly ``cohort_size`` clients, uniform w/o replacement.
* ``bernoulli``  — every client independently with prob cohort_size/N.  For a
  jit-static shape we draw the cohort count s ~ Binomial(N, p) (clipped to a
  capacity), take the first s entries of a random permutation, and mask the
  rest; conditioned on s this equals independent-Bernoulli participation.

Payload accounting mirrors §4.2: FedCM doubles only the DOWNLINK (x_t plus
Δ_t); uplink is one delta — unchanged from FedAvg.  SCAFFOLD pays both ways
(c down, Δc_i up); MimeLite pays an extra full-batch gradient up.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.algorithms import (
    Algorithm,
    ClientOutputs,
    ServerState,
    client_state_init,
    get_algorithm,
    server_init,
)
from repro.utils.trees import (
    tree_axpy,
    tree_bytes,
    tree_scale,
    tree_zeros_like,
)


class FedState(NamedTuple):
    params: Any
    server: ServerState
    client_states: Any  # stacked (N, …) or None
    rng: jax.Array


class RoundMetrics(NamedTuple):
    loss: jax.Array  # mean local training loss over cohort × K steps
    n_active: jax.Array
    delta_norm: jax.Array  # ‖mean Δ_i‖
    momentum_norm: jax.Array  # ‖Δ_t‖ (server momentum entering the round)
    eta_l: jax.Array
    bytes_down: jax.Array  # server→clients this round (f32 elements × 4)
    bytes_up: jax.Array  # clients→server this round


def cohort_capacity(cfg: FedConfig) -> int:
    """Static cohort axis length. ``fixed``: exactly S. ``bernoulli``: a
    Binomial(N, p) tail bound — mean + 5σ, clipped to N (p(overflow) < 3e-7;
    overflow clips the round's cohort, a negligible bias at these sizes)."""
    if cfg.participation == "fixed":
        return cfg.cohort_size
    p = cfg.cohort_size / cfg.num_clients
    sd = math.sqrt(cfg.num_clients * p * (1 - p))
    return min(cfg.num_clients, int(math.ceil(cfg.cohort_size + 5 * sd)))


def sample_cohort(rng, cfg: FedConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (client_ids (C,), active_mask (C,)) with C = cohort_capacity."""
    cap = cohort_capacity(cfg)
    k_perm, k_n = jax.random.split(rng)
    ids = jax.random.choice(k_perm, cfg.num_clients, (cap,), replace=False)
    if cfg.participation == "fixed":
        return ids, jnp.ones((cap,), bool)
    p = cfg.cohort_size / cfg.num_clients
    draws = jax.random.bernoulli(k_n, p, (cfg.num_clients,))
    s = jnp.clip(jnp.sum(draws).astype(jnp.int32), 1, cap)
    return ids, jnp.arange(cap) < s


def local_learning_rate(cfg: FedConfig, t) -> jax.Array:
    """Appendix C.2: exponential per-round decay of η_l."""
    return jnp.float32(cfg.eta_l) * jnp.float32(cfg.eta_l_decay) ** t.astype(jnp.float32)


# ----------------------------------------------------------------------
# client update
# ----------------------------------------------------------------------


def client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    params,  # x_t (broadcast)
    bcast_momentum,  # Δ_t (or c for scaffold; zeros otherwise)
    client_state,  # this client's c_i / λ_i slice (or zeros pytree)
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
) -> Tuple[ClientOutputs, jax.Array]:
    """One client's K local steps.  Returns (outputs, mean local loss)."""
    x0 = params
    cst = (client_state, bcast_momentum) if algo.name == "scaffold" else client_state

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        v = algo.direction(cfg, bcast_momentum, cst, x, x0, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0, batches,
                              unroll=cfg.local_steps if unroll else 1)

    full_grad = tree_zeros_like(x0)
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0, full_grad_batch)

    outs = algo.client_finalize(cfg, x0, xK, cst, eta_l, full_grad)
    return outs, jnp.mean(losses)


# ----------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------


class FederatedEngine:
    """Builds the jitted round step for (algorithm, loss_fn, data layout).

    Usage::

        eng = FederatedEngine(cfg, loss_fn)
        state = eng.init(params, rng)
        state, metrics = eng.run_round(state, data)     # data: FederatedData
        # or, lower-level / dry-runnable:
        state, metrics = eng.round_step(state, batches, ids, mask, full_batches)
    """

    def __init__(
        self,
        cfg: FedConfig,
        loss_fn: Callable[[Any, Any], jax.Array],
        batch_size: int = 50,
        client_sharding: Optional[Any] = None,  # NamedSharding for the cohort axis
    ) -> None:
        self.cfg = cfg
        self.algo = get_algorithm(cfg.algo)
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.client_sharding = client_sharding
        self.analysis_unroll = False  # dry-run analysis form
        self._round_step = jax.jit(self._round_step_impl)

    # -------------------------------------------------- init
    def init(self, params, rng) -> FedState:
        return FedState(
            params=params,
            server=server_init(params, self.cfg.momentum_dtype),
            client_states=client_state_init(params, self.cfg),
            rng=rng,
        )

    # -------------------------------------------------- payload accounting
    def payload_bytes(self, params) -> Dict[str, int]:
        """Per-client per-round communication in bytes (§4.2 discussion)."""
        P = tree_bytes(params)
        down = P  # x_t always goes down
        up = P  # Δ_i always goes up
        if self.algo.needs_momentum_broadcast:
            down += P  # Δ_t (fedcm/mimelite) or c (scaffold)
        if self.algo.name == "scaffold":
            up += P  # Δc_i — feddyn's λ_i, by contrast, never leaves the client
        if self.algo.needs_full_grad:
            up += P  # MimeLite full-batch gradient
        return {"down_per_client": down, "up_per_client": up}

    # -------------------------------------------------- round
    def _round_step_impl(self, state: FedState, batches, ids, mask, full_batches):
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, state.server.round)

        # gather per-client states for the cohort (stale entries untouched)
        if algo.needs_client_state:
            cohort_cst = jax.tree_util.tree_map(lambda a: a[ids], state.client_states)
        else:
            cohort_cst = jax.tree_util.tree_map(
                lambda p: jnp.zeros((ids.shape[0], *p.shape), p.dtype), state.params
            )

        def one_client(cst_i, batches_i, full_i):
            return client_update(
                algo, cfg, self.loss_fn, state.params, state.server.momentum,
                cst_i, batches_i, eta_l, full_grad_batch=full_i,
                unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst, batches, full_batches)

        # masked cohort mean (bernoulli: only active entries count)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)

        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def mmean(tree):
            return jax.tree_util.tree_map(
                lambda a: (
                    jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                    .astype(jnp.float32) / n_active
                ),
                tree,
            )

        mean_delta = mmean(outs.delta)
        mean_sd = mmean(outs.state_delta)
        mean_extra = mmean(outs.extra)

        new_params, new_server = algo.server_update(
            cfg, state.params, state.server, mean_delta, mean_sd, mean_extra,
            n_active, eta_l,
        )

        # scatter updated client states back (only active cohort members)
        new_cst = state.client_states
        if algo.needs_client_state:
            def scatter(a, d):
                upd = a[ids] + d * w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                return a.at[ids].set(upd)

            new_cst = jax.tree_util.tree_map(scatter, state.client_states, outs.state_delta)

        pay = self.payload_bytes(state.params)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * w) / n_active,
            n_active=n_active,
            delta_norm=_tree_norm(mean_delta),
            momentum_norm=_tree_norm(state.server.momentum),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
        )
        return FedState(new_params, new_server, new_cst, state.rng), metrics

    def round_step(self, state, batches, ids, mask, full_batches=None):
        if full_batches is None:
            # zero-size placeholder with the right treedef for vmap
            full_batches = jax.tree_util.tree_map(
                lambda b: b[:, 0], batches
            )  # (C, B, …) dummy; unused unless needs_full_grad
        return self._round_step(state, batches, ids, mask, full_batches)

    # -------------------------------------------------- data-driven round
    def run_round(self, state: FedState, data) -> Tuple[FedState, RoundMetrics]:
        """Samples cohort + minibatches from a FederatedData and steps."""
        rng, k_cohort, k_batch = jax.random.split(state.rng, 3)
        ids, mask = sample_cohort(k_cohort, self.cfg)
        raw = data.sample_round_batches(
            k_batch, ids, self.cfg.local_steps, self.batch_size
        )
        batches = self._to_loss_batches(raw)
        full = None
        if self.algo.needs_full_grad:
            full = self._to_loss_batches(data.full_client_batch(ids))
        state = state._replace(rng=rng)
        return self.round_step(state, batches, ids, mask, full)

    @staticmethod
    def _to_loss_batches(raw):
        """{"x","y"} → loss_fn batch dict (pass-through for custom dicts)."""
        return raw


def _tree_norm(t):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(t)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.float32(0.0)


# ----------------------------------------------------------------------
# evaluation
# ----------------------------------------------------------------------


def make_eval_fn(predict_fn: Callable[[Any, Any], jax.Array], batch_size: int = 1000):
    """predict_fn(params, x) -> logits.  Returns eval(params, x, y) -> acc."""

    @jax.jit
    def eval_batch(params, x, y):
        logits = predict_fn(params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    def evaluate(params, x, y):
        n = x.shape[0]
        accs, ws = [], []
        for i in range(0, n, batch_size):
            xb, yb = x[i : i + batch_size], y[i : i + batch_size]
            accs.append(float(eval_batch(params, xb, yb)))
            ws.append(len(xb))
        return float(sum(a * w for a, w in zip(accs, ws)) / sum(ws))

    return evaluate
