"""The federated round engine.

One communication round (Algorithm 2 of the paper) is a single jitted —
and, on a mesh, pjit-sharded — program:

    sample cohort  →  broadcast (x_t, Δ_t)  →  vmap over clients of
    [lax.scan over K local steps]  →  masked-mean aggregate  →  server update

The engine is architecture-agnostic: it only sees ``loss_fn(params, batch)``
(DESIGN.md §7 — FedCM is optimizer-level).  On a TPU mesh the cohort axis is
sharded over ("pod","data") and each client's parameters may additionally be
tensor-sharded on "model"; the aggregation mean lowers to an all-reduce over
the cohort axes — the server/client message pattern of the paper becomes
collectives (DESIGN.md §3).

Participation models (§6.1 of the paper):

* ``fixed``      — exactly ``cohort_size`` clients, uniform w/o replacement.
* ``bernoulli``  — every client independently with prob cohort_size/N.  For a
  jit-static shape we draw the cohort count s ~ Binomial(N, p) (clipped to a
  capacity), take the first s entries of a random permutation, and mask the
  rest; conditioned on s this equals independent-Bernoulli participation.

Payload accounting mirrors §4.2: FedCM doubles only the DOWNLINK (x_t plus
Δ_t); uplink is one delta — unchanged from FedAvg.  SCAFFOLD pays both ways
(c down, Δc_i up); MimeLite pays an extra full-batch gradient up.

Fused multi-round engine (``run_rounds``): the paper's headline results
(Table 1, §6.1) need hundreds to thousands of rounds, and dispatching each
round as its own jit call — with host-side cohort sampling in between —
makes round *dispatch* the wall-clock bottleneck long before the math is.
``run_rounds(state, data, n_rounds)`` therefore executes N rounds as a
single ``jax.lax.scan`` whose body does everything a round needs on-device:

* cohort sampling (``sample_cohort``) from the carried rng,
* synthetic-data minibatch gathers (``repro.data.pipeline.gather_round_batches``,
  pure array-in/array-out so it traces),
* the round step itself (the same ``_round_step_impl`` the per-round path
  jits, so the two paths are numerically one implementation).

The carried ``FedState`` is donated (``donate_argnums``), so server params/
momentum/client-state buffers are updated in place across all N rounds, and
per-round ``RoundMetrics`` come back stacked ``(n_rounds, ...)``.  The
``client_sharding`` constructor arg pins the cohort axis of batches and
client states via sharding constraints in both the per-round and fused
paths.

Flat parameter plane (``cfg.use_flat_plane``, default on): params and
server momentum/second-moment are ravelled ONCE per ``run_rounds`` call
(``repro.core.flat.FlatSpec``) into contiguous ``(P,)`` buffers that carry
the round-scope state; every round-scope reduction lands flat — masked
cohort means concatenate per-leaf contractions into ONE ``(P,)`` buffer,
the server update and metric norms are single fused ops, and stateless
algorithms never materialize the zero state/extra planes the tree path
builds and aggregates.  The K-step local scan itself keeps the LEAF form
(model autodiff is per-leaf; a flat↔tree conversion per step measures
2-3× slower on CPU XLA), so its body is bitwise the tree path's.  Under
``use_fused_kernel`` the scan flips to the flat ``(P,)`` carry — the
kernels consume flat buffers directly, per-client control variates ride an
``(N, P)`` plane (ONE gather/scatter), and the per-step concatenate/split
of the PR-1 kernel route disappears entirely.  The tree path
(``use_flat_plane=False``) is retained verbatim as the numerical oracle
(tests/test_flat.py) and for tensor-sharded lowering (launch/fed_dryrun).

``cfg.use_fused_kernel`` routes the update phase through Pallas: on the
flat plane, the per-local-step direction via ``kernels/fed_direction`` (all
algorithms) and the round-close masked-mean + momentum EMA + param step via
``kernels/server_update`` (fedavg/fedcm/scaffold/mimelite); on the tree
path, the legacy whole-tree ``fedcm_step_tree`` launch (fedcm/mimelite).
Each kernel's ``ref.py`` is its oracle.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import FedConfig
from repro.core.algorithms import (
    Algorithm,
    ClientOutputs,
    ServerState,
    client_state_init,
    get_algorithm,
    server_init,
    sparse_client_finalize,
)
from repro.core.flat import FlatSpec
from repro.data.pipeline import gather_full_client_batch, gather_round_batches
from repro.kernels.fed_direction.ops import flat_direction_step
from repro.kernels.fedcm_update.ops import fedcm_step_tree
from repro.kernels.server_update.ops import fused_server_step
from repro.utils.trees import (
    ravel_leaves,
    tree_axpy,
    tree_bytes,
    tree_scale,
    tree_zeros_like,
)


class FedState(NamedTuple):
    params: Any
    server: ServerState
    client_states: Any  # stacked (N, …) or None
    rng: jax.Array


class RoundMetrics(NamedTuple):
    loss: jax.Array  # mean local training loss over cohort × K steps
    n_active: jax.Array
    delta_norm: jax.Array  # ‖mean Δ_i‖
    momentum_norm: jax.Array  # ‖Δ_t‖ (server momentum entering the round)
    eta_l: jax.Array
    bytes_down: jax.Array  # server→clients this round (f32 elements × 4)
    bytes_up: jax.Array  # clients→server this round


def cohort_capacity(cfg: FedConfig) -> int:
    """Static cohort axis length. ``fixed``: exactly S. ``bernoulli``: a
    Binomial(N, p) tail bound — mean + 5σ, clipped to N (p(overflow) < 3e-7;
    overflow clips the round's cohort, a negligible bias at these sizes)."""
    if cfg.participation == "fixed":
        return cfg.cohort_size
    p = cfg.cohort_size / cfg.num_clients
    sd = math.sqrt(cfg.num_clients * p * (1 - p))
    return min(cfg.num_clients, int(math.ceil(cfg.cohort_size + 5 * sd)))


def sample_cohort(rng, cfg: FedConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (client_ids (C,), active_mask (C,)) with C = cohort_capacity."""
    cap = cohort_capacity(cfg)
    k_perm, k_n = jax.random.split(rng)
    ids = jax.random.choice(k_perm, cfg.num_clients, (cap,), replace=False)
    if cfg.participation == "fixed":
        return ids, jnp.ones((cap,), bool)
    p = cfg.cohort_size / cfg.num_clients
    draws = jax.random.bernoulli(k_n, p, (cfg.num_clients,))
    s = jnp.clip(jnp.sum(draws).astype(jnp.int32), 1, cap)
    return ids, jnp.arange(cap) < s


def local_learning_rate(cfg: FedConfig, t) -> jax.Array:
    """Appendix C.2: exponential per-round decay of η_l."""
    return jnp.float32(cfg.eta_l) * jnp.float32(cfg.eta_l_decay) ** t.astype(jnp.float32)


# ----------------------------------------------------------------------
# client update
# ----------------------------------------------------------------------


def client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    params,  # x_t (broadcast)
    bcast_momentum,  # Δ_t (or c for scaffold; zeros otherwise)
    client_state,  # this client's c_i / λ_i slice (or zeros pytree)
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
) -> Tuple[ClientOutputs, jax.Array]:
    """One client's K local steps.  Returns (outputs, mean local loss)."""
    x0 = params
    cst = (client_state, bcast_momentum) if algo.name == "scaffold" else client_state
    # fedcm and mimelite share the blend form v = α·g + (1−α)·m, which is
    # exactly what the fused Pallas kernel computes in one HBM pass
    use_kernel = cfg.use_fused_kernel and algo.name in ("fedcm", "mimelite")

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        if use_kernel:
            x = fedcm_step_tree(x, g, bcast_momentum, cfg.alpha, eta_l)
            return x, loss
        v = algo.direction(cfg, bcast_momentum, cst, x, x0, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0, batches,
                              unroll=cfg.local_steps if unroll else 1)

    full_grad = tree_zeros_like(x0)
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0, full_grad_batch)

    outs = algo.client_finalize(cfg, x0, xK, cst, eta_l, full_grad)
    return outs, jnp.mean(losses)


def flat_client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    spec: FlatSpec,
    x_t: jax.Array,  # (P,) broadcast round anchor (flat)
    x0_tree,  # the same anchor as a tree (unravelled ONCE per round)
    m_t: jax.Array,  # (P,) Δ_t (or c for scaffold; zeros otherwise)
    m_tree,  # its tree view (unravelled ONCE per round)
    cst_tree_i,  # this client's c_i / λ_i as a tree slice, or None
    cst_flat_i,  # the same as a (P,) plane row, or None
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
):
    """One client's K local steps, finalized onto flat-engine outputs.

    jnp path: the local scan carries the LEAF form — model autodiff is
    per-leaf anyway, and a flat↔tree conversion per step would add unfused
    ops to the hottest loop (measured ~2-3× slower on CPU XLA) — so the
    step body is bitwise the tree path's, and the client's outputs stay
    leaf trees with ``None`` for unused planes
    (``sparse_client_finalize``).  The engine then reduces them straight to
    flat ``(P,)`` MEANS — the full ``(C, P)`` cohort plane is never
    materialized (a batched concatenate costs more than the per-leaf
    contractions it would save).

    ``cfg.use_fused_kernel`` flips the scan onto the flat ``(P,)`` carry
    instead: the ``fed_direction`` kernel consumes flat buffers directly
    (no per-step concatenate/split — the loss unravels the plane by
    slicing, which fuses on TPU where this path is aimed) and the outputs
    ARE ``(P,)`` planes, giving the ``(C, P)`` delta plane the fused
    ``server_update`` kernel wants for free.
    """
    if cfg.use_fused_kernel:
        def flat_loss(flat, batch):
            return loss_fn(spec.unravel(flat), batch)

        cst = (cst_flat_i, m_t) if algo.name == "scaffold" else cst_flat_i

        def step(x, batch):
            loss, g = jax.value_and_grad(flat_loss)(x, batch)
            if cfg.weight_decay:
                g = cfg.weight_decay * x + g
            x = flat_direction_step(algo.name, cfg, x, g, m_t, cst, x_t, eta_l)
            return x, loss

        xK_flat, losses = jax.lax.scan(step, x_t, batches,
                                       unroll=cfg.local_steps if unroll else 1)
        full_grad = None
        if algo.needs_full_grad:
            assert full_grad_batch is not None
            full_grad = jax.grad(flat_loss)(x_t, full_grad_batch)
        outs = sparse_client_finalize(algo, cfg, x_t, xK_flat, cst, eta_l, full_grad)
        return outs, jnp.mean(losses)

    cst = (cst_tree_i, m_tree) if algo.name == "scaffold" else cst_tree_i

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        v = algo.direction(cfg, m_tree, cst, x, x0_tree, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0_tree, batches,
                              unroll=cfg.local_steps if unroll else 1)
    full_grad = None
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0_tree, full_grad_batch)
    outs = sparse_client_finalize(algo, cfg, x0_tree, xK, cst, eta_l, full_grad)
    return outs, jnp.mean(losses)


# ----------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------


class FederatedEngine:
    """Builds the jitted round step for (algorithm, loss_fn, data layout).

    Usage::

        eng = FederatedEngine(cfg, loss_fn)
        state = eng.init(params, rng)
        state, metrics = eng.run_rounds(state, data, n_rounds)   # fused scan
        state, metrics = eng.run_round(state, data)     # one round at a time
        # or, lower-level / dry-runnable:
        state, metrics = eng.round_step(state, batches, ids, mask, full_batches)

    ``client_sharding`` (a ``NamedSharding`` whose spec names the mesh axes
    for the cohort dimension, e.g. ``NamedSharding(mesh, P(("pod","data")))``)
    is applied as a sharding constraint to the leading axis of every
    cohort-stacked array — minibatches, gathered client states, and the
    MimeLite full batches — in both the per-round and fused paths.
    """

    def __init__(
        self,
        cfg: FedConfig,
        loss_fn: Callable[[Any, Any], jax.Array],
        batch_size: int = 50,
        client_sharding: Optional[Any] = None,  # NamedSharding for the cohort axis
    ) -> None:
        self.cfg = cfg
        self.algo = get_algorithm(cfg.algo)
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.client_sharding = client_sharding
        self.analysis_unroll = False  # dry-run analysis form
        self._round_step = jax.jit(self._round_step_impl)
        # traced once per (shapes, n_rounds) — the compile-count regression
        # test asserts a 100-round run is ONE trace, not 100
        self.run_rounds_traces = 0
        self._run_rounds = jax.jit(
            self._run_rounds_impl,
            static_argnames=("n_rounds",),
            donate_argnums=(0,),
        )

    # -------------------------------------------------- init
    def init(self, params, rng) -> FedState:
        return FedState(
            params=params,
            server=server_init(params, self.cfg.momentum_dtype),
            client_states=client_state_init(params, self.cfg),
            rng=rng,
        )

    # -------------------------------------------------- payload accounting
    def payload_bytes(self, params) -> Dict[str, int]:
        """Per-client per-round communication in bytes (§4.2 discussion)."""
        return self._payload_from_nbytes(tree_bytes(params))

    def _payload_from_nbytes(self, P: int) -> Dict[str, int]:
        """Payload accounting from a total byte count — the flat path charges
        ``FlatSpec.nbytes`` (the wire dtypes), identical to ``tree_bytes``."""
        down = P  # x_t always goes down
        up = P  # Δ_i always goes up
        if self.algo.needs_momentum_broadcast:
            down += P  # Δ_t (fedcm/mimelite) or c (scaffold)
        if self.algo.name == "scaffold":
            up += P  # Δc_i — feddyn's λ_i, by contrast, never leaves the client
        if self.algo.needs_full_grad:
            up += P  # MimeLite full-batch gradient
        return {"down_per_client": down, "up_per_client": up}

    # -------------------------------------------------- cohort sharding
    def _constrain_cohort(self, tree):
        """Pin the leading (cohort) axis of every leaf to ``client_sharding``."""
        if self.client_sharding is None or tree is None:
            return tree
        mesh = self.client_sharding.mesh
        spec = self.client_sharding.spec
        cohort_axes = spec[0] if len(spec) else None

        def pin(a):
            s = NamedSharding(mesh, P(cohort_axes, *([None] * (a.ndim - 1))))
            return jax.lax.with_sharding_constraint(a, s)

        return jax.tree_util.tree_map(pin, tree)

    # -------------------------------------------------- flat plane
    def _ravel_state(self, state: FedState, spec: FlatSpec) -> FedState:
        """Tree state → flat-plane state: the ONE ravel of a run_rounds call.
        Params/second-moment become f32 ``(P,)`` planes and momentum a
        ``momentum_dtype`` plane.  Stacked per-client control variates
        become an ``(N, P)`` plane on the kernel path (whose clients
        produce flat buffers anyway, so gather/scatter are ONE op each);
        the jnp path keeps them in leaf form — its local steps consume
        leaves, and a per-round (C, P) concatenate costs more than the
        per-leaf gather/scatter it would replace."""
        cfg = self.cfg
        fsrv = ServerState(
            momentum=spec.ravel(state.server.momentum, dtype=cfg.momentum_dtype),
            second_moment=spec.ravel(state.server.second_moment),
            round=state.server.round,
        )
        fcst = state.client_states
        if fcst is not None and cfg.use_fused_kernel:
            fcst = spec.ravel(fcst, batch_dims=1)
        return FedState(spec.ravel(state.params), fsrv, fcst, state.rng)

    def _unravel_state(self, fstate: FedState, spec: FlatSpec) -> FedState:
        """Flat-plane state → tree state (leaf shapes AND dtypes restored)."""
        cfg = self.cfg
        srv = ServerState(
            momentum=spec.unravel(fstate.server.momentum, dtype=cfg.momentum_dtype),
            second_moment=spec.unravel(fstate.server.second_moment),
            round=fstate.server.round,
        )
        cst = fstate.client_states
        if cst is not None and cfg.use_fused_kernel:
            cst = spec.unravel(cst)
        return FedState(spec.unravel(fstate.params), srv, cst, fstate.rng)

    def _flat_round_step(self, fstate: FedState, batches, ids, mask,
                         full_batches, spec: FlatSpec):
        """One round entirely on the flat plane: (P,) carry through the
        local-step scan, (C, P) cohort planes through aggregation, (N, P)
        client-state scatter.  Same math as ``_tree_round_step`` — the
        equivalence tests in tests/test_flat.py hold the two bitwise-close."""
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, fstate.server.round)
        batches = self._constrain_cohort(batches)

        x_t = fstate.params  # (P,) f32
        m_t = fstate.server.momentum  # (P,) momentum_dtype
        # leaf views for the local scan — unravelled ONCE per round (x0 is
        # the scan carry init, so its slices materialize at loop entry; the
        # momentum view is a loop-invariant closure)
        x0_tree = spec.unravel(x_t)
        m_tree = spec.unravel(m_t, dtype=cfg.momentum_dtype)

        cohort_cst = cohort_cst_tree = None
        if algo.needs_client_state:
            if cfg.use_fused_kernel:  # (N, P) plane: ONE gather
                cohort_cst = self._constrain_cohort(fstate.client_states[ids])
            else:  # leaf form, as the local steps consume it
                cohort_cst_tree = self._constrain_cohort(
                    jax.tree_util.tree_map(lambda a: a[ids], fstate.client_states)
                )
        full = None
        if algo.needs_full_grad:
            full = self._constrain_cohort(full_batches)

        def one_client(cst_tree_i, cst_flat_i, batches_i, full_i):
            return flat_client_update(
                algo, cfg, self.loss_fn, spec, x_t, x0_tree, m_t, m_tree,
                cst_tree_i, cst_flat_i, batches_i, eta_l,
                full_grad_batch=full_i, unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst_tree, cohort_cst, batches, full)

        # masked cohort means, reduced straight to flat (P,) buffers.
        # jnp path: outs hold (C, *shape) leaf trees — contract per leaf and
        # concatenate only the tiny means (materializing the full (C, P)
        # plane costs more than it saves).  Kernel path: outs ARE (C, P)
        # planes (the fused server kernel streams them once).  Unused
        # planes are None — never materialized, never reduced (the tree
        # path pays for both).
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)
        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))
        use_kernel = cfg.use_fused_kernel

        def leaf_mean(a):
            return (
                jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                .astype(jnp.float32) / n_active
            )

        def pmean(x):
            if x is None:
                return None
            if use_kernel:  # (C, P) plane
                return leaf_mean(x)
            return ravel_leaves(
                [leaf_mean(l) for l in jax.tree_util.tree_leaves(x)], jnp.float32
            )

        fsrv = fstate.server
        if use_kernel and algo.name in ("fedavg", "fedcm", "scaffold", "mimelite"):
            new_params, new_momentum, mean_delta = self._fused_server_update(
                algo, outs, w, n_active, x_t, m_t, eta_l
            )
            new_server = ServerState(new_momentum, fsrv.second_moment, fsrv.round + 1)
        else:
            mean_delta = pmean(outs.delta)
            new_params, new_server = algo.server_update(
                cfg, x_t, fsrv, mean_delta, pmean(outs.state_delta),
                pmean(outs.extra), n_active, eta_l,
            )

        # scatter updated client states back (only active cohort members):
        # ONE scatter on the (N, P) plane (kernel path) or per-leaf like
        # the tree oracle (jnp path)
        new_cst = fstate.client_states
        if algo.needs_client_state:
            if use_kernel:
                upd = cohort_cst + outs.state_delta * w[:, None]
                new_cst = fstate.client_states.at[ids].set(upd)
            else:
                def scatter(a, d):
                    upd = a[ids] + d * w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                    return a.at[ids].set(upd)

                new_cst = jax.tree_util.tree_map(
                    scatter, fstate.client_states, outs.state_delta
                )

        pay = self._payload_from_nbytes(spec.nbytes)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * w) / n_active,
            n_active=n_active,
            delta_norm=_flat_norm(mean_delta),
            momentum_norm=_flat_norm(m_t),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
        )
        return FedState(new_params, new_server, new_cst, fstate.rng), metrics

    def _fused_server_update(self, algo, outs, w, n_active, x_t, m_t, eta_l):
        """Round-close via the fused server kernel: masked mean + momentum
        EMA + param step in one pass over the (C, P) plane (two passes for
        the algorithms that EMA a second plane)."""
        cfg = self.cfg
        wn = w / n_active
        # honor cfg.aggregate_dtype exactly like the jnp paths: the uplink
        # planes are quantized BEFORE the reduction (the kernel body then
        # accumulates in f32).  Only the reduction inputs are cast — the
        # client-state scatter keeps the unquantized plane, as the tree
        # oracle does.
        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def q(plane):
            return plane if agg_dt == jnp.float32 else plane.astype(agg_dt)
        if algo.name in ("fedavg", "fedcm"):
            # m' := Δ_{t+1} = −mean/(η_l·K);  x' = x + η_g·mean
            s = -1.0 / (eta_l * cfg.local_steps)
            m_dt = jnp.dtype(cfg.momentum_dtype) if algo.name == "fedcm" else jnp.float32
            return fused_server_step(
                q(outs.delta), wn, x_t, m_t, 0.0, s, cfg.eta_g, m_dtype=m_dt
            )
        if algo.name == "scaffold":
            new_x, _, mean_delta = fused_server_step(
                q(outs.delta), wn, x_t, m_t, 1.0, 0.0, cfg.eta_g
            )
            frac = n_active / cfg.num_clients
            _, new_c, _ = fused_server_step(
                q(outs.state_delta), wn, x_t, m_t, 1.0, frac, 0.0,
                m_dtype=jnp.float32,
            )
            return new_x, new_c, mean_delta
        # mimelite: x from the delta plane, m EMA from the full-batch grads
        new_x, _, mean_delta = fused_server_step(
            q(outs.delta), wn, x_t, m_t, 1.0, 0.0, cfg.eta_g
        )
        _, new_m, _ = fused_server_step(
            q(outs.extra), wn, x_t, m_t, 1.0 - cfg.alpha, cfg.alpha, 0.0,
            m_dtype=jnp.float32,
        )
        return new_x, new_m, mean_delta

    # -------------------------------------------------- round
    def _round_step_impl(self, state: FedState, batches, ids, mask, full_batches):
        if self.cfg.use_flat_plane:
            spec = FlatSpec.from_tree(state.params)
            fstate = self._ravel_state(state, spec)
            fstate, metrics = self._flat_round_step(
                fstate, batches, ids, mask, full_batches, spec
            )
            return self._unravel_state(fstate, spec), metrics
        return self._tree_round_step(state, batches, ids, mask, full_batches)

    def _tree_round_step(self, state: FedState, batches, ids, mask, full_batches):
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, state.server.round)

        batches = self._constrain_cohort(batches)
        full_batches = self._constrain_cohort(full_batches)

        # gather per-client states for the cohort (stale entries untouched)
        if algo.needs_client_state:
            cohort_cst = jax.tree_util.tree_map(lambda a: a[ids], state.client_states)
        else:
            cohort_cst = jax.tree_util.tree_map(
                lambda p: jnp.zeros((ids.shape[0], *p.shape), p.dtype), state.params
            )
        cohort_cst = self._constrain_cohort(cohort_cst)

        def one_client(cst_i, batches_i, full_i):
            return client_update(
                algo, cfg, self.loss_fn, state.params, state.server.momentum,
                cst_i, batches_i, eta_l, full_grad_batch=full_i,
                unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst, batches, full_batches)

        # masked cohort mean (bernoulli: only active entries count)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)

        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def mmean(tree):
            return jax.tree_util.tree_map(
                lambda a: (
                    jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                    .astype(jnp.float32) / n_active
                ),
                tree,
            )

        mean_delta = mmean(outs.delta)
        mean_sd = mmean(outs.state_delta)
        mean_extra = mmean(outs.extra)

        new_params, new_server = algo.server_update(
            cfg, state.params, state.server, mean_delta, mean_sd, mean_extra,
            n_active, eta_l,
        )

        # scatter updated client states back (only active cohort members)
        new_cst = state.client_states
        if algo.needs_client_state:
            def scatter(a, d):
                upd = a[ids] + d * w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                return a.at[ids].set(upd)

            new_cst = jax.tree_util.tree_map(scatter, state.client_states, outs.state_delta)

        pay = self.payload_bytes(state.params)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * w) / n_active,
            n_active=n_active,
            delta_norm=_tree_norm(mean_delta),
            momentum_norm=_tree_norm(state.server.momentum),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
        )
        return FedState(new_params, new_server, new_cst, state.rng), metrics

    def round_step(self, state, batches, ids, mask, full_batches=None):
        if full_batches is None:
            # zero-size placeholder with the right treedef for vmap
            full_batches = jax.tree_util.tree_map(
                lambda b: b[:, 0], batches
            )  # (C, B, …) dummy; unused unless needs_full_grad
        return self._round_step(state, batches, ids, mask, full_batches)

    # -------------------------------------------------- data-driven round
    def _prepare_round(self, state: FedState, client_x, client_y):
        """Per-round setup shared VERBATIM by ``run_round`` and the
        ``run_rounds`` scan body: rng threading, cohort sampling, minibatch
        and (MimeLite) full-batch gathers.  One implementation is what
        makes the two paths' trajectories identical — don't fork it.

        Returns (state-with-advanced-rng, batches, ids, mask, full).
        """
        rng, k_cohort, k_batch = jax.random.split(state.rng, 3)
        ids, mask = sample_cohort(k_cohort, self.cfg)
        raw = gather_round_batches(
            client_x, client_y, k_batch, ids, self.cfg.local_steps, self.batch_size
        )
        batches = self._to_loss_batches(raw)
        if self.algo.needs_full_grad:
            full = self._to_loss_batches(
                gather_full_client_batch(client_x, client_y, ids)
            )
        else:
            # (C, B, ...) dummy with the right treedef for vmap; unused
            # unless needs_full_grad
            full = jax.tree_util.tree_map(lambda b: b[:, 0], batches)
        return state._replace(rng=rng), batches, ids, mask, full

    def run_round(self, state: FedState, data) -> Tuple[FedState, RoundMetrics]:
        """Samples cohort + minibatches from a FederatedData and steps."""
        state, batches, ids, mask, full = self._prepare_round(
            state, data.client_x, data.client_y
        )
        return self.round_step(state, batches, ids, mask, full)

    # -------------------------------------------------- fused multi-round
    def run_rounds(self, state: FedState, data, n_rounds: int) -> Tuple[FedState, RoundMetrics]:
        """Execute ``n_rounds`` communication rounds as ONE jitted lax.scan.

        Cohort sampling and minibatch drawing happen inside the scan body
        (no host round-trips), the carried ``FedState`` is donated, and the
        per-round metrics come back stacked with a leading ``(n_rounds,)``
        axis.  Numerically equivalent to calling ``run_round`` ``n_rounds``
        times (same rng threading, same ``_round_step_impl``); the
        equivalence test in tests/test_run_rounds.py holds all algorithms
        to that.  Caveat for sub-f32 param leaves on the flat plane: this
        fused form carries one f32 master plane across all N rounds and
        rounds to the leaf dtype once at the end, while ``run_round``
        re-rounds at every round boundary — bf16 trajectories agree to
        bf16 precision per round, not bitwise (f32 params are exact either
        way).

        The input ``state`` may be donated to the computation — use the
        returned state, not the argument, afterwards.
        """
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        return self._run_rounds(state, data.client_x, data.client_y, n_rounds=n_rounds)

    def _run_rounds_impl(self, state: FedState, client_x, client_y, n_rounds: int):
        self.run_rounds_traces += 1  # python side effect: counts traces only

        if self.cfg.use_flat_plane:
            # ravel ONCE for the whole N-round program; the scan carries
            # (P,)/(N,P) planes and unravels once at the end
            spec = FlatSpec.from_tree(state.params)
            fstate = self._ravel_state(state, spec)

            def flat_body(st, _):
                st, batches, ids, mask, full = self._prepare_round(st, client_x, client_y)
                return self._flat_round_step(st, batches, ids, mask, full, spec)

            fstate, metrics = jax.lax.scan(flat_body, fstate, None, length=n_rounds)
            return self._unravel_state(fstate, spec), metrics

        def body(st, _):
            st, batches, ids, mask, full = self._prepare_round(st, client_x, client_y)
            return self._tree_round_step(st, batches, ids, mask, full)

        return jax.lax.scan(body, state, None, length=n_rounds)

    @staticmethod
    def _to_loss_batches(raw):
        """{"x","y"} → loss_fn batch dict (pass-through for custom dicts).

        Must stay traceable: ``run_rounds`` calls it inside a jitted scan.
        """
        return raw


def _tree_norm(t):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(t)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.float32(0.0)


def _flat_norm(x):
    """‖x‖₂ of one flat plane — same formulation as ``_tree_norm`` so flat
    and tree metrics agree bitwise for single-buffer input."""
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


# ----------------------------------------------------------------------
# evaluation
# ----------------------------------------------------------------------


def make_eval_fn(predict_fn: Callable[[Any, Any], jax.Array], batch_size: int = 1000):
    """predict_fn(params, x) -> logits.  Returns eval(params, x, y) -> acc.

    Device-resident: the whole test set is evaluated by ONE jitted
    ``lax.map`` over padded ``(n_batches, B, …)`` batches — a single
    dispatch and a single device→host sync per call, instead of one of each
    per 1000 examples.  (The old per-batch python loop stalled ``fed_train``
    between fused ``run_rounds`` chunks.)  Padding rows carry zero weight,
    so the returned accuracy is exact for any n.  Retraces only when the
    padded shape changes, i.e. once per dataset.
    """

    @jax.jit
    def _evaluate(params, xb, yb, wb):
        def one(args):
            x, y, w = args
            logits = predict_fn(params, x)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32) * w)

        hits = jax.lax.map(one, (xb, yb, wb))
        return jnp.sum(hits) / jnp.sum(wb)

    def evaluate(params, x, y):
        x, y = jnp.asarray(x), jnp.asarray(y)
        n = x.shape[0]
        nb = max(1, -(-n // batch_size))
        pad = nb * batch_size - n
        xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        yp = jnp.pad(y, ((0, pad),))
        w = (jnp.arange(nb * batch_size) < n).astype(jnp.float32)

        def rs(a):
            return a.reshape((nb, batch_size) + a.shape[1:])

        return float(_evaluate(params, rs(xp), rs(yp), rs(w)))

    return evaluate
