"""The federated round engine.

One communication round (Algorithm 2 of the paper) is a single jitted —
and, on a mesh, pjit-sharded — program:

    sample cohort  →  broadcast (x_t, Δ_t)  →  vmap over clients of
    [lax.scan over K local steps]  →  masked-mean aggregate  →  server update

The engine is architecture-agnostic: it only sees ``loss_fn(params, batch)``
(DESIGN.md §7 — FedCM is optimizer-level).  On a TPU mesh the cohort axis is
sharded over ("pod","data") and each client's parameters may additionally be
tensor-sharded on "model"; the aggregation mean lowers to an all-reduce over
the cohort axes — the server/client message pattern of the paper becomes
collectives (DESIGN.md §3).

Participation models (§6.1 of the paper):

* ``fixed``      — exactly ``cohort_size`` clients, uniform w/o replacement.
* ``bernoulli``  — every client independently with prob cohort_size/N.  For a
  jit-static shape we draw the cohort count s ~ Binomial(N, p) (clipped to a
  capacity), take the first s entries of a random permutation, and mask the
  rest; conditioned on s this equals independent-Bernoulli participation.
  The capacity is a mean + ``cfg.bernoulli_capacity_sigma``·sd tail bound;
  rounds whose draw exceeds it are CLIPPED to capacity and the overflow
  count is surfaced as ``RoundMetrics.n_clipped`` (never silently dropped).

Streaming availability sampler (``sample_cohort_ex``): selection is driven
by a pluggable availability process on ``FedConfig`` —
``repro.data.population.availability_log_weights`` maps ``cfg.availability``
("uniform" | "zipf" | "diurnal") to per-client log weights, non-uniform
draws go through Gumbel top-k without replacement, ``bernoulli``
participation thins by per-client inclusion probabilities, and
``cfg.dropout_rate`` models stragglers by mask-only thinning AFTER
selection.  The uniform process keeps the legacy two-key draw
bitwise-identical, so pre-existing trajectories are unchanged.

Population store (``cfg.population_store``): per-client state planes
(scaffold c_i, feddyn λ_i) either live as the stacked ``(N, P)`` device
plane ("resident" — the bitwise oracle) or in a sparse host-memory
``repro.data.population.HostPopulationStore`` ("host").  The host path
runs ``run_rounds_store`` / ``run_rounds_store_async``: a host loop around
the SAME jitted round pieces, with a pure ``(C, P)`` gather-on-participation
before each round step and a scatter-on-fold after — device memory scales
with the cohort, host memory with the touched-client set, and N=1e6 is a
literal config value.  Store-backed rounds are f32-BITWISE against the
resident engine at matched cohorts (tests/test_population.py): the round
math is the same code, parameterized by ``cohort_rows``/``emit_rows``
instead of the resident plane.

Payload accounting mirrors §4.2: FedCM doubles only the DOWNLINK (x_t plus
Δ_t); uplink is one delta — unchanged from FedAvg.  SCAFFOLD pays both ways
(c down, Δc_i up); MimeLite pays an extra full-batch gradient up.

Fused multi-round engine (``run_rounds``): the paper's headline results
(Table 1, §6.1) need hundreds to thousands of rounds, and dispatching each
round as its own jit call — with host-side cohort sampling in between —
makes round *dispatch* the wall-clock bottleneck long before the math is.
``run_rounds(state, data, n_rounds)`` therefore executes N rounds as a
single ``jax.lax.scan`` whose body does everything a round needs on-device:

* cohort sampling (``sample_cohort``) from the carried rng,
* synthetic-data minibatch gathers (``repro.data.pipeline.gather_round_batches``,
  pure array-in/array-out so it traces),
* the round step itself (the same ``_round_step_impl`` the per-round path
  jits, so the two paths are numerically one implementation).

The carried ``FedState`` is donated (``donate_argnums``), so server params/
momentum/client-state buffers are updated in place across all N rounds, and
per-round ``RoundMetrics`` come back stacked ``(n_rounds, ...)``.  The
``client_sharding`` constructor arg pins the cohort axis of batches and
client states via sharding constraints in both the per-round and fused
paths.

Flat parameter plane (``cfg.use_flat_plane``, default on): params and
server momentum/second-moment are ravelled ONCE per ``run_rounds`` call
(``repro.core.flat.FlatSpec``) into contiguous ``(P,)`` buffers that carry
the round-scope state; every round-scope reduction lands flat — masked
cohort means concatenate per-leaf contractions into ONE ``(P,)`` buffer,
the server update and metric norms are single fused ops, and stateless
algorithms never materialize the zero state/extra planes the tree path
builds and aggregates.  The K-step local scan itself keeps the LEAF form
(model autodiff is per-leaf; a flat↔tree conversion per step measures
2-3× slower on CPU XLA), so its body is bitwise the tree path's.  Under
``use_fused_kernel`` the scan flips to the flat ``(P,)`` carry — the
kernels consume flat buffers directly, per-client control variates ride an
``(N, P)`` plane (ONE gather/scatter), and the per-step concatenate/split
of the PR-1 kernel route disappears entirely.  The tree path
(``use_flat_plane=False``) is retained verbatim as the numerical oracle
(tests/test_flat.py) and for tensor-sharded lowering (launch/fed_dryrun).

The algorithm layer is the declarative registry (``repro.core.registry``):
the engine consumes ONE ``AlgorithmSpec`` per run — its direction
coefficient row drives the local steps, its fold coefficient rows (+
optional pure post-step) drive the round close, and its state-plane flags
drive ``FedState`` allocation and payload accounting.  The engine contains
zero per-algorithm branches; registering a new spec makes it runnable on
every path below.

``cfg.use_fused_kernel`` routes the update phase through Pallas — flat
plane only: the per-local-step direction via ``kernels/fed_direction``
(the spec's ``DirectionRow`` becomes the SMEM coefficient vector) and the
round-close masked-mean + momentum EMA + param step via
``kernels/server_update`` (one launch per ``FoldPass``; specs with a
``server_fn`` escape hatch fall back to the jnp reduction).  The legacy
whole-tree ``fedcm_update`` launch is retired from the tree path (its
``ref.py`` stays as a blend oracle); on the tree path the flag is inert.
Each kernel's ``ref.py`` is its oracle.

Async pipelined engine (``run_rounds_async``): overlapping cohorts as ONE
``lax.scan`` whose carry adds a static depth-D ring of in-flight cohort
uplinks (``repro.core.flat.CohortUplink``) and an S-deep momentum delay
line.  Iteration t launches a cohort against (current params,
S-rounds-stale momentum), rotates it into the ring, and folds the uplink
launched D−1 iterations ago through the staleness-discount-extended fused
server kernel.  ``(D=1, S=0)`` reproduces ``run_rounds`` exactly; eval can
ride inside the scan at an ``eval_every`` cadence (padded ``lax.map``) so
train-with-eval is one jitted program.

Cohort-parallel execution (``cohort_mesh`` / ``cfg.cohort_shard``): a
``("clients",)`` mesh turns the round SPMD over the client axis.  The
cohort phase runs inside ``shard_map`` — each device owns C/num_shards
clients end-to-end (local-step scans, ``fed_direction`` launches, state
gathers all device-local; ragged cohorts pad with zero-weight rows AFTER
the gathers so the rng stream is untouched) — and the server fold lowers
to the scattered kernel (``kernels/server_update/ops.scatter_fold``):
``all_to_all`` transposes the ``(C, P)`` uplink planes to plane-column
shards, each device reduces the COMPLETE cohort for its columns in the
unsharded reduction order, runs the spec's fold rows on its ``x``/``m``
chunks, and ``all_gather`` rebuilds the replicated planes.  That
transpose-first decomposition (NOT ``psum_scatter``, which would
re-associate the f32 sum) plus the server kernel's ≥2-step grid floor is
what keeps sharded execution f32-BITWISE against the unsharded engine —
for every registered algorithm, sync and async
(tests/test_cohort_shard.py).  Under ``run_rounds_async`` the ring
carries client-sharded planes, so the fold's collective sits D−1 rounds
behind the launch it consumes — the latency the overlap hides.  Flat +
kernel path only; the spec's ``server_post_fn`` runs replicated after
the gather, and ``server_fn`` escape hatches get scattered means
(``repro.core.flat.cohort_mean_scatter``) into a replicated escape.

Fault tolerance (``cfg.fault`` / ``cfg.min_quorum``): faults are pure
config data (``repro.configs.base.FaultConfig``, drawn by
``repro.core.faults`` keyed on (seed, absolute round, client id)) spliced
between launch and fold on every path — uplink drops and straggler
deadlines thin the ``(C,)`` mask, payload corruption (NaN/Inf planes,
scaled bit-noise) rewrites delta rows, and a quarantine pass zeroes the
fold-weight row AND sanitizes the payload rows of any non-finite (or
norm-outlier) uplink so 0·NaN never reaches a reduction.  Degradation is
graceful by construction: every masked-mean denominator is guarded
(``max(n_active, 1)``), and a round whose surviving cohort falls below
``max(1, cfg.min_quorum)`` becomes a no-op — params/momentum selected
through unchanged, client-state writes suppressed — surfaced as
``RoundMetrics.quorum_skipped`` next to ``n_dropped`` / ``n_quarantined``
/ ``n_retries`` (host-store gather/scatter retries with capped
exponential backoff).  ``fault=None`` traces none of this and stays
f32-bitwise against the fault-free engine; in the async ring, faulted
planes ride the D−1 rounds to their fold like any other uplink.

Uplink compression (``cfg.compression`` / a spec's ``uplink_compression``):
wire encoding is pure config/spec data
(``repro.configs.base.CompressionConfig``, realized by
``repro.core.compress``) spliced between fault injection and fold on
every path.  Stochastic-rounded int8 and bf16 planes reach the server
fold COMPRESSED — the fused ``dequant_server_update`` kernel dequantizes
inside the accumulation pass, the async ring carries the compressed
representation (4–8× less in-flight memory at depth D), and the
cohort-sharded ``all_to_all`` moves int8/bf16 payloads instead of f32.
Top-k sparsification applies to the delta plane only, with error
feedback: the unsent remainder accumulates per client in
``FedState.residuals`` (resident ``(N, P)``) or a host residual store,
and joins that client's next uplink.  ``compression=None`` traces none
of this and stays f32-bitwise against the pre-compression engine;
payload accounting (``RoundMetrics.bytes_up``) reflects the active
encoding.
"""
from __future__ import annotations

import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import CompressionConfig, FedConfig
from repro.core.algorithms import (
    Algorithm,
    ClientOutputs,
    FlatClientOutputs,
    ServerState,
    client_state_init,
    get_algorithm,
    server_init,
    sparse_client_finalize,
)
from repro.core.compress import (
    QPlane,
    TopKPlane,
    as_qplane,
    compress_plane,
    decompress_plane,
    error_feedback_topk,
    plane_key,
    round_key,
    uplink_bytes_per_client,
    validate_compression,
)
from repro.core.faults import (
    corrupt_uplink,
    fault_masks,
    rows_finite,
    rows_sqnorm,
    zero_rows,
)
from repro.core.flat import (
    CohortUplink,
    FlatSpec,
    cohort_mean_scatter,
    pad_cohort,
    ring_push,
)
from repro.data.pipeline import gather_full_client_batch, gather_round_batches
from repro.data.population import (
    POPULATION_STORES,
    TransientStoreError,
    availability_log_weights,
    make_population_store,
)
from repro.kernels.fed_direction.ops import flat_direction_step
from repro.kernels.server_update.ops import fused_fold, scatter_fold
from repro.sharding.rules import (
    COHORT_AXIS,
    cohort_axis_size,
    cohort_uplink_specs,
    padded_cohort,
)
from repro.utils.compat import shard_map
from repro.utils.trees import (
    ravel_leaves,
    tree_axpy,
    tree_bytes,
    tree_zeros_like,
)


class FlatMaster(NamedTuple):
    """f32 master planes carried ACROSS flat-engine calls for sub-f32 trees.

    The flat engine computes on f32 ``(P,)`` planes and rounds back to the
    leaf dtypes on exit; without this cache a bf16 model would re-round at
    every ``run_round`` boundary while ``run_rounds`` rounds once at the
    end (the divergence PR 2 documented).  ``FederatedEngine.init`` attaches
    it whenever the tree has non-f32 leaves, ``_ravel_state`` resumes from
    it, and ``_unravel_state`` refreshes it — so N× ``run_round`` agrees
    with ``run_rounds(N)`` to the same cross-program f32 noise as an f32
    model (measured ≲2e-5; the legacy behaviour differed by a bf16 ulp,
    ~4e-3, at EVERY boundary — the regression test pins the gap).  ``None``
    for all-f32 trees (the ravel is exact, nothing to preserve) and on the
    tree path."""

    params: jax.Array  # (P,) f32
    second_moment: Optional[jax.Array]  # (P,) f32, or None (spec doesn't need v)
    client_states: Optional[jax.Array]  # (N, P) f32 (kernel path) or None


class FedState(NamedTuple):
    """Engine state.  ``master`` is an INTERNAL cache: for sub-f32 trees it
    holds the un-rounded f32 planes that ``params``/``server.second_moment``
    /``client_states`` are rounded views OF, and the engine resumes from it
    in preference to re-ravelling the leaves.  If you replace any of those
    fields externally (checkpoint restore, weight surgery), drop the cache
    — ``state._replace(params=new, master=None)`` — or the next round will
    silently continue from the cached planes instead of your edit."""

    params: Any
    server: ServerState
    client_states: Any  # stacked (N, …) or None
    rng: jax.Array
    master: Optional[FlatMaster] = None  # flat-engine f32 master planes
    # top-k error-feedback residuals: resident (N, P) f32, or None (no
    # top-k compression / host residual store carries the rows instead)
    residuals: Optional[jax.Array] = None


class RoundMetrics(NamedTuple):
    loss: jax.Array  # mean local training loss over cohort × K steps
    n_active: jax.Array
    delta_norm: jax.Array  # ‖mean Δ_i‖
    momentum_norm: jax.Array  # ‖Δ_t‖ (server momentum entering the round)
    eta_l: jax.Array
    bytes_down: jax.Array  # server→clients this round (f32 elements × 4)
    bytes_up: jax.Array  # clients→server this round
    # bernoulli draws beyond the static cohort capacity this round (clipped
    # clients sat out; 0 under "fixed" and at the default 5σ capacity)
    n_clipped: jax.Array = None
    # ---- fault-tolerance counters (0 everywhere when cfg.fault is None) --
    n_dropped: jax.Array = None  # uplinks lost to drop_rate / deadline
    n_quarantined: jax.Array = None  # uplinks zeroed by the quarantine pass
    n_retries: jax.Array = None  # host-store gather/scatter retries
    quorum_skipped: jax.Array = None  # 1.0 when survivors < max(1, min_quorum)


class AsyncRoundMetrics(NamedTuple):
    """Per-iteration metrics of the pipelined scan.  ``loss``/``n_active``/
    ``eta_l``/``momentum_norm`` describe the cohort LAUNCHED this round
    (client compute happens at launch); ``delta_norm``/``folded`` describe
    the fold — 0 during the D−1 warmup rounds while the pipeline fills.
    ``eval_acc`` is −1.0 on rounds where the in-scan eval didn't run."""

    loss: jax.Array
    n_active: jax.Array
    delta_norm: jax.Array
    momentum_norm: jax.Array  # ‖broadcast momentum‖ as the CLIENTS saw it
    eta_l: jax.Array
    bytes_down: jax.Array
    bytes_up: jax.Array
    folded: jax.Array  # 0/1: did this round fold a completed cohort
    eval_acc: jax.Array  # in-scan eval accuracy, −1.0 when not evaluated
    n_clipped: jax.Array = None  # capacity-overflow clips of the LAUNCHED cohort
    # fault counters: n_dropped/n_quarantined describe the LAUNCHED cohort
    # (faults hit the uplink at launch and ride the ring to the fold);
    # quorum_skipped describes the FOLD (0 during warmup)
    n_dropped: jax.Array = None
    n_quarantined: jax.Array = None
    n_retries: jax.Array = None
    quorum_skipped: jax.Array = None


def metrics_to_host(ms: NamedTuple) -> Dict[str, np.ndarray]:
    """Surface a (stacked) metrics tuple off-device in ONE transfer.

    A fused chunk returns ``RoundMetrics`` of stacked ``(chunk,)`` arrays;
    reading them field-by-field with ``float(...)`` costs one device sync
    each.  This fetches every non-None field in a single ``device_get``
    of the whole tuple — the ONLY host sync telemetry adds per chunk
    (REP003 stays clean: this is host-side driver code, never reachable
    from the jitted round program) — and returns ``{field: np.ndarray}``.
    Scalar fields come back as shape-``(1,)`` so callers can treat
    per-round and single-round metrics uniformly."""
    named = [(f, v) for f, v in zip(ms._fields, ms) if v is not None]
    fetched = jax.device_get(tuple(v for _, v in named))
    return {
        f: np.atleast_1d(np.asarray(v)) for (f, _), v in zip(named, fetched)
    }


def cohort_capacity(cfg: FedConfig) -> int:
    """Static cohort axis length. ``fixed``: exactly S. ``bernoulli``: a
    Binomial(N, p) tail bound — mean + ``cfg.bernoulli_capacity_sigma``·σ,
    clipped to N.  At the default 5σ, p(overflow) < 3e-7; an overflow clips
    the round's cohort and is COUNTED in ``RoundMetrics.n_clipped`` (the
    pre-store engine truncated silently — the bias the clip metric and its
    regression test now pin)."""
    if cfg.participation == "fixed":
        return cfg.cohort_size
    p = cfg.cohort_size / cfg.num_clients
    sd = math.sqrt(cfg.num_clients * p * (1 - p))
    sigma = float(getattr(cfg, "bernoulli_capacity_sigma", 5.0))
    return min(cfg.num_clients, int(math.ceil(cfg.cohort_size + sigma * sd)))


def sample_cohort_ex(rng, cfg: FedConfig, t=None):
    """Streaming availability sampler.  Returns
    ``(client_ids (C,), active_mask (C,), n_clipped ())`` with
    C = cohort_capacity and ``n_clipped`` the number of bernoulli draws
    beyond capacity this round (those clients sit the round out).

    Selection is driven by ``cfg.availability``
    (``repro.data.population.availability_log_weights``): uniform keeps the
    legacy two-key draw BITWISE (same splits, same ``jax.random.choice`` /
    scalar-p bernoulli branch — pre-existing trajectories are unchanged);
    non-uniform processes select via Gumbel top-k without replacement and
    thin by per-client inclusion probabilities ``clip(S·softmax(logw), 0, 1)``
    under ``participation="bernoulli"``.  ``cfg.dropout_rate`` then drops
    each selected client independently (straggler model) — mask-only, after
    selection, keeping ≥1 active client unless ``cfg.allow_empty_cohort``
    lets the round come up empty (it degrades to a guarded no-op fold).
    ``t`` is the round counter (may be traced; only the diurnal process
    reads it)."""
    cap = cohort_capacity(cfg)
    dropout = float(getattr(cfg, "dropout_rate", 0.0))
    if dropout > 0.0:
        k_perm, k_n, k_drop = jax.random.split(rng, 3)
    else:  # legacy split — keeps dropout-free trajectories bitwise
        k_perm, k_n = jax.random.split(rng)
        k_drop = None
    logw = availability_log_weights(cfg, t)
    if logw is None:  # uniform: the legacy draw, verbatim
        ids = jax.random.choice(k_perm, cfg.num_clients, (cap,), replace=False)
    else:
        # Gumbel top-k = weighted sampling without replacement
        g = jax.random.gumbel(k_perm, (cfg.num_clients,), dtype=jnp.float32)
        _, ids = jax.lax.top_k(logw + g, cap)
        ids = ids.astype(jnp.int32)
    n_clipped = jnp.int32(0)
    if cfg.participation == "fixed":
        mask = jnp.ones((cap,), bool)
    else:
        if logw is None:
            p = cfg.cohort_size / cfg.num_clients
            draws = jax.random.bernoulli(k_n, p, (cfg.num_clients,))
        else:
            q = jnp.clip(cfg.cohort_size * jax.nn.softmax(logw), 0.0, 1.0)
            draws = jax.random.bernoulli(k_n, q)
        s_raw = jnp.sum(draws).astype(jnp.int32)
        allow_empty = bool(getattr(cfg, "allow_empty_cohort", False))
        s = jnp.clip(s_raw, 0 if allow_empty else 1, cap)
        mask = jnp.arange(cap) < s
        n_clipped = jnp.maximum(s_raw - cap, 0)
    if dropout > 0.0:
        keep = jax.random.bernoulli(k_drop, 1.0 - dropout, (cap,))
        kept = mask & keep
        if getattr(cfg, "allow_empty_cohort", False):
            # empty rounds degrade to guarded no-op folds — let them happen
            mask = kept
        else:
            # legacy guard: a fully-dropped cohort keeps its first client
            first = mask & (jnp.arange(cap) == jnp.argmax(mask))
            mask = jnp.where(jnp.any(kept), kept, first)
    return ids, mask, n_clipped


def sample_cohort(rng, cfg: FedConfig, t=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (client_ids (C,), active_mask (C,)) with C = cohort_capacity.
    Back-compat wrapper over ``sample_cohort_ex`` (drops the clip count)."""
    ids, mask, _ = sample_cohort_ex(rng, cfg, t)
    return ids, mask


def local_learning_rate(cfg: FedConfig, t) -> jax.Array:
    """Appendix C.2: exponential per-round decay of η_l."""
    return jnp.float32(cfg.eta_l) * jnp.float32(cfg.eta_l_decay) ** t.astype(jnp.float32)


def _where_tree(ok, new, old):
    """Per-leaf ``where(ok, new, old)`` — the quorum/no-op-round select.
    Bitwise inert on healthy rounds: ``jnp.where(True, new, old)`` IS
    ``new``.  ``None`` (unallocated planes) passes through."""
    if new is None:
        return None
    return jax.tree_util.tree_map(lambda a, b: jnp.where(ok, a, b), new, old)


# ----------------------------------------------------------------------
# client update
# ----------------------------------------------------------------------


def client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    params,  # x_t (broadcast)
    bcast_momentum,  # Δ_t (or c for scaffold; zeros otherwise)
    client_state,  # this client's c_i / λ_i slice (or zeros pytree)
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
) -> Tuple[ClientOutputs, jax.Array]:
    """One client's K local steps.  Returns (outputs, mean local loss).

    The spec's declarative direction row consumes the broadcast buffer and
    the client's state slice as NAMED streams — no per-algorithm packing
    (the old scaffold ``(c_i, c)`` tuple) happens here.
    """
    x0 = params

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        v = algo.direction(cfg, bcast_momentum, client_state, x, x0, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0, batches,
                              unroll=cfg.local_steps if unroll else 1)

    full_grad = tree_zeros_like(x0)
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0, full_grad_batch)

    outs = algo.client_finalize(cfg, x0, xK, client_state, bcast_momentum,
                                eta_l, full_grad)
    return outs, jnp.mean(losses)


def flat_client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    spec: FlatSpec,
    x_t: jax.Array,  # (P,) broadcast round anchor (flat)
    x0_tree,  # the same anchor as a tree (unravelled ONCE per round)
    m_t: jax.Array,  # (P,) Δ_t (or c for scaffold; zeros otherwise)
    m_tree,  # its tree view (unravelled ONCE per round)
    cst_tree_i,  # this client's c_i / λ_i as a tree slice, or None
    cst_flat_i,  # the same as a (P,) plane row, or None
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
):
    """One client's K local steps, finalized onto flat-engine outputs.

    jnp path: the local scan carries the LEAF form — model autodiff is
    per-leaf anyway, and a flat↔tree conversion per step would add unfused
    ops to the hottest loop (measured ~2-3× slower on CPU XLA) — so the
    step body is bitwise the tree path's, and the client's outputs stay
    leaf trees with ``None`` for unused planes
    (``sparse_client_finalize``).  The engine then reduces them straight to
    flat ``(P,)`` MEANS — the full ``(C, P)`` cohort plane is never
    materialized (a batched concatenate costs more than the per-leaf
    contractions it would save).

    ``cfg.use_fused_kernel`` flips the scan onto the flat ``(P,)`` carry
    instead: the ``fed_direction`` kernel consumes flat buffers directly
    (no per-step concatenate/split — the loss unravels the plane by
    slicing, which fuses on TPU where this path is aimed) and the outputs
    ARE ``(P,)`` planes, giving the ``(C, P)`` delta plane the fused
    ``server_update`` kernel wants for free.
    """
    if cfg.use_fused_kernel:
        def flat_loss(flat, batch):
            return loss_fn(spec.unravel(flat), batch)

        def step(x, batch):
            loss, g = jax.value_and_grad(flat_loss)(x, batch)
            if cfg.weight_decay:
                g = cfg.weight_decay * x + g
            x = flat_direction_step(algo, cfg, x, g, m_t, cst_flat_i, x_t, eta_l)
            return x, loss

        xK_flat, losses = jax.lax.scan(step, x_t, batches,
                                       unroll=cfg.local_steps if unroll else 1)
        full_grad = None
        if algo.needs_full_grad:
            assert full_grad_batch is not None
            full_grad = jax.grad(flat_loss)(x_t, full_grad_batch)
        outs = sparse_client_finalize(algo, cfg, x_t, xK_flat, cst_flat_i,
                                      m_t, eta_l, full_grad)
        return outs, jnp.mean(losses)

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        v = algo.direction(cfg, m_tree, cst_tree_i, x, x0_tree, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0_tree, batches,
                              unroll=cfg.local_steps if unroll else 1)
    full_grad = None
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0_tree, full_grad_batch)
    outs = sparse_client_finalize(algo, cfg, x0_tree, xK, cst_tree_i,
                                  m_tree, eta_l, full_grad)
    return outs, jnp.mean(losses)


# ----------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------


class FederatedEngine:
    """Builds the jitted round step for (algorithm, loss_fn, data layout).

    Usage::

        eng = FederatedEngine(cfg, loss_fn)
        state = eng.init(params, rng)
        state, metrics = eng.run_rounds(state, data, n_rounds)   # fused scan
        state, metrics = eng.run_round(state, data)     # one round at a time
        # or, lower-level / dry-runnable:
        state, metrics = eng.round_step(state, batches, ids, mask, full_batches)

    ``client_sharding`` (a ``NamedSharding`` whose spec names the mesh axes
    for the cohort dimension, e.g. ``NamedSharding(mesh, P(("pod","data")))``)
    is applied as a sharding constraint to the leading axis of every
    cohort-stacked array — minibatches, gathered client states, and the
    MimeLite full batches — in both the per-round and fused paths.
    """

    def __init__(
        self,
        cfg: FedConfig,
        loss_fn: Callable[[Any, Any], jax.Array],
        batch_size: int = 50,
        client_sharding: Optional[Any] = None,  # NamedSharding for the cohort axis
        cohort_mesh: Optional[Any] = None,  # Mesh with a "clients" axis
    ) -> None:
        self.cfg = cfg
        self.algo = get_algorithm(cfg.algo)
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.client_sharding = client_sharding
        self.analysis_unroll = False  # dry-run analysis form
        # ---- uplink compression (wire encoding, launch → fold) ----
        # cfg.compression wins; otherwise a spec that declares its own
        # wire format (registry uplink_compression) supplies the default.
        comp = getattr(cfg, "compression", None)
        if comp is None and self.algo.uplink_compression is not None:
            comp = CompressionConfig(kind=self.algo.uplink_compression)
        if comp is not None:
            validate_compression(comp)
            if not cfg.use_flat_plane:
                raise ValueError(
                    "uplink compression is a flat-plane transform (it "
                    "quantizes (C, P) cohort planes) — set "
                    "cfg.use_flat_plane=True (the tree path stays the "
                    "uncompressed oracle)"
                )
        self.compression = comp
        self.residual_population = None  # host store for top-k residuals
        # ---- population store (out-of-core client state) ----
        # "host" keeps per-client state rows in a sparse host store
        # (repro.data.population.HostPopulationStore, created by init());
        # the engine host-loops the SAME jitted round pieces with a (C, P)
        # gather before each step and a scatter after each fold.
        store = getattr(cfg, "population_store", "resident")
        if store not in POPULATION_STORES:
            raise ValueError(
                f"unknown population_store {store!r}; known: {POPULATION_STORES}"
            )
        self.population_store = store
        self.population = None  # HostPopulationStore, attached by init()
        # fail at construction, not at the first sampled round
        availability_log_weights(cfg, t=0)
        if store == "host":
            if not cfg.use_flat_plane:
                raise ValueError(
                    "population_store='host' rides the flat parameter plane "
                    "(the store gathers/scatters contiguous (C, P) rows) — "
                    "set cfg.use_flat_plane=True"
                )
            if cohort_mesh is not None or getattr(cfg, "cohort_shard", 0) > 0:
                raise ValueError(
                    "population_store='host' is host-loop execution and is "
                    "not composable with cohort-parallel shard_map — drop "
                    "cohort_mesh / set cfg.cohort_shard=0"
                )
        # ---- cohort-parallel (SPMD-over-clients) execution path ----
        # a Mesh with a "clients" axis turns every cohort phase into
        # shard_map over that axis: each device owns C/num_shards clients
        # end-to-end and the server fold becomes an explicit
        # reduce-scatter/all-gather (kernels/server_update/ops.scatter_fold).
        # cfg.cohort_shard > 0 is the data-only way to ask for it (the
        # engine builds the mesh over the first N visible devices).
        if cohort_mesh is None and getattr(cfg, "cohort_shard", 0) > 0:
            from repro.launch.mesh import make_cohort_mesh

            cohort_mesh = make_cohort_mesh(cfg.cohort_shard)
        self.cohort_mesh = cohort_mesh
        self._cohort_shards = 1
        if cohort_mesh is not None:
            if not cfg.use_flat_plane:
                raise ValueError(
                    "cohort-parallel execution runs on the flat parameter "
                    "plane — it shards (C, P) uplink planes; set "
                    "cfg.use_flat_plane=True (the tree path stays the "
                    "single-device oracle)"
                )
            if not cfg.use_fused_kernel:
                raise ValueError(
                    "cohort-parallel execution rides the flat+kernel path "
                    "(clients produce (C, P) planes, the fold is the "
                    "scattered server kernel) — set cfg.use_fused_kernel="
                    "True / pass --fused-kernel"
                )
            if client_sharding is not None:
                raise ValueError(
                    "cohort_mesh (shard_map over clients) and "
                    "client_sharding (GSPMD cohort-axis constraints) are "
                    "alternative lowerings of the same axis — pass one"
                )
            self._cohort_shards = cohort_axis_size(cohort_mesh)
        self._round_step = jax.jit(self._round_step_impl)
        # traced once per (shapes, n_rounds) — the compile-count regression
        # test asserts a 100-round run is ONE trace, not 100
        self.run_rounds_traces = 0
        self._run_rounds = jax.jit(
            self._run_rounds_impl,
            static_argnames=("n_rounds",),
            donate_argnums=(0,),
        )
        self.run_rounds_async_traces = 0
        self._run_rounds_async = jax.jit(
            self._run_rounds_async_impl,
            static_argnames=(
                "n_rounds", "pipeline_depth", "staleness", "eval_every",
                "predict_fn", "scan_unroll",
            ),
            donate_argnums=(0,),
        )
        # donate the state only: the pending uplinks are consumed, not
        # updated — most of their buffers have no same-shaped output to
        # alias into and donating them just trips "unusable donation"
        # warnings
        self._drain_async = jax.jit(
            self._drain_async_impl,
            static_argnames=("pipeline_depth",),
            donate_argnums=(0,),
        )

    # -------------------------------------------------- init
    def init(self, params, rng) -> FedState:
        """Allocate the FedState the registered spec requires: the stacked
        per-client planes iff ``needs_client_state``, the second-moment
        plane iff ``needs_second_moment`` — allocation is derived from the
        spec's state-plane flags, never from algorithm names.

        Under ``population_store="host"`` the per-client planes never
        touch the device: ``client_state_init`` returns None and a fresh
        ``HostPopulationStore`` is attached as ``self.population``
        (re-``init`` = a fresh population)."""
        if self.population_store != "resident" and self.algo.needs_client_state:
            self.population = make_population_store(
                self.cfg, FlatSpec.from_tree(params).size
            )
        # top-k error-feedback residuals are a per-client state stream of
        # their own: resident (N, P) zeros, or a second host store whose
        # unwritten rows read as zeros (same init semantics)
        residuals = None
        if self._ef_residuals:
            size = FlatSpec.from_tree(params).size
            if self.population_store == "resident":
                residuals = jnp.zeros(
                    (self.cfg.num_clients, size), jnp.float32
                )
            else:
                self.residual_population = make_population_store(
                    self.cfg, size
                )
        state = FedState(
            params=params,
            server=server_init(params, self.cfg.momentum_dtype,
                               needs_second_moment=self.algo.needs_second_moment),
            client_states=client_state_init(params, self.cfg),
            rng=rng,
            residuals=residuals,
        )
        # flat engine + sub-f32 leaves: attach the f32 master planes up
        # front so every later call sees one stable treedef (no master→
        # no-master retrace) and run_round/run_rounds share one precision
        # contract from round 0
        if self.cfg.use_flat_plane:
            try:
                spec = FlatSpec.from_tree(params)
            except TypeError:  # non-float leaves: flat path will refuse anyway
                return state
            if self._needs_master(spec):
                cst = None
                if state.client_states is not None and self.cfg.use_fused_kernel:
                    cst = spec.ravel(state.client_states, batch_dims=1)
                sm = state.server.second_moment
                state = state._replace(master=FlatMaster(
                    params=spec.ravel(params),
                    second_moment=spec.ravel(sm) if sm is not None else None,
                    client_states=cst,
                ))
        return state

    @staticmethod
    def _needs_master(spec: FlatSpec) -> bool:
        """True when rounding plane→leaves loses bits (any non-f32 leaf)."""
        return any(np.dtype(l.dtype) != np.float32 for l in spec.leaves)

    @property
    def _ef_residuals(self) -> bool:
        """True when top-k compression carries an error-feedback stream."""
        return self.compression is not None and self.compression.kind == "topk"

    # -------------------------------------------------- payload accounting
    def payload_bytes(self, params) -> Dict[str, int]:
        """Per-client per-round communication in bytes (§4.2 discussion)."""
        if self.compression is not None:
            spec = FlatSpec.from_tree(params)
            return self._payload_from_nbytes(spec.nbytes, spec.size)
        return self._payload_from_nbytes(tree_bytes(params))

    def _payload_from_nbytes(self, P: int, size: Optional[int] = None) -> Dict[str, int]:
        """Payload accounting from a total byte count — the flat path charges
        ``FlatSpec.nbytes`` (the wire dtypes), identical to ``tree_bytes``.
        Wire shapes are DERIVED from the spec's state-plane flags (§4.2) via
        ``AlgorithmSpec.wire_uplink_planes`` — the same accounting
        ``fed_train --list-algos`` prints per algorithm.  Under active
        compression the uplink charge is bytes-on-the-wire of the encoded
        planes (``repro.core.compress.uplink_bytes_per_client``; ``size``
        is the plane element count the flat callers provide)."""
        down = P  # x_t always goes down
        if self.algo.needs_momentum_broadcast:
            down += P  # Δ_t (fedcm/mimelite) or c (scaffold)
        # Δ_i always; +Δc_i iff the state plane goes over the wire
        # (SCAFFOLD — feddyn's λ_i never leaves the client); +full-batch
        # gradient iff needs_full_grad (MimeLite)
        if self.compression is not None and size is not None:
            up = uplink_bytes_per_client(
                self.compression, self.algo.wire_uplink_planes, size, P
            )
        else:
            up = P * len(self.algo.wire_uplink_planes)
        return {"down_per_client": down, "up_per_client": up}

    # -------------------------------------------------- cohort sharding
    def _constrain_cohort(self, tree):
        """Pin the leading (cohort) axis of every leaf to ``client_sharding``."""
        if self.client_sharding is None or tree is None:
            return tree
        mesh = self.client_sharding.mesh
        spec = self.client_sharding.spec
        cohort_axes = spec[0] if len(spec) else None

        def pin(a):
            s = NamedSharding(mesh, P(cohort_axes, *([None] * (a.ndim - 1))))
            return jax.lax.with_sharding_constraint(a, s)

        return jax.tree_util.tree_map(pin, tree)

    # -------------------------------------------------- flat plane
    def _ravel_state(self, state: FedState, spec: FlatSpec) -> FedState:
        """Tree state → flat-plane state: the ONE ravel of a run_rounds call.
        Params/second-moment become f32 ``(P,)`` planes and momentum a
        ``momentum_dtype`` plane.  Stacked per-client control variates
        become an ``(N, P)`` plane on the kernel path (whose clients
        produce flat buffers anyway, so gather/scatter are ONE op each);
        the jnp path keeps them in leaf form — its local steps consume
        leaves, and a per-round (C, P) concatenate costs more than the
        per-leaf gather/scatter it would replace.

        A carried ``state.master`` (sub-f32 trees) takes precedence over
        re-ravelling the rounded leaves: that is what makes sequential
        ``run_round`` calls bitwise-continue the f32 trajectory instead of
        re-rounding at every boundary."""
        cfg, mst = self.cfg, state.master
        sm = state.server.second_moment
        fsrv = ServerState(
            # momentum plane and tree share momentum_dtype — ravel is exact,
            # no master needed
            momentum=spec.ravel(state.server.momentum, dtype=cfg.momentum_dtype),
            second_moment=(mst.second_moment if mst is not None
                           else (spec.ravel(sm) if sm is not None else None)),
            round=state.server.round,
        )
        fcst = state.client_states
        if fcst is not None and cfg.use_fused_kernel:
            fcst = (mst.client_states if mst is not None and
                    mst.client_states is not None
                    else spec.ravel(fcst, batch_dims=1))
        params = mst.params if mst is not None else spec.ravel(state.params)
        return FedState(params, fsrv, fcst, state.rng,
                        residuals=state.residuals)

    def _unravel_state(self, fstate: FedState, spec: FlatSpec) -> FedState:
        """Flat-plane state → tree state (leaf shapes AND dtypes restored).
        For sub-f32 trees the un-rounded planes ride along as ``master``."""
        cfg = self.cfg
        fsm = fstate.server.second_moment
        srv = ServerState(
            momentum=spec.unravel(fstate.server.momentum, dtype=cfg.momentum_dtype),
            second_moment=spec.unravel(fsm) if fsm is not None else None,
            round=fstate.server.round,
        )
        cst = fstate.client_states
        cst_is_plane = cst is not None and cfg.use_fused_kernel
        if cst_is_plane:
            cst = spec.unravel(cst)
        master = None
        if self._needs_master(spec):
            master = FlatMaster(
                params=fstate.params,
                second_moment=fstate.server.second_moment,
                client_states=fstate.client_states if cst_is_plane else None,
            )
        return FedState(spec.unravel(fstate.params), srv, cst, fstate.rng,
                        master, residuals=fstate.residuals)

    def _flat_cohort_pass(self, fstate: FedState, batches, ids, mask,
                          full_batches, spec: FlatSpec, m_t, eta_l,
                          cohort_rows=None):
        """The cohort's client phase on the flat plane: gather per-client
        state, vmap the K-local-step update over the cohort.  Shared
        VERBATIM by the sync round (``_flat_round_step``) and the async
        launch (``_launch_async_cohort``) — ``m_t`` is the broadcast buffer
        the clients descend against (the CURRENT momentum for sync, an
        S-rounds-stale one for the pipelined path).

        ``cohort_rows`` (store-backed path) is a pre-gathered ``(C, P)``
        f32 block from the population store, replacing the resident-plane
        gather; the per-client math downstream is identical either way.

        Returns (outs, losses, cohort_cst, cohort_cst_tree): cohort_cst is
        the (C, P) gathered client-state plane on the kernel path,
        cohort_cst_tree its leaf-form counterpart on the jnp path (None
        where unused)."""
        cfg, algo = self.cfg, self.algo
        batches = self._constrain_cohort(batches)

        x_t = fstate.params  # (P,) f32
        # leaf views for the local scan — unravelled ONCE per round (x0 is
        # the scan carry init, so its slices materialize at loop entry; the
        # momentum view is a loop-invariant closure)
        x0_tree = spec.unravel(x_t)
        m_tree = spec.unravel(m_t, dtype=cfg.momentum_dtype)

        cohort_cst = cohort_cst_tree = None
        if algo.needs_client_state:
            if cohort_rows is not None:  # store-backed: rows came from host
                if cfg.use_fused_kernel:
                    cohort_cst = self._constrain_cohort(cohort_rows)
                else:  # leaf form, as the local steps consume it — the
                    # unravel restores leaf dtypes, matching the resident
                    # per-leaf gather bitwise (rows are exact f32 ravels)
                    cohort_cst_tree = self._constrain_cohort(
                        spec.unravel(cohort_rows)
                    )
            elif cfg.use_fused_kernel:  # (N, P) plane: ONE gather
                cohort_cst = self._constrain_cohort(fstate.client_states[ids])
            else:  # leaf form, as the local steps consume it
                cohort_cst_tree = self._constrain_cohort(
                    jax.tree_util.tree_map(lambda a: a[ids], fstate.client_states)
                )
        full = None
        if algo.needs_full_grad:
            full = self._constrain_cohort(full_batches)

        def one_client(cst_tree_i, cst_flat_i, batches_i, full_i):
            return flat_client_update(
                algo, cfg, self.loss_fn, spec, x_t, x0_tree, m_t, m_tree,
                cst_tree_i, cst_flat_i, batches_i, eta_l,
                full_grad_batch=full_i, unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst_tree, cohort_cst, batches, full)
        return outs, losses, cohort_cst, cohort_cst_tree

    # -------------------------------------------------- cohort-parallel
    @property
    def _sharded(self) -> bool:
        return self.cohort_mesh is not None

    def _pad_cohort(self, tree, mode: str = "edge"):
        """Pad the leading cohort axis to a multiple of the mesh's
        ``"clients"`` axis.  Applied AFTER the minibatch/state gathers —
        the rng stream and every real client's inputs stay bitwise those
        of the unsharded round.  Data pads by edge-repeat (pad clients
        compute on a real client's finite inputs — a batch-normalizing
        loss_fn on all-zero input would emit NaN, and ``0 · NaN`` poisons
        the fold); the weight row pads with exact zeros (``mode="zero"``)
        so pad rows never count."""
        target = padded_cohort(cohort_capacity(self.cfg), self._cohort_shards)
        return pad_cohort(tree, target, mode=mode)

    def _sharded_cohort_pass(self, fstate: FedState, batches, ids, mask,
                             full_batches, spec: FlatSpec, m_t, eta_l):
        """The cohort's client phase SPMD over the ``"clients"`` mesh axis:
        each device runs the K-local-step update for its C/num_shards
        clients end-to-end inside ``shard_map`` — sampling gathers happen
        before entry (replicated rng), ``fed_direction`` kernel launches
        stay device-local, and no collective runs until the fold.

        Same contract as ``_flat_cohort_pass`` (kernel-path layout), with
        the cohort axis PADDED to the shard count: ``outs`` planes are
        ``(C_pad, P)`` sharded over clients, ``losses`` is ``(C_pad,)``,
        and ``cohort_cst`` is the UNpadded ``(C, P)`` gather (the
        client-state scatter consumes only real rows)."""
        cfg, algo = self.cfg, self.algo

        cohort_cst = None
        if algo.needs_client_state:
            cohort_cst = fstate.client_states[ids]  # (C, P): ONE gather
        operands = {"batches": self._pad_cohort(batches)}
        if cohort_cst is not None:
            operands["cst"] = self._pad_cohort(cohort_cst)
        if algo.needs_full_grad:
            operands["full"] = self._pad_cohort(full_batches)

        plane_keys = tuple(algo.uplink_planes)

        def shard_body(x_t, m_t, eta_l, operands):
            x0_tree = spec.unravel(x_t)
            m_tree = spec.unravel(m_t, dtype=cfg.momentum_dtype)

            def one_client(cst_i, batches_i, full_i):
                return flat_client_update(
                    algo, cfg, self.loss_fn, spec, x_t, x0_tree, m_t, m_tree,
                    None, cst_i, batches_i, eta_l,
                    full_grad_batch=full_i, unroll=self.analysis_unroll,
                )

            outs, losses = jax.vmap(one_client)(
                operands.get("cst"), operands["batches"], operands.get("full")
            )
            out = {k: getattr(outs, k) for k in plane_keys}
            out["losses"] = losses
            return out

        sh, rep = P(COHORT_AXIS), P()
        out = shard_map(
            shard_body,
            mesh=self.cohort_mesh,
            in_specs=(rep, rep, rep, {k: sh for k in operands}),
            # uplink planes + the per-client loss row shard over clients —
            # derived from the registry's state-plane flags
            out_specs=cohort_uplink_specs(algo, extra=("losses",)),
            check_vma=False,
        )(fstate.params, m_t, eta_l, operands)
        outs = FlatClientOutputs(
            delta=out["delta"],
            state_delta=out.get("state_delta"),
            extra=out.get("extra"),
        )
        # replicate the per-client loss row before the metrics reduce it:
        # summing a clients-sharded (C,) array would lower to per-device
        # partial sums + all-reduce, re-associating the f32 sum away from
        # the unsharded metric (the planes stay sharded — their reductions
        # go through the scattered fold, which preserves order by design)
        losses = jax.lax.with_sharding_constraint(
            out["losses"], NamedSharding(self.cohort_mesh, P())
        )
        return outs, losses, cohort_cst, None

    def _sharded_round_close(self, algo, fsrv, outs, wp, n_active, x_t, eta_l,
                             discount=1.0):
        """``_fused_round_close`` under cohort sharding: the fold rows run
        through the scattered server kernel (``scatter_fold`` inside
        ``shard_map`` — all_to_all to plane columns, device-local
        full-cohort reduce, kernel launch per row, all_gather), and the
        spec's pure post-step then runs on the REPLICATED ``(P,)`` planes
        at the same program level (and with the same shapes) as the
        unsharded close — elementwise posts stay bitwise that way."""
        cfg = self.cfg
        planes = {k: getattr(outs, k) for k in algo.fold_planes}
        nsh = self._cohort_shards

        def fold_body(planes, wp, n_active, x, m, eta_l):
            return scatter_fold(
                algo, cfg, planes, wp / jnp.maximum(n_active, 1.0), n_active,
                x, m, eta_l,
                discount=discount, axis_name=COHORT_AXIS, n_shards=nsh,
            )

        sh, rep = P(COHORT_AXIS), P()
        new_x, new_m, mean_delta = shard_map(
            fold_body,
            mesh=self.cohort_mesh,
            in_specs=({k: sh for k in planes}, rep, rep, rep, rep, rep),
            out_specs=(rep, rep, rep),
            check_vma=False,
        )(planes, wp, n_active, x_t, fsrv.momentum, eta_l)
        return self._close_post(algo, fsrv, new_x, new_m, mean_delta,
                                n_active, eta_l, discount)

    def _close_post(self, algo, fsrv, new_x, new_m, mean_delta, n_active,
                    eta_l, discount):
        """Shared tail of the kernel round close (fused AND scattered):
        adopt the folded momentum, then run the spec's pure post-step on
        the replicated planes with the discount-weighted mean.  ONE
        implementation — the sync/async and sharded/unsharded closes must
        never drift in how γ reaches the post."""
        new_server = fsrv._replace(momentum=new_m)
        if algo.server_post_fn is not None:
            dmean = mean_delta if discount == 1.0 else discount * mean_delta
            new_x, new_server = algo.server_post_fn(
                self.cfg, new_x, new_server, dmean, n_active, eta_l
            )
        return new_x, new_server, mean_delta

    def _sharded_means(self, outs, wp, n_active):
        """Masked cohort means of every uplink plane as scattered
        reductions (``cohort_mean_scatter`` inside ``shard_map``) — the
        sharded analog of the kernel-path ``_masked_pmean`` calls feeding
        a ``server_fn`` escape-hatch spec.  Returns (mean_delta, mean_sd,
        mean_extra) with ``None`` for planes the spec never produced."""
        cfg = self.cfg
        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))
        planes = {k: getattr(outs, k) for k in self.algo.uplink_planes
                  if getattr(outs, k) is not None}
        nsh = self._cohort_shards

        def body(planes, wp, n_active):
            return {k: cohort_mean_scatter(v, wp, n_active, COHORT_AXIS, nsh,
                                           agg_dtype=agg_dt)
                    for k, v in planes.items()}

        sh, rep = P(COHORT_AXIS), P()
        means = shard_map(
            body,
            mesh=self.cohort_mesh,
            in_specs=({k: sh for k in planes}, rep, rep),
            out_specs={k: rep for k in planes},
            check_vma=False,
        )(planes, wp, n_active)
        return means.get("delta"), means.get("state_delta"), means.get("extra")

    # -------------------------------------------------- fault tolerance
    def _quorum_ok(self, n_active):
        """Healthy-round predicate: the server fold applies only when the
        surviving cohort meets ``max(1, cfg.min_quorum)``.  The floor of 1
        is the empty-cohort guard (an all-zero weight row used to
        0/0-poison the masked mean); rounds with n_active ≥ quorum are
        bitwise unaffected (``where(True, new, old)`` is ``new``)."""
        return n_active >= jnp.float32(max(1, getattr(self.cfg, "min_quorum", 0)))

    def _inject_faults(self, t, ids, mask, outs):
        """Apply the configured fault model to one cohort's uplink, between
        launch and fold.  Returns ``(mask, outs, n_dropped, n_quarantined)``.

        Pure mask/plane transforms (repro.core.faults), keyed by
        (fault.seed, absolute round t, client id): drops/deadline thin the
        mask, corruption rewrites delta rows of surviving clients, and the
        quarantine pass both masks out and SANITIZES (exact-zeros) any
        non-finite or norm-outlier row — zeroing is load-bearing because a
        0-weight NaN row still poisons tensordot/scatter reductions.  When
        ``cfg.fault`` is None nothing here is traced: fault-free programs
        are bitwise the pre-fault engine's.  Representation-generic over
        the kernel (C[, pad], P) planes and the jnp/tree (C, leaf…) trees;
        under cohort sharding the plane ops run on padded rows (pad rows
        carry mask=False and are never corrupted or counted)."""
        fault = getattr(self.cfg, "fault", None)
        zero = jnp.float32(0.0)
        if fault is None:
            return mask, outs, zero, zero
        C = mask.shape[0]
        # kernel-path planes under cohort sharding carry C_pad rows
        padded = self._sharded and self.cfg.use_fused_kernel

        def pad_mask(v):
            return self._pad_cohort(v, mode="zero") if padded else v

        plan = fault_masks(fault, t, ids)
        n_dropped = zero
        if fault.drop_rate > 0.0 or fault.deadline > 0.0:
            n_dropped = jnp.sum((mask & plan.drop).astype(jnp.float32))
            mask = mask & ~plan.drop
        if fault.corrupt_rate > 0.0:
            cmask = pad_mask(plan.corrupt & mask)
            nkeys = plan.noise_keys
            if nkeys is not None and padded:
                nkeys = self._pad_cohort(nkeys)  # edge pad; cmask=False there
            outs = outs._replace(
                delta=corrupt_uplink(fault, cmask, nkeys, outs.delta))
        n_quar = zero
        if fault.quarantine:
            rows = (padded_cohort(cohort_capacity(self.cfg),
                                  self._cohort_shards) if padded else C)
            fin = (rows_finite(outs.delta, rows)
                   & rows_finite(outs.state_delta, rows)
                   & rows_finite(outs.extra, rows))
            bad = ~fin
            mask_r = pad_mask(mask)
            if fault.quarantine_norm_mult > 0.0:
                norm = jnp.sqrt(rows_sqnorm(outs.delta, rows))
                act = mask_r & fin
                med = jnp.nanmedian(jnp.where(act, norm, jnp.nan))
                bad = bad | (act & (norm > jnp.float32(
                    fault.quarantine_norm_mult) * med))
            n_quar = jnp.sum((mask_r & bad).astype(jnp.float32))
            outs = outs._replace(
                delta=zero_rows(outs.delta, bad),
                state_delta=zero_rows(outs.state_delta, bad),
                extra=zero_rows(outs.extra, bad),
            )
            mask = mask & ~(bad[:C] if padded else bad)
        return mask, outs, n_dropped, n_quar

    def _store_io(self, fn, *args):
        """Host-store gather/scatter with capped exponential backoff on
        ``TransientStoreError``.  Returns ``(result, n_retries)``.  Retries
        re-invoke the SAME pure operation, so a run that needed retries is
        bitwise-equal to one that didn't."""
        fault = getattr(self.cfg, "fault", None)
        if fault is None:
            return fn(*args), 0
        attempt = 0
        while True:
            try:
                return fn(*args), attempt
            except TransientStoreError:
                if attempt >= fault.store_max_retries:
                    raise
                delay = min(float(fault.store_backoff_cap),
                            float(fault.store_backoff_base) * (2.0 ** attempt))
                if delay > 0.0:
                    time.sleep(delay)
                attempt += 1

    def _masked_pmean(self, x, w, n_active):
        """Masked cohort mean of one uplink, reduced straight to a flat
        ``(P,)`` buffer (quantized to ``cfg.aggregate_dtype`` first, like
        every aggregation path).  jnp path: ``x`` is a (C, *shape) leaf
        tree — contract per leaf and concatenate only the tiny means
        (materializing the full (C, P) plane costs more than it saves).
        Kernel path: ``x`` IS a (C, P) plane — one contraction.  ``None``
        passes through (planes that were never materialized)."""
        if x is None:
            return None
        cfg = self.cfg
        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def leaf_mean(a):
            # max(n, 1) guards the empty cohort (0/0 → NaN would poison
            # params); exact for n ≥ 1, so non-empty rounds are bitwise
            return (
                jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                .astype(jnp.float32) / jnp.maximum(n_active, 1.0)
            )

        if cfg.use_fused_kernel:  # (C, P) plane
            return leaf_mean(x)
        return ravel_leaves(
            [leaf_mean(l) for l in jax.tree_util.tree_leaves(x)], jnp.float32
        )

    # -------------------------------------------------- uplink compression
    def _residual_rows_for(self, fstate: FedState, ids, residual_rows):
        """The cohort's error-feedback residual rows (top-k only): the
        host loop pre-gathers them (``residual_rows``); the resident path
        gathers from ``fstate.residuals`` here.  Padded to the sharded
        cohort with exact-zero rows (pad rows never transmit)."""
        if not self._ef_residuals:
            return None
        rows = residual_rows
        if rows is None:
            if fstate.residuals is None:
                raise ValueError(
                    "topk compression carries an error-feedback residual "
                    "stream — call eng.init(params, rng) so "
                    "FedState.residuals (or the host residual store) is "
                    "allocated before stepping"
                )
            rows = fstate.residuals[ids]
        if self._sharded:
            rows = self._pad_cohort(rows, mode="zero")
        return rows

    def _compress_uplink(self, t, outs, w, residual_rows, spec: FlatSpec,
                         ring: bool = False):
        """Wire-encode the cohort's uplink planes — the splice between
        fault injection and server fold on every path.  Returns
        ``(outs, new_residual_rows)`` (residual rows ``None`` except under
        top-k).  ``compression=None`` returns the uplink UNTOUCHED without
        tracing anything — compression-free programs stay f32-bitwise the
        pre-compression engine's.

        Kernel-fold path: int8/bf16 planes come back as :class:`QPlane`
        and reach the fold COMPRESSED (the fused dequant kernel consumes
        them; under cohort sharding the ``all_to_all`` then moves the
        int8/bf16 payload).  ``state_delta`` is additionally needed dense
        by the client-state scatter, so it is decoded immediately —
        except on the async ring (``ring=True``), where it rides
        compressed until fold time (the in-flight memory win) and
        ``_fold_async_slot`` decodes it.  Top-k sparsifies the delta
        plane only, through the error-feedback accumulator; other wire
        planes ride f32 (sparsifying a state stream without its own
        residual would bias the stored state — the registry refuses specs
        declaring it).

        jnp/server_fn paths: every wire plane round-trips through its
        wire representation to dense (what arrived on the wire IS what
        the oracle folds) and downstream code runs unchanged.  ``w`` is
        the post-fault weight row (padded under sharding) gating the
        error-feedback update: a client that did not transmit keeps its
        residual."""
        comp = self.compression
        if comp is None:
            return outs, None
        cfg, algo = self.cfg, self.algo
        wire = algo.wire_uplink_planes
        key = round_key(comp, t)
        kernel_fold = cfg.use_fused_kernel and algo.server_fn is None

        if not cfg.use_fused_kernel:
            # jnp path: planes are (C, leaf…) trees — encode/decode on the
            # flat representation, hand the dense trees back
            planes = {}
            new_rows = None
            for name in ("delta", "state_delta", "extra"):
                tv = getattr(outs, name)
                if tv is None or name not in wire:
                    continue
                plane = spec.ravel(tv, batch_dims=1)
                if comp.kind == "topk":
                    if name != "delta":
                        continue  # non-delta wire planes ride f32
                    _, recon, new_rows = error_feedback_topk(
                        comp, plane, residual_rows, w, spec.size
                    )
                    dense = recon
                else:
                    dense = decompress_plane(
                        compress_plane(comp, plane, plane_key(key, name))
                    )
                planes[name] = spec.unravel(dense)
            return outs._replace(**planes), new_rows

        planes = {}
        new_rows = None
        for name in ("delta", "state_delta", "extra"):
            pv = getattr(outs, name)
            if pv is None or name not in wire:
                continue
            if comp.kind == "topk":
                if name != "delta":
                    continue  # non-delta wire planes ride f32
                rep, recon, new_rows = error_feedback_topk(
                    comp, pv, residual_rows, w, spec.size
                )
                # the ring carries the sparse rep (k ≪ P in-flight);
                # everything else folds the dense decoded payload
                planes[name] = rep if (ring and kernel_fold) else recon
                continue
            rep = as_qplane(compress_plane(comp, pv, plane_key(key, name)))
            if not kernel_fold:
                # server_fn escape hatch reduces via _masked_pmean: decode
                planes[name] = decompress_plane(rep)
            elif name == "state_delta" and not ring:
                # fold consumes the decoded payload AND the client-state
                # scatter needs the same dense rows — decode once here
                planes[name] = decompress_plane(rep)
            else:
                planes[name] = rep
        return outs._replace(**planes), new_rows

    def _decode_ring_entry(self, entry: CohortUplink, spec: FlatSpec):
        """Decode a ring entry's compressed planes at fold time.  The
        sparse top-k delta densifies (the fold kernels want dense or
        QPlane); a QPlane ``state_delta`` stays compressed for the fold
        (fused dequant pass) — ``_fold_async_slot`` decodes it separately
        where the scatter needs dense rows."""
        if self.compression is None:
            return entry
        if isinstance(entry.delta, TopKPlane):
            entry = entry._replace(
                delta=decompress_plane(entry.delta, spec.size)
            )
        return entry

    def _flat_round_step(self, fstate: FedState, batches, ids, mask,
                         full_batches, spec: FlatSpec, n_clipped=None,
                         cohort_rows=None, emit_rows=False,
                         residual_rows=None):
        """One round entirely on the flat plane: (P,) carry through the
        local-step scan, (C, P) cohort planes through aggregation, (N, P)
        client-state scatter.  Same math as ``_tree_round_step`` — the
        equivalence tests in tests/test_flat.py hold the two bitwise-close.

        Store-backed execution (``population_store="host"``) reuses this
        step verbatim: ``cohort_rows`` replaces the resident-plane gather
        and ``emit_rows=True`` (static) swaps the ``(N, P)`` scatter for
        returning the updated ``(C, P)`` rows as a third output — the host
        loop writes them back to the store."""
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, fstate.server.round)
        x_t = fstate.params  # (P,) f32
        m_t = fstate.server.momentum  # (P,) momentum_dtype
        if cohort_rows is not None:
            outs, losses, cohort_cst, cohort_cst_tree = self._flat_cohort_pass(
                fstate, batches, ids, mask, full_batches, spec, m_t, eta_l,
                cohort_rows=cohort_rows,
            )
        else:
            cohort_pass = (self._sharded_cohort_pass if self._sharded
                           else self._flat_cohort_pass)
            outs, losses, cohort_cst, cohort_cst_tree = cohort_pass(
                fstate, batches, ids, mask, full_batches, spec, m_t, eta_l
            )

        # fault injection + quarantine sit between launch and fold — a
        # no-op (untraced) when cfg.fault is None
        mask, outs, n_dropped, n_quar = self._inject_faults(
            fstate.server.round, ids, mask, outs
        )

        # masked cohort means, reduced straight to flat (P,) buffers
        # (_masked_pmean; unused planes are None — never materialized,
        # never reduced, where the tree path pays for both)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)
        # cohort-parallel: pad rows carry zero weight — trailing +0.0
        # terms keep every reduction bitwise the unsharded one's
        wp = self._pad_cohort(w, mode="zero") if self._sharded else w
        use_kernel = cfg.use_fused_kernel and algo.server_fn is None

        # wire encoding between fault injection and fold — untraced when
        # cfg.compression is None (see _compress_uplink); under sharding
        # the encode runs OUTSIDE shard_map on the full padded planes
        new_res_rows = None
        if self.compression is not None:
            res_rows = self._residual_rows_for(fstate, ids, residual_rows)
            outs, new_res_rows = self._compress_uplink(
                fstate.server.round, outs,
                wp if cfg.use_fused_kernel else w, res_rows, spec,
            )

        fsrv = fstate.server
        if use_kernel and self._sharded:
            new_params, new_server, mean_delta = self._sharded_round_close(
                algo, fsrv, outs, wp, n_active, x_t, eta_l
            )
            new_server = new_server._replace(round=fsrv.round + 1)
        elif use_kernel:
            new_params, new_server, mean_delta = self._fused_round_close(
                algo, fsrv, outs, w, n_active, x_t, eta_l
            )
            new_server = new_server._replace(round=fsrv.round + 1)
        else:
            if self._sharded:  # kernel-path spec with a server_fn escape
                mean_delta, mean_sd, mean_extra = self._sharded_means(
                    outs, wp, n_active
                )
            else:
                mean_delta = self._masked_pmean(outs.delta, w, n_active)
                mean_sd = self._masked_pmean(outs.state_delta, w, n_active)
                mean_extra = self._masked_pmean(outs.extra, w, n_active)
            new_params, new_server = algo.server_update(
                cfg, x_t, fsrv, mean_delta, mean_sd, mean_extra,
                n_active, eta_l,
            )

        # graceful degradation: a below-quorum (or empty) cohort carries
        # params/momentum through unchanged — the guarded denominators
        # already kept the fold finite, the select makes it a no-op (the
        # round counter still advances; client-state writes are
        # suppressed via the zeroed scatter weights)
        ok = self._quorum_ok(n_active)
        new_params = _where_tree(ok, new_params, x_t)
        new_server = new_server._replace(
            momentum=_where_tree(ok, new_server.momentum, fsrv.momentum),
            second_moment=_where_tree(ok, new_server.second_moment,
                                      fsrv.second_moment),
        )
        w_sc = w * ok.astype(jnp.float32)

        # scatter updated client states back (only active cohort members):
        # ONE scatter on the (N, P) plane (kernel path; sharded planes are
        # padded — only real rows scatter) or per-leaf like the tree
        # oracle (jnp path).  Store-backed (emit_rows): the SAME per-row
        # update, emitted as (C, P) rows for the host scatter instead.
        new_cst = fstate.client_states
        rows_out = None
        if algo.needs_client_state:
            if emit_rows:
                if cfg.use_fused_kernel:
                    rows_out = cohort_cst + outs.state_delta * w_sc[:, None]
                else:
                    upd = jax.tree_util.tree_map(
                        lambda a, d: a + d * w_sc.reshape(
                            (-1,) + (1,) * (d.ndim - 1)
                        ).astype(a.dtype),
                        cohort_cst_tree, outs.state_delta,
                    )
                    rows_out = spec.ravel(upd, batch_dims=1)
            elif self._sharded:
                C = ids.shape[0]
                upd = cohort_cst + outs.state_delta[:C] * w_sc[:, None]
                new_cst = fstate.client_states.at[ids].set(upd)
            elif cfg.use_fused_kernel:  # (N, P) plane representation
                upd = cohort_cst + outs.state_delta * w_sc[:, None]
                new_cst = fstate.client_states.at[ids].set(upd)
            else:
                def scatter(a, d):
                    upd = a[ids] + d * w_sc.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                    return a.at[ids].set(upd)

                new_cst = jax.tree_util.tree_map(
                    scatter, fstate.client_states, outs.state_delta
                )

        # the error-feedback residual is CLIENT-side state: it tracks what
        # the client did not transmit, so it updates whenever the client
        # transmitted — independent of the fold-time quorum decision
        new_res = fstate.residuals
        if new_res_rows is not None and new_res is not None and not emit_rows:
            C = ids.shape[0]
            new_res = new_res.at[ids].set(new_res_rows[:C])

        pay = self._payload_from_nbytes(spec.nbytes, spec.size)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * wp) / jnp.maximum(n_active, 1.0),
            n_active=n_active,
            delta_norm=_flat_norm(mean_delta),
            momentum_norm=_flat_norm(m_t),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
            n_clipped=(jnp.float32(0.0) if n_clipped is None
                       else n_clipped.astype(jnp.float32)),
            n_dropped=n_dropped,
            n_quarantined=n_quar,
            n_retries=jnp.float32(0.0),
            quorum_skipped=1.0 - ok.astype(jnp.float32),
        )
        new_state = FedState(new_params, new_server, new_cst, fstate.rng,
                             residuals=new_res)
        if emit_rows:
            C = ids.shape[0]
            res_out = None if new_res_rows is None else new_res_rows[:C]
            return new_state, metrics, rows_out, res_out
        return new_state, metrics

    def _fused_round_close(self, algo, fsrv, outs, w, n_active, x_t, eta_l,
                           discount=1.0):
        """Round-close via the fused server kernel: the spec's fold rows
        execute as ``server_update`` passes over the ``(C, P)`` uplink
        planes (``kernels/server_update/ops.fused_fold``), then the spec's
        optional pure post-step runs on the resulting flat planes —
        array-polymorphic, so FedAdam's preconditioner is the same code on
        both paths.

        ``discount`` is the staleness weight γ the async engine applies to
        folded in-flight cohorts — it rides the kernel's SMEM coefficient
        row (1.0 for the sync path: a f32 multiply by 1.0 is exact).  The
        returned ServerState keeps the caller's round counter (sync bumps
        it, the async fold is launch-aligned)."""
        cfg = self.cfg
        planes = {"delta": outs.delta, "state_delta": outs.state_delta,
                  "extra": outs.extra}
        new_x, new_m, mean_delta = fused_fold(
            algo, cfg, planes, w / jnp.maximum(n_active, 1.0), n_active,
            x_t, fsrv.momentum, eta_l, discount=discount,
        )
        return self._close_post(algo, fsrv, new_x, new_m, mean_delta,
                                n_active, eta_l, discount)

    # -------------------------------------------------- round
    def _round_step_impl(self, state: FedState, batches, ids, mask, full_batches):
        if self.cfg.use_flat_plane:
            spec = FlatSpec.from_tree(state.params)
            fstate = self._ravel_state(state, spec)
            fstate, metrics = self._flat_round_step(
                fstate, batches, ids, mask, full_batches, spec
            )
            return self._unravel_state(fstate, spec), metrics
        return self._tree_round_step(state, batches, ids, mask, full_batches)

    def _tree_round_step(self, state: FedState, batches, ids, mask, full_batches,
                         n_clipped=None):
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, state.server.round)

        batches = self._constrain_cohort(batches)
        full_batches = self._constrain_cohort(full_batches)

        # gather per-client states for the cohort (stale entries untouched)
        if algo.needs_client_state:
            cohort_cst = jax.tree_util.tree_map(lambda a: a[ids], state.client_states)
        else:
            cohort_cst = jax.tree_util.tree_map(
                lambda p: jnp.zeros((ids.shape[0], *p.shape), p.dtype), state.params
            )
        cohort_cst = self._constrain_cohort(cohort_cst)

        def one_client(cst_i, batches_i, full_i):
            return client_update(
                algo, cfg, self.loss_fn, state.params, state.server.momentum,
                cst_i, batches_i, eta_l, full_grad_batch=full_i,
                unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst, batches, full_batches)

        # fault injection + quarantine between launch and fold (untraced
        # when cfg.fault is None — the oracle stays the oracle)
        mask, outs, n_dropped, n_quar = self._inject_faults(
            state.server.round, ids, mask, outs
        )

        # masked cohort mean (bernoulli: only active entries count)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)

        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def mmean(tree):
            # max(n, 1): empty-cohort guard, exact for n ≥ 1
            return jax.tree_util.tree_map(
                lambda a: (
                    jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                    .astype(jnp.float32) / jnp.maximum(n_active, 1.0)
                ),
                tree,
            )

        mean_delta = mmean(outs.delta)
        mean_sd = mmean(outs.state_delta)
        mean_extra = mmean(outs.extra)

        new_params, new_server = algo.server_update(
            cfg, state.params, state.server, mean_delta, mean_sd, mean_extra,
            n_active, eta_l,
        )

        # below-quorum / empty round → no-op fold (see _flat_round_step)
        ok = self._quorum_ok(n_active)
        new_params = _where_tree(ok, new_params, state.params)
        new_server = new_server._replace(
            momentum=_where_tree(ok, new_server.momentum,
                                 state.server.momentum),
            second_moment=_where_tree(ok, new_server.second_moment,
                                      state.server.second_moment),
        )
        w_sc = w * ok.astype(jnp.float32)

        # scatter updated client states back (only active cohort members)
        new_cst = state.client_states
        if algo.needs_client_state:
            def scatter(a, d):
                upd = a[ids] + d * w_sc.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                return a.at[ids].set(upd)

            new_cst = jax.tree_util.tree_map(scatter, state.client_states, outs.state_delta)

        pay = self.payload_bytes(state.params)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * w) / jnp.maximum(n_active, 1.0),
            n_active=n_active,
            delta_norm=_tree_norm(mean_delta),
            momentum_norm=_tree_norm(state.server.momentum),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
            n_clipped=(jnp.float32(0.0) if n_clipped is None
                       else n_clipped.astype(jnp.float32)),
            n_dropped=n_dropped,
            n_quarantined=n_quar,
            n_retries=jnp.float32(0.0),
            quorum_skipped=1.0 - ok.astype(jnp.float32),
        )
        return FedState(new_params, new_server, new_cst, state.rng), metrics

    def round_step(self, state, batches, ids, mask, full_batches=None):
        if full_batches is None:
            # zero-size placeholder with the right treedef for vmap
            full_batches = jax.tree_util.tree_map(
                lambda b: b[:, 0], batches
            )  # (C, B, …) dummy; unused unless needs_full_grad
        return self._round_step(state, batches, ids, mask, full_batches)

    # -------------------------------------------------- data-driven round
    def _sample_round(self, rng, client_x, client_y, t):
        """rng threading + cohort sampling + minibatch/(MimeLite) full-batch
        gathers for one round.  ``t`` is the round counter the availability
        process may read (diurnal).  Returns
        (advanced-rng, batches, ids, mask, full, n_clipped)."""
        rng, k_cohort, k_batch = jax.random.split(rng, 3)
        ids, mask, n_clipped = sample_cohort_ex(k_cohort, self.cfg, t)
        raw = gather_round_batches(
            client_x, client_y, k_batch, ids, self.cfg.local_steps, self.batch_size
        )
        batches = self._to_loss_batches(raw)
        if self.algo.needs_full_grad:
            full = self._to_loss_batches(
                gather_full_client_batch(client_x, client_y, ids)
            )
        else:
            # (C, B, ...) dummy with the right treedef for vmap; unused
            # unless needs_full_grad
            full = jax.tree_util.tree_map(lambda b: b[:, 0], batches)
        return rng, batches, ids, mask, full, n_clipped

    def _prepare_round(self, state: FedState, client_x, client_y):
        """Per-round setup shared VERBATIM by ``run_round`` and the
        ``run_rounds`` scan body: rng threading, cohort sampling, minibatch
        and (MimeLite) full-batch gathers.  One implementation is what
        makes the two paths' trajectories identical — don't fork it.

        Returns (state-with-advanced-rng, batches, ids, mask, full,
        n_clipped).
        """
        rng, batches, ids, mask, full, n_clipped = self._sample_round(
            state.rng, client_x, client_y, state.server.round
        )
        return state._replace(rng=rng), batches, ids, mask, full, n_clipped

    def run_round(self, state: FedState, data) -> Tuple[FedState, RoundMetrics]:
        """Samples cohort + minibatches from a FederatedData and steps."""
        if self.population_store == "host":
            state, ms = self.run_rounds_store(state, data, 1)
            return state, jax.tree_util.tree_map(lambda a: a[0], ms)
        state, batches, ids, mask, full, n_clipped = self._prepare_round(
            state, data.client_x, data.client_y
        )
        state, metrics = self.round_step(state, batches, ids, mask, full)
        # round_step's public signature predates the clip metric — stamp it
        # here so run_round/run_rounds report identically
        return state, metrics._replace(n_clipped=n_clipped.astype(jnp.float32))

    # -------------------------------------------------- fused multi-round
    def run_rounds(self, state: FedState, data, n_rounds: int) -> Tuple[FedState, RoundMetrics]:
        """Execute ``n_rounds`` communication rounds as ONE jitted lax.scan.

        Cohort sampling and minibatch drawing happen inside the scan body
        (no host round-trips), the carried ``FedState`` is donated, and the
        per-round metrics come back stacked with a leading ``(n_rounds,)``
        axis.  Numerically equivalent to calling ``run_round`` ``n_rounds``
        times (same rng threading, same ``_round_step_impl``); the
        equivalence test in tests/test_run_rounds.py holds all algorithms
        to that.  Sub-f32 param leaves on the flat plane now agree at the
        SAME tolerance: both paths carry the same f32 master planes
        (``FedState.master``) across round boundaries and only the
        returned leaf views are rounded — ``run_round`` no longer
        re-rounds the carried state each boundary (the PR-2 divergence
        this closes; the bf16 regression test in tests/test_run_rounds.py
        pins the contract).

        The input ``state`` may be donated to the computation — use the
        returned state, not the argument, afterwards.
        """
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        if self.population_store == "host":
            return self.run_rounds_store(state, data, n_rounds)
        return self._run_rounds(state, data.client_x, data.client_y, n_rounds=n_rounds)

    def _run_rounds_impl(self, state: FedState, client_x, client_y, n_rounds: int):
        self.run_rounds_traces += 1  # python side effect: counts traces only

        if self.cfg.use_flat_plane:
            # ravel ONCE for the whole N-round program; the scan carries
            # (P,)/(N,P) planes and unravels once at the end
            spec = FlatSpec.from_tree(state.params)
            fstate = self._ravel_state(state, spec)

            def flat_body(st, _):
                st, batches, ids, mask, full, n_clipped = self._prepare_round(
                    st, client_x, client_y
                )
                return self._flat_round_step(st, batches, ids, mask, full, spec,
                                             n_clipped)

            fstate, metrics = jax.lax.scan(flat_body, fstate, None, length=n_rounds)
            return self._unravel_state(fstate, spec), metrics

        def body(st, _):
            st, batches, ids, mask, full, n_clipped = self._prepare_round(
                st, client_x, client_y
            )
            return self._tree_round_step(st, batches, ids, mask, full, n_clipped)

        return jax.lax.scan(body, state, None, length=n_rounds)

    # -------------------------------------------------- async pipelined rounds
    def run_rounds_async(
        self,
        state: FedState,
        data,
        n_rounds: int,
        *,
        pipeline_depth: Optional[int] = None,
        staleness: Optional[int] = None,
        eval_every: int = 0,
        eval_data: Optional[Tuple[Any, Any]] = None,
        predict_fn: Optional[Callable[[Any, Any], jax.Array]] = None,
        eval_batch_size: int = 1000,
        drain: bool = True,
        scan_unroll: int = 1,
    ) -> Tuple[FedState, AsyncRoundMetrics]:
        """Overlapping-cohort (stale-momentum) FedCM: ONE pipelined lax.scan.

        Every scan iteration LAUNCHES one cohort against the current params
        and a broadcast momentum that is ``staleness`` rounds stale, pushes
        its uplink — cohort delta plane plus per-algorithm extras
        (``repro.core.flat.CohortUplink``) — into a depth-``pipeline_depth``
        ring carried by the scan, and FOLDS the oldest in-flight cohort
        into the server state.  A folded cohort is therefore
        ``pipeline_depth − 1`` rounds old: its clients descended from
        params the server has since moved past — exactly the
        delayed/partial aggregation client-level momentum is robust to
        (Cheng et al. 2023), with the fold weighted by the FedACG-style
        discount ``cfg.staleness_discount ** (depth−1)`` carried into the
        fused server kernel's SMEM coefficient row.

        ``pipeline_depth=1, staleness=0`` IS the sync schedule: the slot
        pushed at iteration t is popped at iteration t, the discount is
        γ⁰ = 1, and the trajectory matches ``run_rounds`` exactly (the
        equivalence test in tests/test_run_rounds.py holds all six
        algorithms to it).

        The first ``pipeline_depth − 1`` iterations fold nothing (pipeline
        fill — unrolled launch-only steps that grow the ring to its static
        depth; ``metrics.folded`` is 0 there), and with ``drain=True``
        (default) the cohorts still in flight at the end are folded by a
        fixed-size epilogue dispatch so no client work is discarded —
        ``n_rounds`` launches, ``n_rounds`` folds, still zero host
        round-trips (the epilogue's operands never leave the device;
        keeping it in the main program makes XLA clone the whole scan
        body around the final carry, measurably slower than a second
        dispatch).

        ``eval_every > 0`` moves evaluation device-resident INSIDE the scan
        (requires ``predict_fn`` and ``eval_data=(x_test, y_test)``): every
        eval_every-th iteration runs the padded ``lax.map`` eval on the
        post-fold params, so a full train-with-eval run is ONE jitted
        program with zero host round-trips; off-cadence rounds report
        ``eval_acc = −1.0``.

        ``scan_unroll`` unrolls the steady scan body (static): the ring
        rotation materializes at the loop boundary once per UNROLLED
        GROUP instead of once per round — within a group the fold reads
        the previous launch's uplink as straight dataflow.  ``2`` wins
        ~8% per round on the CPU update-bound benchmark at D≥2; compile
        time scales with the factor (the sync scan has no ring boundary
        and keeps unroll=1).

        Requires ``cfg.use_flat_plane`` (the ring is a flat-plane carry).
        The input ``state`` may be donated — use the returned state.
        """
        cfg = self.cfg
        depth = cfg.pipeline_depth if pipeline_depth is None else pipeline_depth
        stale = cfg.staleness if staleness is None else staleness
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        if depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got {depth}")
        if stale < 0:
            raise ValueError(f"staleness must be >= 0, got {stale}")
        if not cfg.use_flat_plane:
            raise ValueError(
                "run_rounds_async requires cfg.use_flat_plane=True — the "
                "in-flight cohort ring is a flat-plane carry (the tree path "
                "stays the sync oracle)"
            )
        if self.population_store == "host":
            if eval_every:
                raise ValueError(
                    "population_store='host' runs the async ring as a host "
                    "loop — in-scan eval is unavailable; eval between calls"
                )
            return self.run_rounds_store_async(
                state, data, n_rounds, pipeline_depth=depth, staleness=stale,
                drain=drain,
            )
        xb = yb = wb = None
        if eval_every:
            if predict_fn is None or eval_data is None:
                raise ValueError(
                    "eval_every > 0 needs predict_fn and eval_data=(x, y)"
                )
            xb, yb, wb = _pad_eval_batches(eval_data[0], eval_data[1], eval_batch_size)
        state, pending, metrics = self._run_rounds_async(
            state, data.client_x, data.client_y, xb, yb, wb,
            n_rounds=n_rounds, pipeline_depth=depth, staleness=stale,
            eval_every=eval_every,
            predict_fn=predict_fn if eval_every else None,
            scan_unroll=scan_unroll,
        )
        if drain and len(pending):
            state = self._drain_async(state, pending, pipeline_depth=depth)
        return state, metrics

    def _run_rounds_async_impl(
        self, state: FedState, client_x, client_y, xb, yb, wb, *,
        n_rounds: int, pipeline_depth: int, staleness: int, eval_every: int,
        predict_fn, scan_unroll: int = 1,
    ):
        self.run_rounds_async_traces += 1  # python side effect: trace count
        cfg, algo = self.cfg, self.algo
        D, S = pipeline_depth, staleness

        spec = FlatSpec.from_tree(state.params)
        fstate = self._ravel_state(state, spec)
        # momentum delay line: slot t mod S holds the broadcast buffer as it
        # was ENTERING round t−S (read-before-write); seeded with the
        # initial momentum so the first S rounds see round-0 state.  Only
        # algorithms that broadcast momentum (fedcm/mimelite Δ_t, scaffold
        # c) feel S at all.
        mhist = None
        if S > 0 and algo.needs_momentum_broadcast:
            mhist = jnp.tile(fstate.server.momentum[None], (S, 1))
        # FedACG-style lookahead weight of a fold that is D−1 rounds stale —
        # STATIC (depth is static), so γ = 1 costs nothing on the sync path
        discount = float(cfg.staleness_discount) ** (D - 1)
        pay = self._payload_from_nbytes(spec.nbytes, spec.size)

        def in_scan_eval(t, x_plane):
            if not eval_every or predict_fn is None:
                return jnp.float32(-1.0)

            def do_eval(xp):
                params = spec.unravel(xp)

                def one(args):
                    bx, by, bw = args
                    logits = predict_fn(params, bx)
                    hits = (jnp.argmax(logits, -1) == by).astype(jnp.float32)
                    return jnp.sum(hits * bw)

                return jnp.sum(jax.lax.map(one, (xb, yb, wb))) / jnp.sum(wb)

            if isinstance(t, int):  # unrolled warmup step: cadence is static
                return do_eval(x_plane) if (t + 1) % eval_every == 0 \
                    else jnp.float32(-1.0)
            return jax.lax.cond(
                jnp.mod(t + 1, eval_every) == 0, do_eval,
                lambda xp: jnp.float32(-1.0), x_plane,
            )

        def step(fst, pending, mhist, t, fold: bool):
            """One pipelined iteration.  ``fold`` is STATIC: the D−1
            warmup steps (pipeline fill — nothing old enough to fold) only
            grow the ring; every steady step rotates it — the popped
            uplink is by construction D−1 rounds old."""
            r0 = fst.server.round
            fst, batches, ids, mask, full, n_clipped = self._prepare_round(
                fst, client_x, client_y
            )
            if mhist is None:
                m_used = fst.server.momentum
            else:
                sm = jnp.mod(t, S)
                m_used = jax.lax.dynamic_index_in_dim(mhist, sm, 0, keepdims=False)
                mhist = jax.lax.dynamic_update_index_in_dim(
                    mhist, fst.server.momentum, sm, 0
                )
            (entry, n_active, loss, n_dropped, n_quar,
             res_rows) = self._launch_async_cohort(
                fst, m_used, batches, ids, mask, full, spec
            )
            if res_rows is not None:  # top-k residuals update at launch
                C = ids.shape[0]
                fst = fst._replace(
                    residuals=fst.residuals.at[ids].set(res_rows[:C])
                )
            if fold:
                oldest, pending = ring_push(pending, entry)
                fst, mean_norm, q_skip = self._fold_async_slot(
                    fst, oldest, spec, discount
                )
            else:
                pending = (*pending, entry)
                mean_norm = jnp.float32(0.0)
                q_skip = jnp.float32(0.0)
            # round counter is LAUNCH-aligned (η_l schedule stays in step
            # with the sync engine regardless of pipeline fill)
            fst = fst._replace(server=fst.server._replace(round=r0 + 1))
            metrics = AsyncRoundMetrics(
                loss=loss,
                n_active=n_active,
                delta_norm=mean_norm,
                momentum_norm=_flat_norm(m_used),
                eta_l=entry.eta_l,
                bytes_down=n_active * jnp.float32(pay["down_per_client"]),
                bytes_up=n_active * jnp.float32(pay["up_per_client"]),
                folded=jnp.float32(1.0 if fold else 0.0),
                eval_acc=in_scan_eval(t, fst.params),
                n_clipped=n_clipped.astype(jnp.float32),
                n_dropped=n_dropped,
                n_quarantined=n_quar,
                n_retries=jnp.float32(0.0),
                quorum_skipped=q_skip,
            )
            return fst, pending, mhist, metrics

        # pipeline fill: D−1 launch-only steps, UNROLLED — they grow the
        # ring tuple, whose structure must be static before the scan
        pending: Tuple[CohortUplink, ...] = ()
        fill_metrics = []
        warmup = min(D - 1, n_rounds)
        for t in range(warmup):
            fstate, pending, mhist, m = step(fstate, pending, mhist, t, fold=False)
            fill_metrics.append(m)

        def body(carry, t):
            fst, pending, mh = carry
            fst, pending, mh, m = step(fst, pending, mh, t, fold=True)
            return (fst, pending, mh), m

        (fstate, pending, mhist), metrics = jax.lax.scan(
            body, (fstate, pending, mhist), jnp.arange(warmup, n_rounds),
            unroll=scan_unroll,
        )
        if fill_metrics:
            fill = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *fill_metrics
            )
            metrics = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=0), fill, metrics
            )
        return self._unravel_state(fstate, spec), pending, metrics

    def _drain_async_impl(self, state: FedState,
                          pending: Tuple[CohortUplink, ...], *,
                          pipeline_depth: int):
        """Pipeline flush: fold the ≤ D−1 cohorts still in flight at the
        end of a ``run_rounds_async`` scan, oldest first.  A separate
        dispatch ON PURPOSE: feeding the scan's final (state, ring)
        carries into fold arithmetic inside the same program makes XLA
        clone the entire scan body around the last iteration — one
        fixed-size epilogue program is cheaper than that, and its operands
        never leave the device."""
        spec = FlatSpec.from_tree(state.params)
        fstate = self._ravel_state(state, spec)
        # the same staleness weight the in-scan folds used (depth, not
        # len(pending): a shorter-than-depth run still launched at the
        # configured overlap)
        discount = float(self.cfg.staleness_discount) ** (pipeline_depth - 1)
        for entry in pending:
            fstate, _, _ = self._fold_async_slot(fstate, entry, spec, discount)
        return self._unravel_state(fstate, spec)

    def _launch_async_cohort(self, fstate: FedState, m_used, batches, ids,
                             mask, full, spec: FlatSpec, cohort_rows=None,
                             residual_rows=None):
        """Client phase of one pipelined iteration: run the cohort against
        (current params, stale momentum) and pack its uplink as a ring
        entry.  Kernel path: outputs already ARE ``(C, P)`` planes and ride
        raw (the fused server kernel wants the cohort axis).  jnp path:
        ``delta``/``extra`` are pre-reduced HERE to the fold-ready ``(P,)``
        masked means — the weights are launch-time constants, so this is
        the fold's exact value, computed by the exact sync reduction
        (``_masked_pmean``); only the per-client ``state_delta`` plane must
        survive to fold time (the scatter is per-client).

        Returns (entry, n_active, cohort masked-mean loss, n_dropped,
        n_quarantined) — the fault counters of the launched cohort (the
        injected faults ride the ring with the entry).

        Cohort-parallel: the pass runs SPMD over the ``"clients"`` axis
        and the ring entry's planes are the PADDED ``(C_pad, P)`` shards
        (``ids``/``w`` padded to match; pad rows weigh zero) — the ring
        then carries each device's own clients until the scattered fold
        consumes them D−1 rounds later, which is what gives the
        reduce-scatter D−1 rounds of compute to hide behind."""
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, fstate.server.round)
        if cohort_rows is not None:  # store-backed: pre-gathered host rows
            outs, losses, _, _ = self._flat_cohort_pass(
                fstate, batches, ids, mask, full, spec, m_used, eta_l,
                cohort_rows=cohort_rows,
            )
        else:
            cohort_pass = (self._sharded_cohort_pass if self._sharded
                           else self._flat_cohort_pass)
            outs, losses, _, _ = cohort_pass(
                fstate, batches, ids, mask, full, spec, m_used, eta_l
            )
        # faults hit the uplink AT LAUNCH (drops/corruption happen on the
        # wire, not in the ring): the quarantined/thinned planes then ride
        # the ring D−1 rounds to their fold, and the jnp pre-reduction
        # below sees the already-sanitized payload
        mask, outs, n_dropped, n_quar = self._inject_faults(
            fstate.server.round, ids, mask, outs
        )
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)
        wp = self._pad_cohort(w, mode="zero") if self._sharded else w

        # wire encoding happens AT LAUNCH, like the faults above: the ring
        # carries the compressed representation (the in-flight memory win)
        # and the error-feedback residual updates when the client
        # transmits, not D−1 rounds later at the fold
        new_res_rows = None
        if self.compression is not None:
            res_rows = self._residual_rows_for(fstate, ids, residual_rows)
            outs, new_res_rows = self._compress_uplink(
                fstate.server.round, outs,
                wp if cfg.use_fused_kernel else w, res_rows, spec,
                ring=True,
            )

        if cfg.use_fused_kernel:
            delta_e, extra_e = outs.delta, outs.extra
        else:
            delta_e = self._masked_pmean(outs.delta, w, n_active)
            extra_e = self._masked_pmean(outs.extra, w, n_active)
        state_e = None
        if outs.state_delta is not None:
            state_e = (outs.state_delta if cfg.use_fused_kernel
                       else spec.ravel(outs.state_delta, batch_dims=1))

        entry = CohortUplink(
            delta=delta_e,
            state_delta=state_e,
            extra=extra_e,
            ids=(self._pad_cohort(ids) if self._sharded else ids).astype(jnp.int32),
            w=wp,
            eta_l=eta_l,
        )
        loss = jnp.sum(losses * wp) / jnp.maximum(n_active, 1.0)
        return entry, n_active, loss, n_dropped, n_quar, new_res_rows

    def _fold_async_slot(self, fstate: FedState, entry: CohortUplink,
                         spec: FlatSpec, discount, fold_rows=None,
                         emit_rows=False):
        """Server phase of one pipelined iteration: fold ONE ring entry —
        masked cohort mean, staleness-discounted momentum EMA + param step,
        client-state scatter — into the current flat state.  Every entry
        is a real launch (the unrolled pipeline fill means the ring never
        holds placeholders), so there is no validity masking to pay.  Uses
        the entry's LAUNCH-time η_l (the deltas were computed with it).
        Leaves the round counter alone — it is launch-aligned (see the
        scan body).

        Store-backed execution: ``fold_rows`` is the fold-time ``(C, P)``
        gather from the population store (the resident path gathers the
        plane HERE, at fold time — D−1 rounds after launch — so the host
        loop gathers at the same point) and ``emit_rows=True`` returns the
        updated rows instead of scattering into a resident plane.

        Returns (new_fstate, ‖mean Δ‖ of the folded cohort,
        quorum_skipped), plus the updated ``(C, P)`` rows when
        ``emit_rows``.  Quorum is enforced HERE — at fold time — because
        the surviving weight row is only final once the faulted entry
        leaves the ring."""
        cfg, algo = self.cfg, self.algo
        # sparse top-k deltas densify here, at fold time; QPlane planes
        # stay compressed into the fused dequant fold below
        entry = self._decode_ring_entry(entry, spec)
        w = entry.w  # (C_pad,) under cohort sharding — pad rows weigh 0
        n_active = jnp.sum(w)
        x_t = fstate.params
        fsrv = fstate.server
        use_kernel = cfg.use_fused_kernel and algo.server_fn is None

        if use_kernel and self._sharded:
            new_params, new_server, mean_delta = self._sharded_round_close(
                algo, fsrv, entry, w, n_active, x_t, entry.eta_l,
                discount=discount,
            )
        elif use_kernel:
            new_params, new_server, mean_delta = self._fused_round_close(
                algo, fsrv, entry, w, n_active, x_t, entry.eta_l,
                discount=discount,
            )
        else:
            if self._sharded:
                # scattered reductions of the ring's sharded (C_pad, P)
                # planes feeding the spec's server_fn escape hatch
                mean_delta, mean_sd, mean_extra = self._sharded_means(
                    entry, w, n_active
                )
            elif cfg.use_fused_kernel:
                # kernel-path algorithm whose round-close is a ``server_fn``
                # escape hatch: reduce the raw (C, P) planes exactly as the
                # sync kernel path does
                mean_delta = self._masked_pmean(entry.delta, w, n_active)
                mean_sd = self._masked_pmean(entry.state_delta, w, n_active)
                mean_extra = self._masked_pmean(entry.extra, w, n_active)
            else:
                # jnp path: delta/extra were pre-reduced at launch (the
                # weights are launch-time constants — same value, same
                # reduction, C× less ring state); only the per-client
                # state plane still needs its mean, reduced per leaf VIEW
                # so the contraction shapes match the sync round's exactly
                # (one plane-wide tensordot schedules its accumulation
                # differently and would break D=1 bitwise equality)
                mean_delta = entry.delta
                mean_extra = entry.extra
                mean_sd = None
                if entry.state_delta is not None:
                    mean_sd = self._masked_pmean(
                        spec.unravel(entry.state_delta, dtype=jnp.float32),
                        w, n_active,
                    )
            # the γ=1 sync fold stays bitwise: spec.server_update skips the
            # statically-1.0 discount multiply
            new_params, new_server = algo.server_update(
                cfg, x_t, fsrv, mean_delta, mean_sd, mean_extra,
                n_active, entry.eta_l, discount=discount,
            )
            new_server = new_server._replace(round=fsrv.round)

        # below-quorum / empty fold → no-op (see _flat_round_step); the
        # zeroed weights also suppress the client-state writes below
        ok = self._quorum_ok(n_active)
        new_params = _where_tree(ok, new_params, x_t)
        new_server = new_server._replace(
            momentum=_where_tree(ok, new_server.momentum, fsrv.momentum),
            second_moment=_where_tree(ok, new_server.second_moment,
                                      fsrv.second_moment),
        )
        w = w * ok.astype(jnp.float32)
        skipped = 1.0 - ok.astype(jnp.float32)

        # scatter the folded cohort's client-state updates (stale entries
        # of non-participants untouched).  A ring-compressed state plane
        # decodes HERE — the scatter adopts exactly the dequantized rows
        # the fold consumed
        sd_e = entry.state_delta
        if isinstance(sd_e, QPlane):
            sd_e = decompress_plane(sd_e)
        new_cst = fstate.client_states
        rows_out = None
        if algo.needs_client_state:
            if emit_rows:
                if cfg.use_fused_kernel:
                    rows_out = fold_rows + sd_e * w[:, None]
                else:
                    gathered = spec.unravel(fold_rows)
                    sd_tree = spec.unravel(sd_e, dtype=jnp.float32)
                    upd = jax.tree_util.tree_map(
                        lambda a, d: a + d * w.reshape(
                            (-1,) + (1,) * (d.ndim - 1)
                        ).astype(a.dtype),
                        gathered, sd_tree,
                    )
                    rows_out = spec.ravel(upd, batch_dims=1)
            elif self._sharded:
                # padded ring rows are dropped BEFORE the scatter: a pad
                # id (0) colliding with a real cohort member would make
                # the duplicate-index .set nondeterministic
                C = cohort_capacity(cfg)
                ids_r, w_r = entry.ids[:C], w[:C]
                upd = (fstate.client_states[ids_r]
                       + sd_e[:C] * w_r[:, None])
                new_cst = fstate.client_states.at[ids_r].set(upd)
            elif cfg.use_fused_kernel:  # (N, P) plane: ONE gather + scatter
                upd = fstate.client_states[entry.ids] + sd_e * w[:, None]
                new_cst = fstate.client_states.at[entry.ids].set(upd)
            else:
                sd_tree = spec.unravel(sd_e, dtype=jnp.float32)

                def scatter(a, d):
                    upd = a[entry.ids] + d * w.reshape(
                        (-1,) + (1,) * (d.ndim - 1)
                    ).astype(a.dtype)
                    return a.at[entry.ids].set(upd)

                new_cst = jax.tree_util.tree_map(
                    scatter, fstate.client_states, sd_tree
                )

        new_state = FedState(new_params, new_server, new_cst, fstate.rng,
                             residuals=fstate.residuals)
        if emit_rows:
            return new_state, _flat_norm(mean_delta), skipped, rows_out
        return new_state, _flat_norm(mean_delta), skipped

    # -------------------------------------------------- store-backed rounds
    def _store_jits(self, spec: FlatSpec):
        """Jitted per-round pieces of the store-backed host loops, cached
        per FlatSpec.  The pieces ARE the resident engine's round functions
        (``_sample_round``/``_flat_round_step``/``_launch_async_cohort``/
        ``_fold_async_slot``) parameterized by host-gathered rows — sharing
        the traced math verbatim is what makes the store path f32-bitwise
        against the resident oracle at matched cohorts."""
        cache = getattr(self, "_store_jit_cache", None)
        if cache is None:
            cache = self._store_jit_cache = {}
        if spec in cache:
            return cache[spec]

        def sample_device(fst, client_x, client_y):
            # device-resident FederatedData: the resident scan body's
            # sampler, verbatim (same rng threading → matched cohorts)
            return self._prepare_round(fst, client_x, client_y)

        def sample_ids(rng, t):
            # streaming data: sample only the cohort on device; the batch
            # key degrades to a host seed for the on-demand generator
            rng, k_cohort, k_batch = jax.random.split(rng, 3)
            ids, mask, n_clipped = sample_cohort_ex(k_cohort, self.cfg, t)
            seed = jax.random.randint(k_batch, (), 0, jnp.int32(2**31 - 1))
            return rng, ids, mask, n_clipped, seed

        def step(fst, batches, ids, mask, full, n_clipped, rows, res_rows):
            if rows is None and res_rows is None:
                # stateless, uncompressed-or-residual-free: nothing to emit
                fst, m = self._flat_round_step(
                    fst, batches, ids, mask, full, spec, n_clipped
                )
                return fst, m, None, None
            return self._flat_round_step(
                fst, batches, ids, mask, full, spec, n_clipped,
                cohort_rows=rows, emit_rows=True, residual_rows=res_rows,
            )

        def launch(fst, m_used, batches, ids, mask, full, rows, res_rows):
            return self._launch_async_cohort(
                fst, m_used, batches, ids, mask, full, spec,
                cohort_rows=rows, residual_rows=res_rows,
            )

        def fold(fst, entry, fold_rows, discount):
            if fold_rows is None:
                fst, norm, q_skip = self._fold_async_slot(
                    fst, entry, spec, discount
                )
                return fst, norm, q_skip, None
            return self._fold_async_slot(
                fst, entry, spec, discount, fold_rows=fold_rows, emit_rows=True
            )

        cache[spec] = {
            "sample_device": jax.jit(sample_device),
            "sample_ids": jax.jit(sample_ids),
            "step": jax.jit(step),
            "launch": jax.jit(launch),
            # discount is a static python float (rides SMEM coefficients)
            "fold": jax.jit(fold, static_argnums=(3,)),
        }
        return cache[spec]

    def _host_sample(self, jits, fstate: FedState, data, device_data: bool):
        """One round's cohort + batches under the host loop.  Device-
        resident ``FederatedData`` goes through the resident sampler
        verbatim (bitwise-matched cohorts AND batches); streaming data
        (``repro.data.population.StreamingClientData``) samples ids on
        device and generates only the cohort's minibatches on the host."""
        if device_data:
            return jits["sample_device"](fstate, data.client_x, data.client_y)
        rng, ids, mask, n_clipped, seed = jits["sample_ids"](
            fstate.rng, fstate.server.round
        )
        ids_np = np.asarray(ids)
        raw = data.host_round_batches(
            ids_np, int(seed), self.cfg.local_steps, self.batch_size
        )
        batches = self._to_loss_batches(
            {k: jnp.asarray(v) for k, v in raw.items()}
        )
        if self.algo.needs_full_grad:
            full = self._to_loss_batches(
                {k: jnp.asarray(v) for k, v in data.host_full_batches(ids_np).items()}
            )
        else:
            full = jax.tree_util.tree_map(lambda b: b[:, 0], batches)
        return fstate._replace(rng=rng), batches, ids, mask, full, n_clipped

    def _require_store(self):
        if self.population is None:
            # init() attaches the store; a hand-built FedState lands here
            raise RuntimeError(
                "population store missing — call eng.init(params, rng) "
                "before store-backed rounds"
            )
        return self.population

    def _residual_store(self):
        """The host-side residual row store (top-k under ``"host"``), or
        ``None`` when residuals are resident / compression carries none."""
        if not self._ef_residuals or self.population_store == "resident":
            return None
        if self.residual_population is None:
            raise RuntimeError(
                "residual store missing — call eng.init(params, rng) "
                "before store-backed rounds with topk compression"
            )
        return self.residual_population

    def run_rounds_store(self, state: FedState, data, n_rounds: int):
        """Sync engine for ``population_store="host"``: a host loop of the
        jitted round step with a store gather before and scatter after each
        round.  No ``(N, ·)`` device array exists at any point — only the
        ``(C, P)`` cohort block — so N is bounded by host memory over
        TOUCHED clients, not device memory over the population.

        ``data`` may be a device-resident ``FederatedData`` (the bitwise-
        oracle pairing used by tests) or a ``StreamingClientData`` whose
        shards generate on demand (the N=1e6 path).

        ``cfg.store_prefetch`` (default on) double-buffers the host side:
        round t+1's cohort sampling, minibatch generation, and optimistic
        store gather run on a background thread while round t's device
        step executes; rows round t scattered after the optimistic gather
        are re-gathered at consumption (the cohort overlap is tiny at
        fleet scale).  The device work, its inputs, and the rng chain are
        IDENTICAL to the synchronous loop — the prefetch-on/off bitwise
        test pins the contract (only ``n_retries`` may differ under
        injected store chaos: the patch gathers shift the failure
        stream)."""
        cfg = self.cfg
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        spec = FlatSpec.from_tree(state.params)
        jits = self._store_jits(spec)
        fstate = self._ravel_state(state, spec)
        device_data = hasattr(data, "client_x")
        stateful = self.algo.needs_client_state
        store = self._require_store() if stateful else None
        res_store = self._residual_store()
        if getattr(cfg, "store_prefetch", True) and n_rounds > 1:
            fstate, metrics = self._store_loop_prefetch(
                fstate, jits, data, device_data, store, res_store, n_rounds
            )
        else:
            fstate, metrics = self._store_loop_sync(
                fstate, jits, data, device_data, store, res_store, n_rounds
            )
        state = self._unravel_state(fstate, spec)
        return state, jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *metrics)

    def _store_loop_sync(self, fstate, jits, data, device_data, store,
                         res_store, n_rounds):
        """The synchronous host loop — sample, gather, step, scatter, one
        round at a time.  The bitwise oracle for the prefetched loop."""
        metrics = []
        for _ in range(n_rounds):
            fstate, batches, ids, mask, full, n_clipped = self._host_sample(
                jits, fstate, data, device_data
            )
            ids_np = np.asarray(ids)
            rows = res_rows = None
            retries = 0
            if store is not None:
                got, r_g = self._store_io(store.gather, ids_np)
                rows = jnp.asarray(got)
                retries += r_g
            if res_store is not None:
                got, r_g = self._store_io(res_store.gather, ids_np)
                res_rows = jnp.asarray(got)
                retries += r_g
            fstate, m, new_rows, new_res = jits["step"](
                fstate, batches, ids, mask, full, n_clipped, rows, res_rows
            )
            if store is not None:
                _, r_s = self._store_io(
                    store.scatter, ids_np, np.asarray(new_rows)
                )
                retries += r_s
            if res_store is not None:
                _, r_s = self._store_io(
                    res_store.scatter, ids_np, np.asarray(new_res)
                )
                retries += r_s
            if retries:  # stamp host-side; device path stamped 0
                m = m._replace(n_retries=jnp.float32(retries))
            metrics.append(m)
        return fstate, metrics

    def _store_loop_prefetch(self, fstate, jits, data, device_data, store,
                             res_store, n_rounds):
        """Double-buffered host loop: a one-worker executor runs round
        t+1's ``_host_sample`` + optimistic store gather while round t's
        jitted step runs on device.  Safe by construction:

        * the sampler reads ONLY (rng, round counter) — both known before
          the step (the step never advances rng, and the counter advances
          by exactly 1) — so the prefetched cohort/batches are bitwise the
          synchronous loop's;
        * store ops serialize on a lock (gathers never observe a torn
          scatter), and rows the current round scatters after the
          optimistic gather are re-gathered at consumption
          (``intersect1d`` of consecutive cohorts) — every step consumes
          exactly the post-scatter rows the synchronous loop would."""
        lock = threading.Lock()

        def sample_and_gather(probe):
            nf, batches, ids, mask, full, n_clipped = self._host_sample(
                jits, probe, data, device_data
            )
            ids_np = np.asarray(ids)
            rows = res_rows = None
            retries = 0
            with lock:
                if store is not None:
                    got, r = self._store_io(store.gather, ids_np)
                    rows, retries = got, retries + r
                if res_store is not None:
                    got, r = self._store_io(res_store.gather, ids_np)
                    res_rows, retries = got, retries + r
            return (nf.rng, batches, ids, ids_np, mask, full, n_clipped,
                    rows, res_rows, retries)

        metrics = []
        ex = ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="store-prefetch")
        try:
            pending = ex.submit(sample_and_gather, fstate)
            prev_ids = None  # cohort scattered since the pending gather began
            for t in range(n_rounds):
                (rng, batches, ids, ids_np, mask, full, n_clipped, rows,
                 res_rows, retries) = pending.result()
                fstate = fstate._replace(rng=rng)
                if prev_ids is not None:
                    # patch rows the previous round's scatter invalidated
                    overlap = np.intersect1d(ids_np, prev_ids)
                    if overlap.size:
                        pos = {int(c): i for i, c in enumerate(ids_np)}
                        sel = np.array([pos[int(c)] for c in overlap])
                        with lock:
                            if store is not None:
                                got, r = self._store_io(store.gather, overlap)
                                rows[sel], retries = got, retries + r
                            if res_store is not None:
                                got, r = self._store_io(
                                    res_store.gather, overlap
                                )
                                res_rows[sel], retries = got, retries + r
                # round t+1's host work overlaps the device step below
                if t + 1 < n_rounds:
                    probe = fstate._replace(server=fstate.server._replace(
                        round=fstate.server.round + 1
                    ))
                    pending = ex.submit(sample_and_gather, probe)
                fstate, m, new_rows, new_res = jits["step"](
                    fstate, batches, ids, mask, full, n_clipped,
                    None if rows is None else jnp.asarray(rows),
                    None if res_rows is None else jnp.asarray(res_rows),
                )
                with lock:
                    if store is not None:
                        _, r = self._store_io(
                            store.scatter, ids_np, np.asarray(new_rows)
                        )
                        retries += r
                    if res_store is not None:
                        _, r = self._store_io(
                            res_store.scatter, ids_np, np.asarray(new_res)
                        )
                        retries += r
                prev_ids = (ids_np if (store is not None
                                       or res_store is not None) else None)
                if retries:
                    m = m._replace(n_retries=jnp.float32(retries))
                metrics.append(m)
        finally:
            ex.shutdown(wait=True)
        return fstate, metrics

    def _host_fold(self, jits, fstate: FedState, entry: CohortUplink,
                   discount: float, store, stateful: bool):
        """Fold one ring entry under the host loop: fold-time store gather
        (mirroring the resident fold's plane gather D−1 rounds after
        launch), the jitted fold, and the row scatter back.  Returns
        (fstate, mean_norm, quorum_skipped, store retries)."""
        retries = 0
        if stateful:
            ids_np = np.asarray(entry.ids)
            got, r_g = self._store_io(store.gather, ids_np)
            frows = jnp.asarray(got)
            fstate, mean_norm, q_skip, new_rows = jits["fold"](
                fstate, entry, frows, discount
            )
            _, r_s = self._store_io(store.scatter, ids_np, np.asarray(new_rows))
            retries = r_g + r_s
        else:
            fstate, mean_norm, q_skip, _ = jits["fold"](
                fstate, entry, None, discount
            )
        return fstate, mean_norm, q_skip, retries

    def run_rounds_store_async(
        self, state: FedState, data, n_rounds: int, *,
        pipeline_depth: Optional[int] = None, staleness: Optional[int] = None,
        drain: bool = True,
    ):
        """Async overlapping-cohort engine for ``population_store="host"``:
        the resident scan's schedule — launch against (current params,
        S-stale momentum), ring of D in-flight uplinks, fold the oldest,
        launch-aligned round counter — replayed as a host loop with store
        gathers/scatters at exactly the resident gather/scatter points.
        The ring's ``state_delta`` planes are ``(C, P)`` (never ``(N, ·)``).
        ``(D, S)`` semantics, warmup, discount γ^(D−1), and drain order
        match ``run_rounds_async`` entry for entry."""
        cfg, algo = self.cfg, self.algo
        D = cfg.pipeline_depth if pipeline_depth is None else pipeline_depth
        S = cfg.staleness if staleness is None else staleness
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        if D < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got {D}")
        if S < 0:
            raise ValueError(f"staleness must be >= 0, got {S}")
        spec = FlatSpec.from_tree(state.params)
        jits = self._store_jits(spec)
        fstate = self._ravel_state(state, spec)
        device_data = hasattr(data, "client_x")
        stateful = algo.needs_client_state
        store = self._require_store() if stateful else None
        mhist = None
        if S > 0 and algo.needs_momentum_broadcast:
            mhist = [fstate.server.momentum for _ in range(S)]
        discount = float(cfg.staleness_discount) ** (D - 1)
        pay = self._payload_from_nbytes(spec.nbytes, spec.size)
        res_store = self._residual_store()
        ring = []
        metrics = []
        for t in range(n_rounds):
            r0 = fstate.server.round
            fstate, batches, ids, mask, full, n_clipped = self._host_sample(
                jits, fstate, data, device_data
            )
            if mhist is None:
                m_used = fstate.server.momentum
            else:  # S-deep delay line, read-before-write at slot t mod S
                sm = t % S
                m_used = mhist[sm]
                mhist[sm] = fstate.server.momentum
            rows = res_rows = None
            retries = 0
            if stateful:
                got, r_g = self._store_io(store.gather, np.asarray(ids))
                rows = jnp.asarray(got)
                retries += r_g
            if res_store is not None:
                got, r_g = self._store_io(res_store.gather, np.asarray(ids))
                res_rows = jnp.asarray(got)
                retries += r_g
            entry, n_active, loss, n_dropped, n_quar, new_res = jits["launch"](
                fstate, m_used, batches, ids, mask, full, rows, res_rows
            )
            if res_store is not None:  # residuals update at launch
                _, r_s = self._store_io(
                    res_store.scatter, np.asarray(ids), np.asarray(new_res)
                )
                retries += r_s
            ring.append(entry)
            fold_now = len(ring) >= D
            if fold_now:
                fstate, mean_norm, q_skip, r_f = self._host_fold(
                    jits, fstate, ring.pop(0), discount, store, stateful
                )
                retries += r_f
            else:  # pipeline fill: launch-only
                mean_norm = jnp.float32(0.0)
                q_skip = jnp.float32(0.0)
            # launch-aligned round counter, as in the resident scan body
            fstate = fstate._replace(
                server=fstate.server._replace(round=r0 + 1)
            )
            metrics.append(AsyncRoundMetrics(
                loss=loss,
                n_active=n_active,
                delta_norm=mean_norm,
                momentum_norm=_flat_norm(m_used),
                eta_l=entry.eta_l,
                bytes_down=n_active * jnp.float32(pay["down_per_client"]),
                bytes_up=n_active * jnp.float32(pay["up_per_client"]),
                folded=jnp.float32(1.0 if fold_now else 0.0),
                eval_acc=jnp.float32(-1.0),
                n_clipped=n_clipped.astype(jnp.float32),
                n_dropped=n_dropped,
                n_quarantined=n_quar,
                n_retries=jnp.float32(retries),
                quorum_skipped=q_skip,
            ))
        if drain:  # flush in-flight cohorts, oldest first
            for entry in ring:
                fstate, _, _, _ = self._host_fold(
                    jits, fstate, entry, discount, store, stateful
                )
            ring = []
        state = self._unravel_state(fstate, spec)
        return state, jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *metrics)

    @staticmethod
    def _to_loss_batches(raw):
        """{"x","y"} → loss_fn batch dict (pass-through for custom dicts).

        Must stay traceable: ``run_rounds`` calls it inside a jitted scan.
        """
        return raw


def _tree_norm(t):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(t)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.float32(0.0)


def _flat_norm(x):
    """‖x‖₂ of one flat plane — same formulation as ``_tree_norm`` so flat
    and tree metrics agree bitwise for single-buffer input."""
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


# ----------------------------------------------------------------------
# evaluation
# ----------------------------------------------------------------------


def _pad_eval_batches(x, y, batch_size: int):
    """Pad + reshape a test set to ``(n_batches, B, …)`` with a 0/1 weight
    plane so padded rows never count — the shared prep of the host-side
    ``make_eval_fn`` and the in-scan eval of ``run_rounds_async``."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    n = x.shape[0]
    nb = max(1, -(-n // batch_size))
    pad = nb * batch_size - n
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    yp = jnp.pad(y, ((0, pad),))
    w = (jnp.arange(nb * batch_size) < n).astype(jnp.float32)

    def rs(a):
        return a.reshape((nb, batch_size) + a.shape[1:])

    return rs(xp), rs(yp), rs(w)


def make_eval_fn(predict_fn: Callable[[Any, Any], jax.Array], batch_size: int = 1000):
    """predict_fn(params, x) -> logits.  Returns eval(params, x, y) -> acc.

    Device-resident: the whole test set is evaluated by ONE jitted
    ``lax.map`` over padded ``(n_batches, B, …)`` batches — a single
    dispatch and a single device→host sync per call, instead of one of each
    per 1000 examples.  (The old per-batch python loop stalled ``fed_train``
    between fused ``run_rounds`` chunks.)  Padding rows carry zero weight,
    so the returned accuracy is exact for any n.  Retraces only when the
    padded shape changes, i.e. once per dataset.
    """

    @jax.jit
    def _evaluate(params, xb, yb, wb):
        def one(args):
            x, y, w = args
            logits = predict_fn(params, x)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32) * w)

        hits = jax.lax.map(one, (xb, yb, wb))
        return jnp.sum(hits) / jnp.sum(wb)

    def evaluate(params, x, y):
        xb, yb, wb = _pad_eval_batches(x, y, batch_size)
        return float(_evaluate(params, xb, yb, wb))

    return evaluate
