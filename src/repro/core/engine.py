"""The federated round engine.

One communication round (Algorithm 2 of the paper) is a single jitted —
and, on a mesh, pjit-sharded — program:

    sample cohort  →  broadcast (x_t, Δ_t)  →  vmap over clients of
    [lax.scan over K local steps]  →  masked-mean aggregate  →  server update

The engine is architecture-agnostic: it only sees ``loss_fn(params, batch)``
(DESIGN.md §7 — FedCM is optimizer-level).  On a TPU mesh the cohort axis is
sharded over ("pod","data") and each client's parameters may additionally be
tensor-sharded on "model"; the aggregation mean lowers to an all-reduce over
the cohort axes — the server/client message pattern of the paper becomes
collectives (DESIGN.md §3).

Participation models (§6.1 of the paper):

* ``fixed``      — exactly ``cohort_size`` clients, uniform w/o replacement.
* ``bernoulli``  — every client independently with prob cohort_size/N.  For a
  jit-static shape we draw the cohort count s ~ Binomial(N, p) (clipped to a
  capacity), take the first s entries of a random permutation, and mask the
  rest; conditioned on s this equals independent-Bernoulli participation.

Payload accounting mirrors §4.2: FedCM doubles only the DOWNLINK (x_t plus
Δ_t); uplink is one delta — unchanged from FedAvg.  SCAFFOLD pays both ways
(c down, Δc_i up); MimeLite pays an extra full-batch gradient up.

Fused multi-round engine (``run_rounds``): the paper's headline results
(Table 1, §6.1) need hundreds to thousands of rounds, and dispatching each
round as its own jit call — with host-side cohort sampling in between —
makes round *dispatch* the wall-clock bottleneck long before the math is.
``run_rounds(state, data, n_rounds)`` therefore executes N rounds as a
single ``jax.lax.scan`` whose body does everything a round needs on-device:

* cohort sampling (``sample_cohort``) from the carried rng,
* synthetic-data minibatch gathers (``repro.data.pipeline.gather_round_batches``,
  pure array-in/array-out so it traces),
* the round step itself (the same ``_round_step_impl`` the per-round path
  jits, so the two paths are numerically one implementation).

The carried ``FedState`` is donated (``donate_argnums``), so server params/
momentum/client-state buffers are updated in place across all N rounds, and
per-round ``RoundMetrics`` come back stacked ``(n_rounds, ...)``.  The
``client_sharding`` constructor arg pins the cohort axis of batches and
client states via sharding constraints in both the per-round and fused
paths.

Flat parameter plane (``cfg.use_flat_plane``, default on): params and
server momentum/second-moment are ravelled ONCE per ``run_rounds`` call
(``repro.core.flat.FlatSpec``) into contiguous ``(P,)`` buffers that carry
the round-scope state; every round-scope reduction lands flat — masked
cohort means concatenate per-leaf contractions into ONE ``(P,)`` buffer,
the server update and metric norms are single fused ops, and stateless
algorithms never materialize the zero state/extra planes the tree path
builds and aggregates.  The K-step local scan itself keeps the LEAF form
(model autodiff is per-leaf; a flat↔tree conversion per step measures
2-3× slower on CPU XLA), so its body is bitwise the tree path's.  Under
``use_fused_kernel`` the scan flips to the flat ``(P,)`` carry — the
kernels consume flat buffers directly, per-client control variates ride an
``(N, P)`` plane (ONE gather/scatter), and the per-step concatenate/split
of the PR-1 kernel route disappears entirely.  The tree path
(``use_flat_plane=False``) is retained verbatim as the numerical oracle
(tests/test_flat.py) and for tensor-sharded lowering (launch/fed_dryrun).

The algorithm layer is the declarative registry (``repro.core.registry``):
the engine consumes ONE ``AlgorithmSpec`` per run — its direction
coefficient row drives the local steps, its fold coefficient rows (+
optional pure post-step) drive the round close, and its state-plane flags
drive ``FedState`` allocation and payload accounting.  The engine contains
zero per-algorithm branches; registering a new spec makes it runnable on
every path below.

``cfg.use_fused_kernel`` routes the update phase through Pallas — flat
plane only: the per-local-step direction via ``kernels/fed_direction``
(the spec's ``DirectionRow`` becomes the SMEM coefficient vector) and the
round-close masked-mean + momentum EMA + param step via
``kernels/server_update`` (one launch per ``FoldPass``; specs with a
``server_fn`` escape hatch fall back to the jnp reduction).  The legacy
whole-tree ``fedcm_update`` launch is retired from the tree path (its
``ref.py`` stays as a blend oracle); on the tree path the flag is inert.
Each kernel's ``ref.py`` is its oracle.

Async pipelined engine (``run_rounds_async``): overlapping cohorts as ONE
``lax.scan`` whose carry adds a static depth-D ring of in-flight cohort
uplinks (``repro.core.flat.CohortUplink``) and an S-deep momentum delay
line.  Iteration t launches a cohort against (current params,
S-rounds-stale momentum), rotates it into the ring, and folds the uplink
launched D−1 iterations ago through the staleness-discount-extended fused
server kernel.  ``(D=1, S=0)`` reproduces ``run_rounds`` exactly; eval can
ride inside the scan at an ``eval_every`` cadence (padded ``lax.map``) so
train-with-eval is one jitted program.

Cohort-parallel execution (``cohort_mesh`` / ``cfg.cohort_shard``): a
``("clients",)`` mesh turns the round SPMD over the client axis.  The
cohort phase runs inside ``shard_map`` — each device owns C/num_shards
clients end-to-end (local-step scans, ``fed_direction`` launches, state
gathers all device-local; ragged cohorts pad with zero-weight rows AFTER
the gathers so the rng stream is untouched) — and the server fold lowers
to the scattered kernel (``kernels/server_update/ops.scatter_fold``):
``all_to_all`` transposes the ``(C, P)`` uplink planes to plane-column
shards, each device reduces the COMPLETE cohort for its columns in the
unsharded reduction order, runs the spec's fold rows on its ``x``/``m``
chunks, and ``all_gather`` rebuilds the replicated planes.  That
transpose-first decomposition (NOT ``psum_scatter``, which would
re-associate the f32 sum) plus the server kernel's ≥2-step grid floor is
what keeps sharded execution f32-BITWISE against the unsharded engine —
for every registered algorithm, sync and async
(tests/test_cohort_shard.py).  Under ``run_rounds_async`` the ring
carries client-sharded planes, so the fold's collective sits D−1 rounds
behind the launch it consumes — the latency the overlap hides.  Flat +
kernel path only; the spec's ``server_post_fn`` runs replicated after
the gather, and ``server_fn`` escape hatches get scattered means
(``repro.core.flat.cohort_mean_scatter``) into a replicated escape.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import FedConfig
from repro.core.algorithms import (
    Algorithm,
    ClientOutputs,
    FlatClientOutputs,
    ServerState,
    client_state_init,
    get_algorithm,
    server_init,
    sparse_client_finalize,
)
from repro.core.flat import (
    CohortUplink,
    FlatSpec,
    cohort_mean_scatter,
    pad_cohort,
    ring_push,
)
from repro.data.pipeline import gather_full_client_batch, gather_round_batches
from repro.kernels.fed_direction.ops import flat_direction_step
from repro.kernels.server_update.ops import fused_fold, scatter_fold
from repro.sharding.rules import (
    COHORT_AXIS,
    cohort_axis_size,
    cohort_uplink_specs,
    padded_cohort,
)
from repro.utils.compat import shard_map
from repro.utils.trees import (
    ravel_leaves,
    tree_axpy,
    tree_bytes,
    tree_zeros_like,
)


class FlatMaster(NamedTuple):
    """f32 master planes carried ACROSS flat-engine calls for sub-f32 trees.

    The flat engine computes on f32 ``(P,)`` planes and rounds back to the
    leaf dtypes on exit; without this cache a bf16 model would re-round at
    every ``run_round`` boundary while ``run_rounds`` rounds once at the
    end (the divergence PR 2 documented).  ``FederatedEngine.init`` attaches
    it whenever the tree has non-f32 leaves, ``_ravel_state`` resumes from
    it, and ``_unravel_state`` refreshes it — so N× ``run_round`` agrees
    with ``run_rounds(N)`` to the same cross-program f32 noise as an f32
    model (measured ≲2e-5; the legacy behaviour differed by a bf16 ulp,
    ~4e-3, at EVERY boundary — the regression test pins the gap).  ``None``
    for all-f32 trees (the ravel is exact, nothing to preserve) and on the
    tree path."""

    params: jax.Array  # (P,) f32
    second_moment: Optional[jax.Array]  # (P,) f32, or None (spec doesn't need v)
    client_states: Optional[jax.Array]  # (N, P) f32 (kernel path) or None


class FedState(NamedTuple):
    """Engine state.  ``master`` is an INTERNAL cache: for sub-f32 trees it
    holds the un-rounded f32 planes that ``params``/``server.second_moment``
    /``client_states`` are rounded views OF, and the engine resumes from it
    in preference to re-ravelling the leaves.  If you replace any of those
    fields externally (checkpoint restore, weight surgery), drop the cache
    — ``state._replace(params=new, master=None)`` — or the next round will
    silently continue from the cached planes instead of your edit."""

    params: Any
    server: ServerState
    client_states: Any  # stacked (N, …) or None
    rng: jax.Array
    master: Optional[FlatMaster] = None  # flat-engine f32 master planes


class RoundMetrics(NamedTuple):
    loss: jax.Array  # mean local training loss over cohort × K steps
    n_active: jax.Array
    delta_norm: jax.Array  # ‖mean Δ_i‖
    momentum_norm: jax.Array  # ‖Δ_t‖ (server momentum entering the round)
    eta_l: jax.Array
    bytes_down: jax.Array  # server→clients this round (f32 elements × 4)
    bytes_up: jax.Array  # clients→server this round


class AsyncRoundMetrics(NamedTuple):
    """Per-iteration metrics of the pipelined scan.  ``loss``/``n_active``/
    ``eta_l``/``momentum_norm`` describe the cohort LAUNCHED this round
    (client compute happens at launch); ``delta_norm``/``folded`` describe
    the fold — 0 during the D−1 warmup rounds while the pipeline fills.
    ``eval_acc`` is −1.0 on rounds where the in-scan eval didn't run."""

    loss: jax.Array
    n_active: jax.Array
    delta_norm: jax.Array
    momentum_norm: jax.Array  # ‖broadcast momentum‖ as the CLIENTS saw it
    eta_l: jax.Array
    bytes_down: jax.Array
    bytes_up: jax.Array
    folded: jax.Array  # 0/1: did this round fold a completed cohort
    eval_acc: jax.Array  # in-scan eval accuracy, −1.0 when not evaluated


def cohort_capacity(cfg: FedConfig) -> int:
    """Static cohort axis length. ``fixed``: exactly S. ``bernoulli``: a
    Binomial(N, p) tail bound — mean + 5σ, clipped to N (p(overflow) < 3e-7;
    overflow clips the round's cohort, a negligible bias at these sizes)."""
    if cfg.participation == "fixed":
        return cfg.cohort_size
    p = cfg.cohort_size / cfg.num_clients
    sd = math.sqrt(cfg.num_clients * p * (1 - p))
    return min(cfg.num_clients, int(math.ceil(cfg.cohort_size + 5 * sd)))


def sample_cohort(rng, cfg: FedConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (client_ids (C,), active_mask (C,)) with C = cohort_capacity."""
    cap = cohort_capacity(cfg)
    k_perm, k_n = jax.random.split(rng)
    ids = jax.random.choice(k_perm, cfg.num_clients, (cap,), replace=False)
    if cfg.participation == "fixed":
        return ids, jnp.ones((cap,), bool)
    p = cfg.cohort_size / cfg.num_clients
    draws = jax.random.bernoulli(k_n, p, (cfg.num_clients,))
    s = jnp.clip(jnp.sum(draws).astype(jnp.int32), 1, cap)
    return ids, jnp.arange(cap) < s


def local_learning_rate(cfg: FedConfig, t) -> jax.Array:
    """Appendix C.2: exponential per-round decay of η_l."""
    return jnp.float32(cfg.eta_l) * jnp.float32(cfg.eta_l_decay) ** t.astype(jnp.float32)


# ----------------------------------------------------------------------
# client update
# ----------------------------------------------------------------------


def client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    params,  # x_t (broadcast)
    bcast_momentum,  # Δ_t (or c for scaffold; zeros otherwise)
    client_state,  # this client's c_i / λ_i slice (or zeros pytree)
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
) -> Tuple[ClientOutputs, jax.Array]:
    """One client's K local steps.  Returns (outputs, mean local loss).

    The spec's declarative direction row consumes the broadcast buffer and
    the client's state slice as NAMED streams — no per-algorithm packing
    (the old scaffold ``(c_i, c)`` tuple) happens here.
    """
    x0 = params

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        v = algo.direction(cfg, bcast_momentum, client_state, x, x0, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0, batches,
                              unroll=cfg.local_steps if unroll else 1)

    full_grad = tree_zeros_like(x0)
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0, full_grad_batch)

    outs = algo.client_finalize(cfg, x0, xK, client_state, bcast_momentum,
                                eta_l, full_grad)
    return outs, jnp.mean(losses)


def flat_client_update(
    algo: Algorithm,
    cfg: FedConfig,
    loss_fn: Callable[[Any, Any], jax.Array],
    spec: FlatSpec,
    x_t: jax.Array,  # (P,) broadcast round anchor (flat)
    x0_tree,  # the same anchor as a tree (unravelled ONCE per round)
    m_t: jax.Array,  # (P,) Δ_t (or c for scaffold; zeros otherwise)
    m_tree,  # its tree view (unravelled ONCE per round)
    cst_tree_i,  # this client's c_i / λ_i as a tree slice, or None
    cst_flat_i,  # the same as a (P,) plane row, or None
    batches,  # pytree of (K, B, …) local minibatches
    eta_l,
    full_grad_batch=None,  # MimeLite: the client's whole dataset
    unroll: bool = False,  # dry-run analysis: count every local step
):
    """One client's K local steps, finalized onto flat-engine outputs.

    jnp path: the local scan carries the LEAF form — model autodiff is
    per-leaf anyway, and a flat↔tree conversion per step would add unfused
    ops to the hottest loop (measured ~2-3× slower on CPU XLA) — so the
    step body is bitwise the tree path's, and the client's outputs stay
    leaf trees with ``None`` for unused planes
    (``sparse_client_finalize``).  The engine then reduces them straight to
    flat ``(P,)`` MEANS — the full ``(C, P)`` cohort plane is never
    materialized (a batched concatenate costs more than the per-leaf
    contractions it would save).

    ``cfg.use_fused_kernel`` flips the scan onto the flat ``(P,)`` carry
    instead: the ``fed_direction`` kernel consumes flat buffers directly
    (no per-step concatenate/split — the loss unravels the plane by
    slicing, which fuses on TPU where this path is aimed) and the outputs
    ARE ``(P,)`` planes, giving the ``(C, P)`` delta plane the fused
    ``server_update`` kernel wants for free.
    """
    if cfg.use_fused_kernel:
        def flat_loss(flat, batch):
            return loss_fn(spec.unravel(flat), batch)

        def step(x, batch):
            loss, g = jax.value_and_grad(flat_loss)(x, batch)
            if cfg.weight_decay:
                g = cfg.weight_decay * x + g
            x = flat_direction_step(algo, cfg, x, g, m_t, cst_flat_i, x_t, eta_l)
            return x, loss

        xK_flat, losses = jax.lax.scan(step, x_t, batches,
                                       unroll=cfg.local_steps if unroll else 1)
        full_grad = None
        if algo.needs_full_grad:
            assert full_grad_batch is not None
            full_grad = jax.grad(flat_loss)(x_t, full_grad_batch)
        outs = sparse_client_finalize(algo, cfg, x_t, xK_flat, cst_flat_i,
                                      m_t, eta_l, full_grad)
        return outs, jnp.mean(losses)

    def step(x, batch):
        loss, g = jax.value_and_grad(loss_fn)(x, batch)
        if cfg.weight_decay:
            g = tree_axpy(cfg.weight_decay, x, g)
        v = algo.direction(cfg, m_tree, cst_tree_i, x, x0_tree, g)
        # keep the carry dtype stable (bf16 params + f32 momentum promote)
        x = jax.tree_util.tree_map(
            lambda xi, vi: (xi - eta_l * vi).astype(xi.dtype), x, v
        )
        return x, loss

    xK, losses = jax.lax.scan(step, x0_tree, batches,
                              unroll=cfg.local_steps if unroll else 1)
    full_grad = None
    if algo.needs_full_grad:
        assert full_grad_batch is not None
        full_grad = jax.grad(loss_fn)(x0_tree, full_grad_batch)
    outs = sparse_client_finalize(algo, cfg, x0_tree, xK, cst_tree_i,
                                  m_tree, eta_l, full_grad)
    return outs, jnp.mean(losses)


# ----------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------


class FederatedEngine:
    """Builds the jitted round step for (algorithm, loss_fn, data layout).

    Usage::

        eng = FederatedEngine(cfg, loss_fn)
        state = eng.init(params, rng)
        state, metrics = eng.run_rounds(state, data, n_rounds)   # fused scan
        state, metrics = eng.run_round(state, data)     # one round at a time
        # or, lower-level / dry-runnable:
        state, metrics = eng.round_step(state, batches, ids, mask, full_batches)

    ``client_sharding`` (a ``NamedSharding`` whose spec names the mesh axes
    for the cohort dimension, e.g. ``NamedSharding(mesh, P(("pod","data")))``)
    is applied as a sharding constraint to the leading axis of every
    cohort-stacked array — minibatches, gathered client states, and the
    MimeLite full batches — in both the per-round and fused paths.
    """

    def __init__(
        self,
        cfg: FedConfig,
        loss_fn: Callable[[Any, Any], jax.Array],
        batch_size: int = 50,
        client_sharding: Optional[Any] = None,  # NamedSharding for the cohort axis
        cohort_mesh: Optional[Any] = None,  # Mesh with a "clients" axis
    ) -> None:
        self.cfg = cfg
        self.algo = get_algorithm(cfg.algo)
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.client_sharding = client_sharding
        self.analysis_unroll = False  # dry-run analysis form
        # ---- cohort-parallel (SPMD-over-clients) execution path ----
        # a Mesh with a "clients" axis turns every cohort phase into
        # shard_map over that axis: each device owns C/num_shards clients
        # end-to-end and the server fold becomes an explicit
        # reduce-scatter/all-gather (kernels/server_update/ops.scatter_fold).
        # cfg.cohort_shard > 0 is the data-only way to ask for it (the
        # engine builds the mesh over the first N visible devices).
        if cohort_mesh is None and getattr(cfg, "cohort_shard", 0) > 0:
            from repro.launch.mesh import make_cohort_mesh

            cohort_mesh = make_cohort_mesh(cfg.cohort_shard)
        self.cohort_mesh = cohort_mesh
        self._cohort_shards = 1
        if cohort_mesh is not None:
            if not cfg.use_flat_plane:
                raise ValueError(
                    "cohort-parallel execution runs on the flat parameter "
                    "plane — it shards (C, P) uplink planes; set "
                    "cfg.use_flat_plane=True (the tree path stays the "
                    "single-device oracle)"
                )
            if not cfg.use_fused_kernel:
                raise ValueError(
                    "cohort-parallel execution rides the flat+kernel path "
                    "(clients produce (C, P) planes, the fold is the "
                    "scattered server kernel) — set cfg.use_fused_kernel="
                    "True / pass --fused-kernel"
                )
            if client_sharding is not None:
                raise ValueError(
                    "cohort_mesh (shard_map over clients) and "
                    "client_sharding (GSPMD cohort-axis constraints) are "
                    "alternative lowerings of the same axis — pass one"
                )
            self._cohort_shards = cohort_axis_size(cohort_mesh)
        self._round_step = jax.jit(self._round_step_impl)
        # traced once per (shapes, n_rounds) — the compile-count regression
        # test asserts a 100-round run is ONE trace, not 100
        self.run_rounds_traces = 0
        self._run_rounds = jax.jit(
            self._run_rounds_impl,
            static_argnames=("n_rounds",),
            donate_argnums=(0,),
        )
        self.run_rounds_async_traces = 0
        self._run_rounds_async = jax.jit(
            self._run_rounds_async_impl,
            static_argnames=(
                "n_rounds", "pipeline_depth", "staleness", "eval_every",
                "predict_fn", "scan_unroll",
            ),
            donate_argnums=(0,),
        )
        # donate the state only: the pending uplinks are consumed, not
        # updated — most of their buffers have no same-shaped output to
        # alias into and donating them just trips "unusable donation"
        # warnings
        self._drain_async = jax.jit(
            self._drain_async_impl,
            static_argnames=("pipeline_depth",),
            donate_argnums=(0,),
        )

    # -------------------------------------------------- init
    def init(self, params, rng) -> FedState:
        """Allocate the FedState the registered spec requires: the stacked
        per-client planes iff ``needs_client_state``, the second-moment
        plane iff ``needs_second_moment`` — allocation is derived from the
        spec's state-plane flags, never from algorithm names."""
        state = FedState(
            params=params,
            server=server_init(params, self.cfg.momentum_dtype,
                               needs_second_moment=self.algo.needs_second_moment),
            client_states=client_state_init(params, self.cfg),
            rng=rng,
        )
        # flat engine + sub-f32 leaves: attach the f32 master planes up
        # front so every later call sees one stable treedef (no master→
        # no-master retrace) and run_round/run_rounds share one precision
        # contract from round 0
        if self.cfg.use_flat_plane:
            try:
                spec = FlatSpec.from_tree(params)
            except TypeError:  # non-float leaves: flat path will refuse anyway
                return state
            if self._needs_master(spec):
                cst = None
                if state.client_states is not None and self.cfg.use_fused_kernel:
                    cst = spec.ravel(state.client_states, batch_dims=1)
                sm = state.server.second_moment
                state = state._replace(master=FlatMaster(
                    params=spec.ravel(params),
                    second_moment=spec.ravel(sm) if sm is not None else None,
                    client_states=cst,
                ))
        return state

    @staticmethod
    def _needs_master(spec: FlatSpec) -> bool:
        """True when rounding plane→leaves loses bits (any non-f32 leaf)."""
        return any(np.dtype(l.dtype) != np.float32 for l in spec.leaves)

    # -------------------------------------------------- payload accounting
    def payload_bytes(self, params) -> Dict[str, int]:
        """Per-client per-round communication in bytes (§4.2 discussion)."""
        return self._payload_from_nbytes(tree_bytes(params))

    def _payload_from_nbytes(self, P: int) -> Dict[str, int]:
        """Payload accounting from a total byte count — the flat path charges
        ``FlatSpec.nbytes`` (the wire dtypes), identical to ``tree_bytes``.
        Wire shapes are DERIVED from the spec's state-plane flags (§4.2)."""
        down = P  # x_t always goes down
        up = P  # Δ_i always goes up
        if self.algo.needs_momentum_broadcast:
            down += P  # Δ_t (fedcm/mimelite) or c (scaffold)
        if self.algo.client_state_uplink:
            up += P  # SCAFFOLD Δc_i — feddyn's λ_i never leaves the client
        if self.algo.needs_full_grad:
            up += P  # MimeLite full-batch gradient
        return {"down_per_client": down, "up_per_client": up}

    # -------------------------------------------------- cohort sharding
    def _constrain_cohort(self, tree):
        """Pin the leading (cohort) axis of every leaf to ``client_sharding``."""
        if self.client_sharding is None or tree is None:
            return tree
        mesh = self.client_sharding.mesh
        spec = self.client_sharding.spec
        cohort_axes = spec[0] if len(spec) else None

        def pin(a):
            s = NamedSharding(mesh, P(cohort_axes, *([None] * (a.ndim - 1))))
            return jax.lax.with_sharding_constraint(a, s)

        return jax.tree_util.tree_map(pin, tree)

    # -------------------------------------------------- flat plane
    def _ravel_state(self, state: FedState, spec: FlatSpec) -> FedState:
        """Tree state → flat-plane state: the ONE ravel of a run_rounds call.
        Params/second-moment become f32 ``(P,)`` planes and momentum a
        ``momentum_dtype`` plane.  Stacked per-client control variates
        become an ``(N, P)`` plane on the kernel path (whose clients
        produce flat buffers anyway, so gather/scatter are ONE op each);
        the jnp path keeps them in leaf form — its local steps consume
        leaves, and a per-round (C, P) concatenate costs more than the
        per-leaf gather/scatter it would replace.

        A carried ``state.master`` (sub-f32 trees) takes precedence over
        re-ravelling the rounded leaves: that is what makes sequential
        ``run_round`` calls bitwise-continue the f32 trajectory instead of
        re-rounding at every boundary."""
        cfg, mst = self.cfg, state.master
        sm = state.server.second_moment
        fsrv = ServerState(
            # momentum plane and tree share momentum_dtype — ravel is exact,
            # no master needed
            momentum=spec.ravel(state.server.momentum, dtype=cfg.momentum_dtype),
            second_moment=(mst.second_moment if mst is not None
                           else (spec.ravel(sm) if sm is not None else None)),
            round=state.server.round,
        )
        fcst = state.client_states
        if fcst is not None and cfg.use_fused_kernel:
            fcst = (mst.client_states if mst is not None and
                    mst.client_states is not None
                    else spec.ravel(fcst, batch_dims=1))
        params = mst.params if mst is not None else spec.ravel(state.params)
        return FedState(params, fsrv, fcst, state.rng)

    def _unravel_state(self, fstate: FedState, spec: FlatSpec) -> FedState:
        """Flat-plane state → tree state (leaf shapes AND dtypes restored).
        For sub-f32 trees the un-rounded planes ride along as ``master``."""
        cfg = self.cfg
        fsm = fstate.server.second_moment
        srv = ServerState(
            momentum=spec.unravel(fstate.server.momentum, dtype=cfg.momentum_dtype),
            second_moment=spec.unravel(fsm) if fsm is not None else None,
            round=fstate.server.round,
        )
        cst = fstate.client_states
        cst_is_plane = cst is not None and cfg.use_fused_kernel
        if cst_is_plane:
            cst = spec.unravel(cst)
        master = None
        if self._needs_master(spec):
            master = FlatMaster(
                params=fstate.params,
                second_moment=fstate.server.second_moment,
                client_states=fstate.client_states if cst_is_plane else None,
            )
        return FedState(spec.unravel(fstate.params), srv, cst, fstate.rng, master)

    def _flat_cohort_pass(self, fstate: FedState, batches, ids, mask,
                          full_batches, spec: FlatSpec, m_t, eta_l):
        """The cohort's client phase on the flat plane: gather per-client
        state, vmap the K-local-step update over the cohort.  Shared
        VERBATIM by the sync round (``_flat_round_step``) and the async
        launch (``_launch_async_cohort``) — ``m_t`` is the broadcast buffer
        the clients descend against (the CURRENT momentum for sync, an
        S-rounds-stale one for the pipelined path).

        Returns (outs, losses, cohort_cst) where cohort_cst is the (C, P)
        gathered client-state plane on the kernel path (None otherwise)."""
        cfg, algo = self.cfg, self.algo
        batches = self._constrain_cohort(batches)

        x_t = fstate.params  # (P,) f32
        # leaf views for the local scan — unravelled ONCE per round (x0 is
        # the scan carry init, so its slices materialize at loop entry; the
        # momentum view is a loop-invariant closure)
        x0_tree = spec.unravel(x_t)
        m_tree = spec.unravel(m_t, dtype=cfg.momentum_dtype)

        cohort_cst = cohort_cst_tree = None
        if algo.needs_client_state:
            if cfg.use_fused_kernel:  # (N, P) plane: ONE gather
                cohort_cst = self._constrain_cohort(fstate.client_states[ids])
            else:  # leaf form, as the local steps consume it
                cohort_cst_tree = self._constrain_cohort(
                    jax.tree_util.tree_map(lambda a: a[ids], fstate.client_states)
                )
        full = None
        if algo.needs_full_grad:
            full = self._constrain_cohort(full_batches)

        def one_client(cst_tree_i, cst_flat_i, batches_i, full_i):
            return flat_client_update(
                algo, cfg, self.loss_fn, spec, x_t, x0_tree, m_t, m_tree,
                cst_tree_i, cst_flat_i, batches_i, eta_l,
                full_grad_batch=full_i, unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst_tree, cohort_cst, batches, full)
        return outs, losses, cohort_cst

    # -------------------------------------------------- cohort-parallel
    @property
    def _sharded(self) -> bool:
        return self.cohort_mesh is not None

    def _pad_cohort(self, tree, mode: str = "edge"):
        """Pad the leading cohort axis to a multiple of the mesh's
        ``"clients"`` axis.  Applied AFTER the minibatch/state gathers —
        the rng stream and every real client's inputs stay bitwise those
        of the unsharded round.  Data pads by edge-repeat (pad clients
        compute on a real client's finite inputs — a batch-normalizing
        loss_fn on all-zero input would emit NaN, and ``0 · NaN`` poisons
        the fold); the weight row pads with exact zeros (``mode="zero"``)
        so pad rows never count."""
        target = padded_cohort(cohort_capacity(self.cfg), self._cohort_shards)
        return pad_cohort(tree, target, mode=mode)

    def _sharded_cohort_pass(self, fstate: FedState, batches, ids, mask,
                             full_batches, spec: FlatSpec, m_t, eta_l):
        """The cohort's client phase SPMD over the ``"clients"`` mesh axis:
        each device runs the K-local-step update for its C/num_shards
        clients end-to-end inside ``shard_map`` — sampling gathers happen
        before entry (replicated rng), ``fed_direction`` kernel launches
        stay device-local, and no collective runs until the fold.

        Same contract as ``_flat_cohort_pass`` (kernel-path layout), with
        the cohort axis PADDED to the shard count: ``outs`` planes are
        ``(C_pad, P)`` sharded over clients, ``losses`` is ``(C_pad,)``,
        and ``cohort_cst`` is the UNpadded ``(C, P)`` gather (the
        client-state scatter consumes only real rows)."""
        cfg, algo = self.cfg, self.algo

        cohort_cst = None
        if algo.needs_client_state:
            cohort_cst = fstate.client_states[ids]  # (C, P): ONE gather
        operands = {"batches": self._pad_cohort(batches)}
        if cohort_cst is not None:
            operands["cst"] = self._pad_cohort(cohort_cst)
        if algo.needs_full_grad:
            operands["full"] = self._pad_cohort(full_batches)

        plane_keys = tuple(algo.uplink_planes)

        def shard_body(x_t, m_t, eta_l, operands):
            x0_tree = spec.unravel(x_t)
            m_tree = spec.unravel(m_t, dtype=cfg.momentum_dtype)

            def one_client(cst_i, batches_i, full_i):
                return flat_client_update(
                    algo, cfg, self.loss_fn, spec, x_t, x0_tree, m_t, m_tree,
                    None, cst_i, batches_i, eta_l,
                    full_grad_batch=full_i, unroll=self.analysis_unroll,
                )

            outs, losses = jax.vmap(one_client)(
                operands.get("cst"), operands["batches"], operands.get("full")
            )
            out = {k: getattr(outs, k) for k in plane_keys}
            out["losses"] = losses
            return out

        sh, rep = P(COHORT_AXIS), P()
        out = shard_map(
            shard_body,
            mesh=self.cohort_mesh,
            in_specs=(rep, rep, rep, {k: sh for k in operands}),
            # uplink planes + the per-client loss row shard over clients —
            # derived from the registry's state-plane flags
            out_specs=cohort_uplink_specs(algo, extra=("losses",)),
            check_vma=False,
        )(fstate.params, m_t, eta_l, operands)
        outs = FlatClientOutputs(
            delta=out["delta"],
            state_delta=out.get("state_delta"),
            extra=out.get("extra"),
        )
        # replicate the per-client loss row before the metrics reduce it:
        # summing a clients-sharded (C,) array would lower to per-device
        # partial sums + all-reduce, re-associating the f32 sum away from
        # the unsharded metric (the planes stay sharded — their reductions
        # go through the scattered fold, which preserves order by design)
        losses = jax.lax.with_sharding_constraint(
            out["losses"], NamedSharding(self.cohort_mesh, P())
        )
        return outs, losses, cohort_cst

    def _sharded_round_close(self, algo, fsrv, outs, wp, n_active, x_t, eta_l,
                             discount=1.0):
        """``_fused_round_close`` under cohort sharding: the fold rows run
        through the scattered server kernel (``scatter_fold`` inside
        ``shard_map`` — all_to_all to plane columns, device-local
        full-cohort reduce, kernel launch per row, all_gather), and the
        spec's pure post-step then runs on the REPLICATED ``(P,)`` planes
        at the same program level (and with the same shapes) as the
        unsharded close — elementwise posts stay bitwise that way."""
        cfg = self.cfg
        planes = {k: getattr(outs, k) for k in algo.fold_planes}
        nsh = self._cohort_shards

        def fold_body(planes, wp, n_active, x, m, eta_l):
            return scatter_fold(
                algo, cfg, planes, wp / n_active, n_active, x, m, eta_l,
                discount=discount, axis_name=COHORT_AXIS, n_shards=nsh,
            )

        sh, rep = P(COHORT_AXIS), P()
        new_x, new_m, mean_delta = shard_map(
            fold_body,
            mesh=self.cohort_mesh,
            in_specs=({k: sh for k in planes}, rep, rep, rep, rep, rep),
            out_specs=(rep, rep, rep),
            check_vma=False,
        )(planes, wp, n_active, x_t, fsrv.momentum, eta_l)
        return self._close_post(algo, fsrv, new_x, new_m, mean_delta,
                                n_active, eta_l, discount)

    def _close_post(self, algo, fsrv, new_x, new_m, mean_delta, n_active,
                    eta_l, discount):
        """Shared tail of the kernel round close (fused AND scattered):
        adopt the folded momentum, then run the spec's pure post-step on
        the replicated planes with the discount-weighted mean.  ONE
        implementation — the sync/async and sharded/unsharded closes must
        never drift in how γ reaches the post."""
        new_server = fsrv._replace(momentum=new_m)
        if algo.server_post_fn is not None:
            dmean = mean_delta if discount == 1.0 else discount * mean_delta
            new_x, new_server = algo.server_post_fn(
                self.cfg, new_x, new_server, dmean, n_active, eta_l
            )
        return new_x, new_server, mean_delta

    def _sharded_means(self, outs, wp, n_active):
        """Masked cohort means of every uplink plane as scattered
        reductions (``cohort_mean_scatter`` inside ``shard_map``) — the
        sharded analog of the kernel-path ``_masked_pmean`` calls feeding
        a ``server_fn`` escape-hatch spec.  Returns (mean_delta, mean_sd,
        mean_extra) with ``None`` for planes the spec never produced."""
        cfg = self.cfg
        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))
        planes = {k: getattr(outs, k) for k in self.algo.uplink_planes
                  if getattr(outs, k) is not None}
        nsh = self._cohort_shards

        def body(planes, wp, n_active):
            return {k: cohort_mean_scatter(v, wp, n_active, COHORT_AXIS, nsh,
                                           agg_dtype=agg_dt)
                    for k, v in planes.items()}

        sh, rep = P(COHORT_AXIS), P()
        means = shard_map(
            body,
            mesh=self.cohort_mesh,
            in_specs=({k: sh for k in planes}, rep, rep),
            out_specs={k: rep for k in planes},
            check_vma=False,
        )(planes, wp, n_active)
        return means.get("delta"), means.get("state_delta"), means.get("extra")

    def _masked_pmean(self, x, w, n_active):
        """Masked cohort mean of one uplink, reduced straight to a flat
        ``(P,)`` buffer (quantized to ``cfg.aggregate_dtype`` first, like
        every aggregation path).  jnp path: ``x`` is a (C, *shape) leaf
        tree — contract per leaf and concatenate only the tiny means
        (materializing the full (C, P) plane costs more than it saves).
        Kernel path: ``x`` IS a (C, P) plane — one contraction.  ``None``
        passes through (planes that were never materialized)."""
        if x is None:
            return None
        cfg = self.cfg
        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def leaf_mean(a):
            return (
                jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                .astype(jnp.float32) / n_active
            )

        if cfg.use_fused_kernel:  # (C, P) plane
            return leaf_mean(x)
        return ravel_leaves(
            [leaf_mean(l) for l in jax.tree_util.tree_leaves(x)], jnp.float32
        )

    def _flat_round_step(self, fstate: FedState, batches, ids, mask,
                         full_batches, spec: FlatSpec):
        """One round entirely on the flat plane: (P,) carry through the
        local-step scan, (C, P) cohort planes through aggregation, (N, P)
        client-state scatter.  Same math as ``_tree_round_step`` — the
        equivalence tests in tests/test_flat.py hold the two bitwise-close."""
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, fstate.server.round)
        x_t = fstate.params  # (P,) f32
        m_t = fstate.server.momentum  # (P,) momentum_dtype
        cohort_pass = (self._sharded_cohort_pass if self._sharded
                       else self._flat_cohort_pass)
        outs, losses, cohort_cst = cohort_pass(
            fstate, batches, ids, mask, full_batches, spec, m_t, eta_l
        )

        # masked cohort means, reduced straight to flat (P,) buffers
        # (_masked_pmean; unused planes are None — never materialized,
        # never reduced, where the tree path pays for both)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)
        # cohort-parallel: pad rows carry zero weight — trailing +0.0
        # terms keep every reduction bitwise the unsharded one's
        wp = self._pad_cohort(w, mode="zero") if self._sharded else w
        use_kernel = cfg.use_fused_kernel and algo.server_fn is None

        fsrv = fstate.server
        if use_kernel and self._sharded:
            new_params, new_server, mean_delta = self._sharded_round_close(
                algo, fsrv, outs, wp, n_active, x_t, eta_l
            )
            new_server = new_server._replace(round=fsrv.round + 1)
        elif use_kernel:
            new_params, new_server, mean_delta = self._fused_round_close(
                algo, fsrv, outs, w, n_active, x_t, eta_l
            )
            new_server = new_server._replace(round=fsrv.round + 1)
        else:
            if self._sharded:  # kernel-path spec with a server_fn escape
                mean_delta, mean_sd, mean_extra = self._sharded_means(
                    outs, wp, n_active
                )
            else:
                mean_delta = self._masked_pmean(outs.delta, w, n_active)
                mean_sd = self._masked_pmean(outs.state_delta, w, n_active)
                mean_extra = self._masked_pmean(outs.extra, w, n_active)
            new_params, new_server = algo.server_update(
                cfg, x_t, fsrv, mean_delta, mean_sd, mean_extra,
                n_active, eta_l,
            )

        # scatter updated client states back (only active cohort members):
        # ONE scatter on the (N, P) plane (kernel path; sharded planes are
        # padded — only real rows scatter) or per-leaf like the tree
        # oracle (jnp path)
        new_cst = fstate.client_states
        if algo.needs_client_state:
            if self._sharded:
                C = ids.shape[0]
                upd = cohort_cst + outs.state_delta[:C] * w[:, None]
                new_cst = fstate.client_states.at[ids].set(upd)
            elif cfg.use_fused_kernel:  # (N, P) plane representation
                upd = cohort_cst + outs.state_delta * w[:, None]
                new_cst = fstate.client_states.at[ids].set(upd)
            else:
                def scatter(a, d):
                    upd = a[ids] + d * w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                    return a.at[ids].set(upd)

                new_cst = jax.tree_util.tree_map(
                    scatter, fstate.client_states, outs.state_delta
                )

        pay = self._payload_from_nbytes(spec.nbytes)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * wp) / n_active,
            n_active=n_active,
            delta_norm=_flat_norm(mean_delta),
            momentum_norm=_flat_norm(m_t),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
        )
        return FedState(new_params, new_server, new_cst, fstate.rng), metrics

    def _fused_round_close(self, algo, fsrv, outs, w, n_active, x_t, eta_l,
                           discount=1.0):
        """Round-close via the fused server kernel: the spec's fold rows
        execute as ``server_update`` passes over the ``(C, P)`` uplink
        planes (``kernels/server_update/ops.fused_fold``), then the spec's
        optional pure post-step runs on the resulting flat planes —
        array-polymorphic, so FedAdam's preconditioner is the same code on
        both paths.

        ``discount`` is the staleness weight γ the async engine applies to
        folded in-flight cohorts — it rides the kernel's SMEM coefficient
        row (1.0 for the sync path: a f32 multiply by 1.0 is exact).  The
        returned ServerState keeps the caller's round counter (sync bumps
        it, the async fold is launch-aligned)."""
        cfg = self.cfg
        planes = {"delta": outs.delta, "state_delta": outs.state_delta,
                  "extra": outs.extra}
        new_x, new_m, mean_delta = fused_fold(
            algo, cfg, planes, w / n_active, n_active, x_t, fsrv.momentum,
            eta_l, discount=discount,
        )
        return self._close_post(algo, fsrv, new_x, new_m, mean_delta,
                                n_active, eta_l, discount)

    # -------------------------------------------------- round
    def _round_step_impl(self, state: FedState, batches, ids, mask, full_batches):
        if self.cfg.use_flat_plane:
            spec = FlatSpec.from_tree(state.params)
            fstate = self._ravel_state(state, spec)
            fstate, metrics = self._flat_round_step(
                fstate, batches, ids, mask, full_batches, spec
            )
            return self._unravel_state(fstate, spec), metrics
        return self._tree_round_step(state, batches, ids, mask, full_batches)

    def _tree_round_step(self, state: FedState, batches, ids, mask, full_batches):
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, state.server.round)

        batches = self._constrain_cohort(batches)
        full_batches = self._constrain_cohort(full_batches)

        # gather per-client states for the cohort (stale entries untouched)
        if algo.needs_client_state:
            cohort_cst = jax.tree_util.tree_map(lambda a: a[ids], state.client_states)
        else:
            cohort_cst = jax.tree_util.tree_map(
                lambda p: jnp.zeros((ids.shape[0], *p.shape), p.dtype), state.params
            )
        cohort_cst = self._constrain_cohort(cohort_cst)

        def one_client(cst_i, batches_i, full_i):
            return client_update(
                algo, cfg, self.loss_fn, state.params, state.server.momentum,
                cst_i, batches_i, eta_l, full_grad_batch=full_i,
                unroll=self.analysis_unroll,
            )

        outs, losses = jax.vmap(one_client)(cohort_cst, batches, full_batches)

        # masked cohort mean (bernoulli: only active entries count)
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)

        agg_dt = jnp.dtype(getattr(cfg, "aggregate_dtype", "float32"))

        def mmean(tree):
            return jax.tree_util.tree_map(
                lambda a: (
                    jnp.tensordot(w.astype(agg_dt), a.astype(agg_dt), axes=(0, 0))
                    .astype(jnp.float32) / n_active
                ),
                tree,
            )

        mean_delta = mmean(outs.delta)
        mean_sd = mmean(outs.state_delta)
        mean_extra = mmean(outs.extra)

        new_params, new_server = algo.server_update(
            cfg, state.params, state.server, mean_delta, mean_sd, mean_extra,
            n_active, eta_l,
        )

        # scatter updated client states back (only active cohort members)
        new_cst = state.client_states
        if algo.needs_client_state:
            def scatter(a, d):
                upd = a[ids] + d * w.reshape((-1,) + (1,) * (d.ndim - 1)).astype(a.dtype)
                return a.at[ids].set(upd)

            new_cst = jax.tree_util.tree_map(scatter, state.client_states, outs.state_delta)

        pay = self.payload_bytes(state.params)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * w) / n_active,
            n_active=n_active,
            delta_norm=_tree_norm(mean_delta),
            momentum_norm=_tree_norm(state.server.momentum),
            eta_l=eta_l,
            bytes_down=n_active * jnp.float32(pay["down_per_client"]),
            bytes_up=n_active * jnp.float32(pay["up_per_client"]),
        )
        return FedState(new_params, new_server, new_cst, state.rng), metrics

    def round_step(self, state, batches, ids, mask, full_batches=None):
        if full_batches is None:
            # zero-size placeholder with the right treedef for vmap
            full_batches = jax.tree_util.tree_map(
                lambda b: b[:, 0], batches
            )  # (C, B, …) dummy; unused unless needs_full_grad
        return self._round_step(state, batches, ids, mask, full_batches)

    # -------------------------------------------------- data-driven round
    def _prepare_round(self, state: FedState, client_x, client_y):
        """Per-round setup shared VERBATIM by ``run_round`` and the
        ``run_rounds`` scan body: rng threading, cohort sampling, minibatch
        and (MimeLite) full-batch gathers.  One implementation is what
        makes the two paths' trajectories identical — don't fork it.

        Returns (state-with-advanced-rng, batches, ids, mask, full).
        """
        rng, k_cohort, k_batch = jax.random.split(state.rng, 3)
        ids, mask = sample_cohort(k_cohort, self.cfg)
        raw = gather_round_batches(
            client_x, client_y, k_batch, ids, self.cfg.local_steps, self.batch_size
        )
        batches = self._to_loss_batches(raw)
        if self.algo.needs_full_grad:
            full = self._to_loss_batches(
                gather_full_client_batch(client_x, client_y, ids)
            )
        else:
            # (C, B, ...) dummy with the right treedef for vmap; unused
            # unless needs_full_grad
            full = jax.tree_util.tree_map(lambda b: b[:, 0], batches)
        return state._replace(rng=rng), batches, ids, mask, full

    def run_round(self, state: FedState, data) -> Tuple[FedState, RoundMetrics]:
        """Samples cohort + minibatches from a FederatedData and steps."""
        state, batches, ids, mask, full = self._prepare_round(
            state, data.client_x, data.client_y
        )
        return self.round_step(state, batches, ids, mask, full)

    # -------------------------------------------------- fused multi-round
    def run_rounds(self, state: FedState, data, n_rounds: int) -> Tuple[FedState, RoundMetrics]:
        """Execute ``n_rounds`` communication rounds as ONE jitted lax.scan.

        Cohort sampling and minibatch drawing happen inside the scan body
        (no host round-trips), the carried ``FedState`` is donated, and the
        per-round metrics come back stacked with a leading ``(n_rounds,)``
        axis.  Numerically equivalent to calling ``run_round`` ``n_rounds``
        times (same rng threading, same ``_round_step_impl``); the
        equivalence test in tests/test_run_rounds.py holds all algorithms
        to that.  Sub-f32 param leaves on the flat plane now agree at the
        SAME tolerance: both paths carry the same f32 master planes
        (``FedState.master``) across round boundaries and only the
        returned leaf views are rounded — ``run_round`` no longer
        re-rounds the carried state each boundary (the PR-2 divergence
        this closes; the bf16 regression test in tests/test_run_rounds.py
        pins the contract).

        The input ``state`` may be donated to the computation — use the
        returned state, not the argument, afterwards.
        """
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        return self._run_rounds(state, data.client_x, data.client_y, n_rounds=n_rounds)

    def _run_rounds_impl(self, state: FedState, client_x, client_y, n_rounds: int):
        self.run_rounds_traces += 1  # python side effect: counts traces only

        if self.cfg.use_flat_plane:
            # ravel ONCE for the whole N-round program; the scan carries
            # (P,)/(N,P) planes and unravels once at the end
            spec = FlatSpec.from_tree(state.params)
            fstate = self._ravel_state(state, spec)

            def flat_body(st, _):
                st, batches, ids, mask, full = self._prepare_round(st, client_x, client_y)
                return self._flat_round_step(st, batches, ids, mask, full, spec)

            fstate, metrics = jax.lax.scan(flat_body, fstate, None, length=n_rounds)
            return self._unravel_state(fstate, spec), metrics

        def body(st, _):
            st, batches, ids, mask, full = self._prepare_round(st, client_x, client_y)
            return self._tree_round_step(st, batches, ids, mask, full)

        return jax.lax.scan(body, state, None, length=n_rounds)

    # -------------------------------------------------- async pipelined rounds
    def run_rounds_async(
        self,
        state: FedState,
        data,
        n_rounds: int,
        *,
        pipeline_depth: Optional[int] = None,
        staleness: Optional[int] = None,
        eval_every: int = 0,
        eval_data: Optional[Tuple[Any, Any]] = None,
        predict_fn: Optional[Callable[[Any, Any], jax.Array]] = None,
        eval_batch_size: int = 1000,
        drain: bool = True,
        scan_unroll: int = 1,
    ) -> Tuple[FedState, AsyncRoundMetrics]:
        """Overlapping-cohort (stale-momentum) FedCM: ONE pipelined lax.scan.

        Every scan iteration LAUNCHES one cohort against the current params
        and a broadcast momentum that is ``staleness`` rounds stale, pushes
        its uplink — cohort delta plane plus per-algorithm extras
        (``repro.core.flat.CohortUplink``) — into a depth-``pipeline_depth``
        ring carried by the scan, and FOLDS the oldest in-flight cohort
        into the server state.  A folded cohort is therefore
        ``pipeline_depth − 1`` rounds old: its clients descended from
        params the server has since moved past — exactly the
        delayed/partial aggregation client-level momentum is robust to
        (Cheng et al. 2023), with the fold weighted by the FedACG-style
        discount ``cfg.staleness_discount ** (depth−1)`` carried into the
        fused server kernel's SMEM coefficient row.

        ``pipeline_depth=1, staleness=0`` IS the sync schedule: the slot
        pushed at iteration t is popped at iteration t, the discount is
        γ⁰ = 1, and the trajectory matches ``run_rounds`` exactly (the
        equivalence test in tests/test_run_rounds.py holds all six
        algorithms to it).

        The first ``pipeline_depth − 1`` iterations fold nothing (pipeline
        fill — unrolled launch-only steps that grow the ring to its static
        depth; ``metrics.folded`` is 0 there), and with ``drain=True``
        (default) the cohorts still in flight at the end are folded by a
        fixed-size epilogue dispatch so no client work is discarded —
        ``n_rounds`` launches, ``n_rounds`` folds, still zero host
        round-trips (the epilogue's operands never leave the device;
        keeping it in the main program makes XLA clone the whole scan
        body around the final carry, measurably slower than a second
        dispatch).

        ``eval_every > 0`` moves evaluation device-resident INSIDE the scan
        (requires ``predict_fn`` and ``eval_data=(x_test, y_test)``): every
        eval_every-th iteration runs the padded ``lax.map`` eval on the
        post-fold params, so a full train-with-eval run is ONE jitted
        program with zero host round-trips; off-cadence rounds report
        ``eval_acc = −1.0``.

        ``scan_unroll`` unrolls the steady scan body (static): the ring
        rotation materializes at the loop boundary once per UNROLLED
        GROUP instead of once per round — within a group the fold reads
        the previous launch's uplink as straight dataflow.  ``2`` wins
        ~8% per round on the CPU update-bound benchmark at D≥2; compile
        time scales with the factor (the sync scan has no ring boundary
        and keeps unroll=1).

        Requires ``cfg.use_flat_plane`` (the ring is a flat-plane carry).
        The input ``state`` may be donated — use the returned state.
        """
        cfg = self.cfg
        depth = cfg.pipeline_depth if pipeline_depth is None else pipeline_depth
        stale = cfg.staleness if staleness is None else staleness
        if n_rounds < 1:
            raise ValueError(f"n_rounds must be >= 1, got {n_rounds}")
        if depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got {depth}")
        if stale < 0:
            raise ValueError(f"staleness must be >= 0, got {stale}")
        if not cfg.use_flat_plane:
            raise ValueError(
                "run_rounds_async requires cfg.use_flat_plane=True — the "
                "in-flight cohort ring is a flat-plane carry (the tree path "
                "stays the sync oracle)"
            )
        xb = yb = wb = None
        if eval_every:
            if predict_fn is None or eval_data is None:
                raise ValueError(
                    "eval_every > 0 needs predict_fn and eval_data=(x, y)"
                )
            xb, yb, wb = _pad_eval_batches(eval_data[0], eval_data[1], eval_batch_size)
        state, pending, metrics = self._run_rounds_async(
            state, data.client_x, data.client_y, xb, yb, wb,
            n_rounds=n_rounds, pipeline_depth=depth, staleness=stale,
            eval_every=eval_every,
            predict_fn=predict_fn if eval_every else None,
            scan_unroll=scan_unroll,
        )
        if drain and len(pending):
            state = self._drain_async(state, pending, pipeline_depth=depth)
        return state, metrics

    def _run_rounds_async_impl(
        self, state: FedState, client_x, client_y, xb, yb, wb, *,
        n_rounds: int, pipeline_depth: int, staleness: int, eval_every: int,
        predict_fn, scan_unroll: int = 1,
    ):
        self.run_rounds_async_traces += 1  # python side effect: trace count
        cfg, algo = self.cfg, self.algo
        D, S = pipeline_depth, staleness

        spec = FlatSpec.from_tree(state.params)
        fstate = self._ravel_state(state, spec)
        # momentum delay line: slot t mod S holds the broadcast buffer as it
        # was ENTERING round t−S (read-before-write); seeded with the
        # initial momentum so the first S rounds see round-0 state.  Only
        # algorithms that broadcast momentum (fedcm/mimelite Δ_t, scaffold
        # c) feel S at all.
        mhist = None
        if S > 0 and algo.needs_momentum_broadcast:
            mhist = jnp.tile(fstate.server.momentum[None], (S, 1))
        # FedACG-style lookahead weight of a fold that is D−1 rounds stale —
        # STATIC (depth is static), so γ = 1 costs nothing on the sync path
        discount = float(cfg.staleness_discount) ** (D - 1)
        pay = self._payload_from_nbytes(spec.nbytes)

        def in_scan_eval(t, x_plane):
            if not eval_every or predict_fn is None:
                return jnp.float32(-1.0)

            def do_eval(xp):
                params = spec.unravel(xp)

                def one(args):
                    bx, by, bw = args
                    logits = predict_fn(params, bx)
                    hits = (jnp.argmax(logits, -1) == by).astype(jnp.float32)
                    return jnp.sum(hits * bw)

                return jnp.sum(jax.lax.map(one, (xb, yb, wb))) / jnp.sum(wb)

            if isinstance(t, int):  # unrolled warmup step: cadence is static
                return do_eval(x_plane) if (t + 1) % eval_every == 0 \
                    else jnp.float32(-1.0)
            return jax.lax.cond(
                jnp.mod(t + 1, eval_every) == 0, do_eval,
                lambda xp: jnp.float32(-1.0), x_plane,
            )

        def step(fst, pending, mhist, t, fold: bool):
            """One pipelined iteration.  ``fold`` is STATIC: the D−1
            warmup steps (pipeline fill — nothing old enough to fold) only
            grow the ring; every steady step rotates it — the popped
            uplink is by construction D−1 rounds old."""
            r0 = fst.server.round
            fst, batches, ids, mask, full = self._prepare_round(fst, client_x, client_y)
            if mhist is None:
                m_used = fst.server.momentum
            else:
                sm = jnp.mod(t, S)
                m_used = jax.lax.dynamic_index_in_dim(mhist, sm, 0, keepdims=False)
                mhist = jax.lax.dynamic_update_index_in_dim(
                    mhist, fst.server.momentum, sm, 0
                )
            entry, n_active, loss = self._launch_async_cohort(
                fst, m_used, batches, ids, mask, full, spec
            )
            if fold:
                oldest, pending = ring_push(pending, entry)
                fst, mean_norm = self._fold_async_slot(fst, oldest, spec, discount)
            else:
                pending = (*pending, entry)
                mean_norm = jnp.float32(0.0)
            # round counter is LAUNCH-aligned (η_l schedule stays in step
            # with the sync engine regardless of pipeline fill)
            fst = fst._replace(server=fst.server._replace(round=r0 + 1))
            metrics = AsyncRoundMetrics(
                loss=loss,
                n_active=n_active,
                delta_norm=mean_norm,
                momentum_norm=_flat_norm(m_used),
                eta_l=entry.eta_l,
                bytes_down=n_active * jnp.float32(pay["down_per_client"]),
                bytes_up=n_active * jnp.float32(pay["up_per_client"]),
                folded=jnp.float32(1.0 if fold else 0.0),
                eval_acc=in_scan_eval(t, fst.params),
            )
            return fst, pending, mhist, metrics

        # pipeline fill: D−1 launch-only steps, UNROLLED — they grow the
        # ring tuple, whose structure must be static before the scan
        pending: Tuple[CohortUplink, ...] = ()
        fill_metrics = []
        warmup = min(D - 1, n_rounds)
        for t in range(warmup):
            fstate, pending, mhist, m = step(fstate, pending, mhist, t, fold=False)
            fill_metrics.append(m)

        def body(carry, t):
            fst, pending, mh = carry
            fst, pending, mh, m = step(fst, pending, mh, t, fold=True)
            return (fst, pending, mh), m

        (fstate, pending, mhist), metrics = jax.lax.scan(
            body, (fstate, pending, mhist), jnp.arange(warmup, n_rounds),
            unroll=scan_unroll,
        )
        if fill_metrics:
            fill = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *fill_metrics
            )
            metrics = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=0), fill, metrics
            )
        return self._unravel_state(fstate, spec), pending, metrics

    def _drain_async_impl(self, state: FedState,
                          pending: Tuple[CohortUplink, ...], *,
                          pipeline_depth: int):
        """Pipeline flush: fold the ≤ D−1 cohorts still in flight at the
        end of a ``run_rounds_async`` scan, oldest first.  A separate
        dispatch ON PURPOSE: feeding the scan's final (state, ring)
        carries into fold arithmetic inside the same program makes XLA
        clone the entire scan body around the last iteration — one
        fixed-size epilogue program is cheaper than that, and its operands
        never leave the device."""
        spec = FlatSpec.from_tree(state.params)
        fstate = self._ravel_state(state, spec)
        # the same staleness weight the in-scan folds used (depth, not
        # len(pending): a shorter-than-depth run still launched at the
        # configured overlap)
        discount = float(self.cfg.staleness_discount) ** (pipeline_depth - 1)
        for entry in pending:
            fstate, _ = self._fold_async_slot(fstate, entry, spec, discount)
        return self._unravel_state(fstate, spec)

    def _launch_async_cohort(self, fstate: FedState, m_used, batches, ids,
                             mask, full, spec: FlatSpec):
        """Client phase of one pipelined iteration: run the cohort against
        (current params, stale momentum) and pack its uplink as a ring
        entry.  Kernel path: outputs already ARE ``(C, P)`` planes and ride
        raw (the fused server kernel wants the cohort axis).  jnp path:
        ``delta``/``extra`` are pre-reduced HERE to the fold-ready ``(P,)``
        masked means — the weights are launch-time constants, so this is
        the fold's exact value, computed by the exact sync reduction
        (``_masked_pmean``); only the per-client ``state_delta`` plane must
        survive to fold time (the scatter is per-client).

        Returns (entry, n_active, cohort masked-mean loss).

        Cohort-parallel: the pass runs SPMD over the ``"clients"`` axis
        and the ring entry's planes are the PADDED ``(C_pad, P)`` shards
        (``ids``/``w`` padded to match; pad rows weigh zero) — the ring
        then carries each device's own clients until the scattered fold
        consumes them D−1 rounds later, which is what gives the
        reduce-scatter D−1 rounds of compute to hide behind."""
        cfg, algo = self.cfg, self.algo
        eta_l = local_learning_rate(cfg, fstate.server.round)
        cohort_pass = (self._sharded_cohort_pass if self._sharded
                       else self._flat_cohort_pass)
        outs, losses, _ = cohort_pass(
            fstate, batches, ids, mask, full, spec, m_used, eta_l
        )
        w = mask.astype(jnp.float32)
        n_active = jnp.sum(w)
        wp = self._pad_cohort(w, mode="zero") if self._sharded else w

        if cfg.use_fused_kernel:
            delta_e, extra_e = outs.delta, outs.extra
        else:
            delta_e = self._masked_pmean(outs.delta, w, n_active)
            extra_e = self._masked_pmean(outs.extra, w, n_active)
        state_e = None
        if outs.state_delta is not None:
            state_e = (outs.state_delta if cfg.use_fused_kernel
                       else spec.ravel(outs.state_delta, batch_dims=1))

        entry = CohortUplink(
            delta=delta_e,
            state_delta=state_e,
            extra=extra_e,
            ids=(self._pad_cohort(ids) if self._sharded else ids).astype(jnp.int32),
            w=wp,
            eta_l=eta_l,
        )
        return entry, n_active, jnp.sum(losses * wp) / n_active

    def _fold_async_slot(self, fstate: FedState, entry: CohortUplink,
                         spec: FlatSpec, discount):
        """Server phase of one pipelined iteration: fold ONE ring entry —
        masked cohort mean, staleness-discounted momentum EMA + param step,
        client-state scatter — into the current flat state.  Every entry
        is a real launch (the unrolled pipeline fill means the ring never
        holds placeholders), so there is no validity masking to pay.  Uses
        the entry's LAUNCH-time η_l (the deltas were computed with it).
        Leaves the round counter alone — it is launch-aligned (see the
        scan body).

        Returns (new_fstate, ‖mean Δ‖ of the folded cohort)."""
        cfg, algo = self.cfg, self.algo
        w = entry.w  # (C_pad,) under cohort sharding — pad rows weigh 0
        n_active = jnp.sum(w)
        x_t = fstate.params
        fsrv = fstate.server
        use_kernel = cfg.use_fused_kernel and algo.server_fn is None

        if use_kernel and self._sharded:
            new_params, new_server, mean_delta = self._sharded_round_close(
                algo, fsrv, entry, w, n_active, x_t, entry.eta_l,
                discount=discount,
            )
        elif use_kernel:
            new_params, new_server, mean_delta = self._fused_round_close(
                algo, fsrv, entry, w, n_active, x_t, entry.eta_l,
                discount=discount,
            )
        else:
            if self._sharded:
                # scattered reductions of the ring's sharded (C_pad, P)
                # planes feeding the spec's server_fn escape hatch
                mean_delta, mean_sd, mean_extra = self._sharded_means(
                    entry, w, n_active
                )
            elif cfg.use_fused_kernel:
                # kernel-path algorithm whose round-close is a ``server_fn``
                # escape hatch: reduce the raw (C, P) planes exactly as the
                # sync kernel path does
                mean_delta = self._masked_pmean(entry.delta, w, n_active)
                mean_sd = self._masked_pmean(entry.state_delta, w, n_active)
                mean_extra = self._masked_pmean(entry.extra, w, n_active)
            else:
                # jnp path: delta/extra were pre-reduced at launch (the
                # weights are launch-time constants — same value, same
                # reduction, C× less ring state); only the per-client
                # state plane still needs its mean, reduced per leaf VIEW
                # so the contraction shapes match the sync round's exactly
                # (one plane-wide tensordot schedules its accumulation
                # differently and would break D=1 bitwise equality)
                mean_delta = entry.delta
                mean_extra = entry.extra
                mean_sd = None
                if entry.state_delta is not None:
                    mean_sd = self._masked_pmean(
                        spec.unravel(entry.state_delta, dtype=jnp.float32),
                        w, n_active,
                    )
            # the γ=1 sync fold stays bitwise: spec.server_update skips the
            # statically-1.0 discount multiply
            new_params, new_server = algo.server_update(
                cfg, x_t, fsrv, mean_delta, mean_sd, mean_extra,
                n_active, entry.eta_l, discount=discount,
            )
            new_server = new_server._replace(round=fsrv.round)

        # scatter the folded cohort's client-state updates (stale entries
        # of non-participants untouched)
        new_cst = fstate.client_states
        if algo.needs_client_state:
            if self._sharded:
                # padded ring rows are dropped BEFORE the scatter: a pad
                # id (0) colliding with a real cohort member would make
                # the duplicate-index .set nondeterministic
                C = cohort_capacity(cfg)
                ids_r, w_r = entry.ids[:C], w[:C]
                upd = (fstate.client_states[ids_r]
                       + entry.state_delta[:C] * w_r[:, None])
                new_cst = fstate.client_states.at[ids_r].set(upd)
            elif cfg.use_fused_kernel:  # (N, P) plane: ONE gather + scatter
                upd = fstate.client_states[entry.ids] + entry.state_delta * w[:, None]
                new_cst = fstate.client_states.at[entry.ids].set(upd)
            else:
                sd_tree = spec.unravel(entry.state_delta, dtype=jnp.float32)

                def scatter(a, d):
                    upd = a[entry.ids] + d * w.reshape(
                        (-1,) + (1,) * (d.ndim - 1)
                    ).astype(a.dtype)
                    return a.at[entry.ids].set(upd)

                new_cst = jax.tree_util.tree_map(
                    scatter, fstate.client_states, sd_tree
                )

        new_state = FedState(new_params, new_server, new_cst, fstate.rng)
        return new_state, _flat_norm(mean_delta)

    @staticmethod
    def _to_loss_batches(raw):
        """{"x","y"} → loss_fn batch dict (pass-through for custom dicts).

        Must stay traceable: ``run_rounds`` calls it inside a jitted scan.
        """
        return raw


def _tree_norm(t):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(t)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.float32(0.0)


def _flat_norm(x):
    """‖x‖₂ of one flat plane — same formulation as ``_tree_norm`` so flat
    and tree metrics agree bitwise for single-buffer input."""
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


# ----------------------------------------------------------------------
# evaluation
# ----------------------------------------------------------------------


def _pad_eval_batches(x, y, batch_size: int):
    """Pad + reshape a test set to ``(n_batches, B, …)`` with a 0/1 weight
    plane so padded rows never count — the shared prep of the host-side
    ``make_eval_fn`` and the in-scan eval of ``run_rounds_async``."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    n = x.shape[0]
    nb = max(1, -(-n // batch_size))
    pad = nb * batch_size - n
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    yp = jnp.pad(y, ((0, pad),))
    w = (jnp.arange(nb * batch_size) < n).astype(jnp.float32)

    def rs(a):
        return a.reshape((nb, batch_size) + a.shape[1:])

    return rs(xp), rs(yp), rs(w)


def make_eval_fn(predict_fn: Callable[[Any, Any], jax.Array], batch_size: int = 1000):
    """predict_fn(params, x) -> logits.  Returns eval(params, x, y) -> acc.

    Device-resident: the whole test set is evaluated by ONE jitted
    ``lax.map`` over padded ``(n_batches, B, …)`` batches — a single
    dispatch and a single device→host sync per call, instead of one of each
    per 1000 examples.  (The old per-batch python loop stalled ``fed_train``
    between fused ``run_rounds`` chunks.)  Padding rows carry zero weight,
    so the returned accuracy is exact for any n.  Retraces only when the
    padded shape changes, i.e. once per dataset.
    """

    @jax.jit
    def _evaluate(params, xb, yb, wb):
        def one(args):
            x, y, w = args
            logits = predict_fn(params, x)
            return jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32) * w)

        hits = jax.lax.map(one, (xb, yb, wb))
        return jnp.sum(hits) / jnp.sum(wb)

    def evaluate(params, x, y):
        xb, yb, wb = _pad_eval_batches(x, y, batch_size)
        return float(_evaluate(params, xb, yb, wb))

    return evaluate
