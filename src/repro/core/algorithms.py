"""Builtin federated algorithms as registry specs (paper + baselines + family).

Algorithm 2 of the paper (FedCM) and every baseline it compares against —
FedAvg [McMahan+17], FedAdam [Reddi+20], SCAFFOLD [Karimireddy+20b],
FedDyn [Acar+21], MimeLite [Karimireddy+20a] — plus the wider
momentum-corrected family the registry makes cheap to add: FedAvgM
[Hsu+19] (server heavy-ball), FedAdagrad / FedYogi [Reddi+20] (adaptive
server optimizers), FedACG-style Nesterov server acceleration
[Kim+22, arXiv:2201.03172], and FedProx [Li+20] (the ``c_x``-only
proximal row).  Every algorithm is an ``AlgorithmSpec``
(``repro.core.registry``): a client-direction coefficient row, server-fold
coefficient rows (+ optional pure post-step), and state-plane flags — the
engine contains zero per-algorithm branches.

The *paper-faithful* convention (appendix C.2) is used throughout: the
pseudo-gradient is ``Δ_{t+1} = −(1/(η_l·K)) · mean_i(x_{i,K} − x_t)`` and
the server step on it is ``η_g·η_l·K``, so ``η_g = 1`` corresponds to
plain client-model averaging.  The adaptive server methods (FedAdam /
FedAdagrad / FedYogi) apply their update to the pseudo-gradient with an
absolute server lr (η_g = 0.1 in the paper).

Statelessness matters: FedCM/FedAvg/FedAdam/MimeLite/FedAvgM/FedACG keep
NO per-client state; SCAFFOLD and FedDyn keep per-client control variates,
which is exactly what the paper blames for their degradation at 2%
participation — the engine stores them stacked ``(N, …)`` and leaves
non-participants stale, reproducing that failure mode honestly.  At fleet
scale (``cfg.population_store="host"``) the same planes live out-of-core
in ``repro.data.population.HostPopulationStore`` instead —
``client_state_init`` returns None and the engine gathers/scatters
``(C, P)`` cohort rows per round, bitwise-matching the resident plane.

Flat fast path: every spec interpreter is *array-polymorphic* — a bare jax
array is a single-leaf pytree, so ``spec.direction``/``spec.server_update``
run unchanged on the flat ``(P,)`` parameter plane (``repro.core.flat``).
The flat-only additions are ``FlatClientOutputs`` (optional planes:
algorithms without client state / full-batch grads carry ``None`` instead
of a materialized ``(C, P)`` zeros plane) and ``sparse_client_finalize``
which produces them with the same op order as the tree finalizer, so the
two paths stay bitwise-comparable (tests/test_flat.py holds them to it).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.registry import (  # noqa: F401  (re-exported public API)
    Algorithm,
    AlgorithmSpec,
    ClientOutputs,
    DirectionRow,
    FoldPass,
    ServerState,
    client_state_init,
    get_algorithm,
    list_algorithms,
    register_algorithm,
    server_init,
)
from repro.utils.trees import tree_axpy, tree_scale, tree_sub


class _AlgorithmsView:
    """Read-only dict-like view of the registry (back-compat for the old
    module-level ``ALGORITHMS`` dict)."""

    def __getitem__(self, name: str) -> AlgorithmSpec:
        return get_algorithm(name)

    def __contains__(self, name: str) -> bool:
        return name in list_algorithms()

    def __iter__(self):
        return iter(list_algorithms())

    def __len__(self) -> int:
        return len(list_algorithms())

    def keys(self):
        return list_algorithms()

    def items(self):
        return [(n, get_algorithm(n)) for n in list_algorithms()]


ALGORITHMS = _AlgorithmsView()


# ----------------------------------------------------------------------
# shared coefficient / post-step pieces
# ----------------------------------------------------------------------


def _eta_g_eff(cfg: FedConfig, eta_l) -> jax.Array:
    # appendix C.2: η_g is reported in "averaging" units; effective server
    # step on Δ_{t+1} is η_g·η_l·K, i.e. x ← x + η_g·mean(Δ_i).
    return cfg.eta_g * eta_l * cfg.local_steps


def _c_pseudo_grad(cfg, eta_l, n_active):
    """Fold coefficient turning mean(Δ_i) into Δ_{t+1} (Algorithm 1/2
    line 13): ``m ← −mean/(η_l·K)``."""
    return -1.0 / (eta_l * cfg.local_steps)


def _c_alpha_pseudo_grad(cfg, eta_l, n_active):
    """EMA coupling of the adaptive methods: ``m ← (1−α)·m + α·Δ_{t+1}``."""
    return -cfg.alpha / (eta_l * cfg.local_steps)


def _c_eta_g(cfg, eta_l, n_active):
    return cfg.eta_g


def _c_participation_frac(cfg, eta_l, n_active):
    """SCAFFOLD server control variate: ``c ← c + (|S|/N)·mean(Δc_i)``."""
    return n_active / cfg.num_clients


def _c_feddyn_h(cfg, eta_l, n_active):
    """FedDyn: ``h ← h − α_dyn·(|S|/N)·mean(Δ_i)``."""
    return -cfg.feddyn_alpha * (n_active / cfg.num_clients)


def _pseudo_grad(mean_delta, eta_l, K):
    """Δ_{t+1} = −(1/(η_l·K))·mean_i(Δ_i) — Algorithm 1/2 line 13."""
    return tree_scale(mean_delta, -1.0 / (eta_l * K))


# --- per-client state updates (round close; see registry.state_update_fn)


def _scaffold_state_update(cfg, x0, xK, c_i, c, delta, eta_l):
    # option II: c_i⁺ = c_i − c + (x_t − x_{i,K}) / (K·η_l)
    K = cfg.local_steps
    c_new = jax.tree_util.tree_map(
        lambda ci, cg, d: ci - cg - d / (K * eta_l), c_i, c, delta
    )
    return tree_sub(c_new, c_i)


def _feddyn_state_update(cfg, x0, xK, lam_i, m, delta, eta_l):
    # λ_i ← λ_i − α_dyn·(θ_i − x_t)
    return tree_scale(delta, -cfg.feddyn_alpha)


# --- pure server post-steps (the part a streaming fold pass can't express)


def _feddyn_post(cfg, x, srv, dmean, n_active, eta_l):
    # fold already did  h ← h − α_dyn·(|S|/N)·mean  and  x ← x + mean
    # (the mean of client models); the dual shift is x ← x − h/α_dyn.
    return tree_axpy(-1.0 / cfg.feddyn_alpha, srv.momentum, x), srv


def _fedadam_post(cfg, x, srv, dmean, n_active, eta_l):
    # Reddi+20 server Adam: fold already did m ← (1−α)m + α·Δ_{t+1};
    # here the second moment EMA + preconditioned absolute-lr step.
    pg = _pseudo_grad(dmean, eta_l, cfg.local_steps)
    v = jax.tree_util.tree_map(
        lambda vi, gi: cfg.adam_beta2 * vi + (1.0 - cfg.adam_beta2) * jnp.square(gi),
        srv.second_moment, pg,
    )
    x = jax.tree_util.tree_map(
        lambda p, mi, vi: p - cfg.eta_g * mi / (jnp.sqrt(vi) + cfg.adam_tau),
        x, srv.momentum, v,
    )
    return x, srv._replace(second_moment=v)


def _fedadagrad_post(cfg, x, srv, dmean, n_active, eta_l):
    # Reddi+20 FedAdagrad: v accumulates (no decay) — v ← v + Δ²_{t+1}.
    pg = _pseudo_grad(dmean, eta_l, cfg.local_steps)
    v = jax.tree_util.tree_map(
        lambda vi, gi: vi + jnp.square(gi), srv.second_moment, pg
    )
    x = jax.tree_util.tree_map(
        lambda p, mi, vi: p - cfg.eta_g * mi / (jnp.sqrt(vi) + cfg.adam_tau),
        x, srv.momentum, v,
    )
    return x, srv._replace(second_moment=v)


def _fedyogi_post(cfg, x, srv, dmean, n_active, eta_l):
    # Reddi+20 FedYogi: sign-controlled second moment —
    # v ← v − (1−β2)·sign(v − Δ²)·Δ².
    pg = _pseudo_grad(dmean, eta_l, cfg.local_steps)
    v = jax.tree_util.tree_map(
        lambda vi, gi: vi - (1.0 - cfg.adam_beta2)
        * jnp.sign(vi - jnp.square(gi)) * jnp.square(gi),
        srv.second_moment, pg,
    )
    x = jax.tree_util.tree_map(
        lambda p, mi, vi: p - cfg.eta_g * mi / (jnp.sqrt(vi) + cfg.adam_tau),
        x, srv.momentum, v,
    )
    return x, srv._replace(second_moment=v)


def _fedavgm_post(cfg, x, srv, dmean, n_active, eta_l):
    # heavy-ball server step along the post-fold momentum:
    # x ← x − η_g·η_l·K·m'  (α=1 degenerates to FedAvg exactly).
    return tree_axpy(-_eta_g_eff(cfg, eta_l), srv.momentum, x), srv


def _fedacg_post(cfg, x, srv, dmean, n_active, eta_l):
    # Nesterov/FedACG-style lookahead: step along pg + λ·m' (the momentum
    # the NEXT round will broadcast), not the stale m.
    lam = cfg.acg_lambda
    pg = _pseudo_grad(dmean, eta_l, cfg.local_steps)
    step = jax.tree_util.tree_map(lambda mi, gi: gi + lam * mi, srv.momentum, pg)
    return tree_axpy(-_eta_g_eff(cfg, eta_l), step, x), srv


# ----------------------------------------------------------------------
# the builtin specs — pure data (see repro.core.registry)
# ----------------------------------------------------------------------

register_algorithm(AlgorithmSpec(
    name="fedavg",
    direction_row=DirectionRow(),  # v = g
    # m' := Δ_{t+1} (kept for metrics/inspection);  x' = x + η_g·mean
    fold=(FoldPass("delta", c_mm=0.0, c_md=_c_pseudo_grad, c_xd=_c_eta_g),),
))

register_algorithm(AlgorithmSpec(
    name="fedcm",
    # Algorithm 2, line 8: v = α·g + (1−α)·Δ_t
    direction_row=DirectionRow(
        c_g=lambda cfg: cfg.alpha,
        aux=(("momentum", lambda cfg: 1.0 - cfg.alpha),),
    ),
    # lines 13–14: Δ_{t+1} IS the new momentum (Lemma 4.1: it equals
    # α·Δ̃_t + (1−α)·Δ_t because clients descend along v, not g).
    fold=(FoldPass("delta", c_mm=0.0, c_md=_c_pseudo_grad, c_xd=_c_eta_g),),
    needs_momentum_broadcast=True,
    momentum_store="momentum_dtype",
))

register_algorithm(AlgorithmSpec(
    name="fedadam",
    direction_row=DirectionRow(),  # clients run plain SGD
    # m ← (1−α)·m + α·Δ_{t+1}; the v EMA + preconditioned step is the post
    fold=(FoldPass("delta", c_mm=lambda cfg, e, n: 1.0 - cfg.alpha,
                   c_md=_c_alpha_pseudo_grad, c_xd=0.0),),
    server_post_fn=_fedadam_post,
    needs_second_moment=True,
))

register_algorithm(AlgorithmSpec(
    name="scaffold",
    # option: v = g − c_i + c  (the server's c rides the momentum broadcast)
    direction_row=DirectionRow(
        aux=(("client_state", -1.0), ("momentum", 1.0)),
    ),
    state_update_fn=_scaffold_state_update,
    # params pass over Δ, then the c-EMA pass over Δc
    fold=(FoldPass("delta", c_mm=1.0, c_md=0.0, c_xd=_c_eta_g),
          FoldPass("state_delta", c_mm=1.0, c_md=_c_participation_frac, c_xd=0.0)),
    needs_client_state=True,
    needs_momentum_broadcast=True,
    client_state_uplink=True,  # Δc_i goes up; c comes down with the broadcast
))

register_algorithm(AlgorithmSpec(
    name="feddyn",
    # local objective f_i(x) − ⟨λ_i, x⟩ + (α_dyn/2)‖x − x_t‖²
    direction_row=DirectionRow(
        c_x=lambda cfg: cfg.feddyn_alpha,
        aux=(("client_state", -1.0),),
    ),
    state_update_fn=_feddyn_state_update,
    # h ← h − α_dyn·(|S|/N)·mean;  x ← (x + mean) − h/α_dyn (post)
    fold=(FoldPass("delta", c_mm=1.0, c_md=_c_feddyn_h, c_xd=1.0),),
    server_post_fn=_feddyn_post,
    needs_client_state=True,
    # λ_i never leaves the client — no uplink charge for the state plane
))

register_algorithm(AlgorithmSpec(
    name="mimelite",
    # MimeLite w/ momentum-SGD statistics: d = (1−β)·g + β·m, β = 1−α —
    # identical functional form to FedCM; the difference is how m is
    # UPDATED (full-batch grads at x_t: the ``extra`` fold pass below).
    direction_row=DirectionRow(
        c_g=lambda cfg: cfg.alpha,
        aux=(("momentum", lambda cfg: 1.0 - cfg.alpha),),
    ),
    fold=(FoldPass("delta", c_mm=1.0, c_md=0.0, c_xd=_c_eta_g),
          FoldPass("extra", c_mm=lambda cfg, e, n: 1.0 - cfg.alpha,
                   c_md=lambda cfg, e, n: cfg.alpha, c_xd=0.0)),
    needs_momentum_broadcast=True,
    needs_full_grad=True,
))

# --- the family beyond the paper: pure spec definitions -----------------

register_algorithm(AlgorithmSpec(
    name="fedavgm",
    direction_row=DirectionRow(),  # clients run plain SGD
    # Hsu+19 server heavy-ball on the pseudo-gradient, β = 1−α:
    # m' = (1−α)·m + Δ_{t+1};  x ← x − η_g·η_l·K·m'  (α=1 ⇒ FedAvg)
    fold=(FoldPass("delta", c_mm=lambda cfg, e, n: 1.0 - cfg.alpha,
                   c_md=_c_pseudo_grad, c_xd=0.0),),
    server_post_fn=_fedavgm_post,
))

register_algorithm(AlgorithmSpec(
    name="fedadagrad",
    direction_row=DirectionRow(),
    fold=(FoldPass("delta", c_mm=lambda cfg, e, n: 1.0 - cfg.alpha,
                   c_md=_c_alpha_pseudo_grad, c_xd=0.0),),
    server_post_fn=_fedadagrad_post,
    needs_second_moment=True,
))

register_algorithm(AlgorithmSpec(
    name="fedyogi",
    direction_row=DirectionRow(),
    fold=(FoldPass("delta", c_mm=lambda cfg, e, n: 1.0 - cfg.alpha,
                   c_md=_c_alpha_pseudo_grad, c_xd=0.0),),
    server_post_fn=_fedyogi_post,
    needs_second_moment=True,
))

register_algorithm(AlgorithmSpec(
    name="fedprox",
    # Li+20 (MLSys): local objective f_i(x) + (μ/2)‖x − x_t‖² — the
    # proximal gradient is the pure c_x row v = g + μ·(x − x_t).  No
    # client state, no extra uplink: stateless like FedAvg (and μ=0 IS
    # FedAvg), which is exactly why it stays data-only under every
    # execution path, cohort sharding included.
    direction_row=DirectionRow(c_x=lambda cfg: cfg.fedprox_mu),
    fold=(FoldPass("delta", c_mm=0.0, c_md=_c_pseudo_grad, c_xd=_c_eta_g),),
))

register_algorithm(AlgorithmSpec(
    name="fedacg",
    direction_row=DirectionRow(),
    # Kim+22-style accelerated server momentum:
    # m' = λ·m + Δ_{t+1};  x ← x − η_g·η_l·K·(Δ_{t+1} + λ·m')  (lookahead)
    fold=(FoldPass("delta", c_mm=lambda cfg, e, n: cfg.acg_lambda,
                   c_md=_c_pseudo_grad, c_xd=0.0),),
    server_post_fn=_fedacg_post,
))


# ----------------------------------------------------------------------
# flat-plane fast path
# ----------------------------------------------------------------------


class FlatClientOutputs(NamedTuple):
    """Per-client uplink on the flat plane.  Unused planes are ``None`` —
    the tree path materializes (and aggregates) zeros trees for them, which
    for a stateless algorithm is two full (C, P) writes + reductions of
    nothing; skipping them is part of the flat engine's win."""

    delta: Any  # (P,) x_{i,K} − x_t
    state_delta: Optional[Any]  # (P,) SCAFFOLD Δc_i / FedDyn Δλ_i, or None
    extra: Optional[Any]  # (P,) MimeLite full-batch grad, or None


def sparse_client_finalize(
    algo: AlgorithmSpec, cfg: FedConfig, x0, xK, cst, m, eta_l, full_grad
) -> FlatClientOutputs:
    """``algo.client_finalize`` minus the zeros trees it materializes:
    unused planes come back ``None``.  Array-polymorphic — the flat
    engine's kernel path feeds it bare ``(P,)`` buffers (single-leaf
    pytrees), the jnp path feeds leaf trees.  Op order deliberately
    mirrors the tree finalizer exactly (same ``state_update_fn``), so flat
    and tree trajectories agree bitwise, not just to tolerance."""
    delta = tree_sub(xK, x0)
    state_delta = None
    if algo.needs_client_state and algo.state_update_fn is not None:
        state_delta = algo.state_update_fn(cfg, x0, xK, cst, m, delta, eta_l)
    extra = full_grad if algo.needs_full_grad else None
    return FlatClientOutputs(delta, state_delta, extra)
