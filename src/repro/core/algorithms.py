"""Federated optimization algorithms (the paper's contribution + baselines).

Algorithm 2 of the paper (FedCM) and every baseline it compares against —
FedAvg [McMahan+17], FedAdam [Reddi+20], SCAFFOLD [Karimireddy+20b],
FedDyn [Acar+21] — plus MimeLite [Karimireddy+20a] from Appendix A, under
one interface consumed by the round engine (``repro.core.engine``).

Design: an algorithm is four pure pieces.

* ``server_init(params)``          -> ServerState (momentum Δ_t, adam moments, …)
* ``direction(bcast, cst, x, x0, g)`` -> the per-local-step update direction v
  (FedCM line 8: ``v = α·g + (1−α)·Δ_t``; SCAFFOLD: ``g − c_i + c``; …)
* ``client_finalize(...)``         -> per-client uplink extras + client-state delta
* ``server_update(...)``           -> new params + ServerState from the aggregate

The *paper-faithful* convention (appendix C.2) is used throughout: the
pseudo-gradient is ``Δ_{t+1} = −(1/(η_l·K)) · mean_i(x_{i,K} − x_t)`` and the
server applies ``x_{t+1} = x_t − (η_g·η_l·K)·Δ_{t+1}``, so ``η_g = 1``
corresponds to plain client-model averaging.  FedAdam applies its adaptive
update to the pseudo-gradient with an absolute server lr (η_g = 0.1 in the
paper).

Statelessness matters: FedCM/FedAvg/FedAdam/MimeLite keep NO per-client
state (``client_state_init`` is None); SCAFFOLD and FedDyn keep per-client
control variates, which is exactly what the paper blames for their
degradation at 2% participation — the engine stores them stacked (N, …) and
leaves non-participants stale, reproducing that failure mode honestly.

Flat fast path: every piece below is *array-polymorphic* — a bare jax
array is a single-leaf pytree, so ``direction``/``server_update`` run
unchanged on the flat ``(P,)`` parameter plane (``repro.core.flat``).  The
flat-only additions are ``FlatClientOutputs`` (optional planes: algorithms
that keep no client state / full-batch grad carry ``None`` instead of a
materialized ``(C, P)`` zeros plane) and ``sparse_client_finalize`` which
produces them with the same op order as the tree finalizers, so the two
paths stay bitwise-comparable (tests/test_flat.py holds them to it).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.utils.trees import (
    tree_add,
    tree_axpy,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)


class ServerState(NamedTuple):
    """Server-side state shared by all algorithms (unused leaves = zeros)."""

    momentum: Any  # FedCM Δ_t / FedAdam m / MimeLite m / FedDyn h
    second_moment: Any  # FedAdam v
    round: jax.Array  # int32 round counter t


class ClientOutputs(NamedTuple):
    delta: Any  # x_{i,K} − x_t  (the uplink payload of every algorithm)
    state_delta: Any  # per-client state update (SCAFFOLD Δc_i, FedDyn Δλ_i) or zeros
    extra: Any  # extra uplink pytree (MimeLite full-batch grad) or zeros


class Algorithm(NamedTuple):
    name: str
    needs_client_state: bool
    needs_momentum_broadcast: bool
    needs_full_grad: bool  # MimeLite: full-batch grad at x_t
    direction: Callable[..., Any]
    client_finalize: Callable[..., ClientOutputs]
    server_update: Callable[..., Any]


def server_init(params, momentum_dtype="float32") -> ServerState:
    mdt = jnp.dtype(momentum_dtype)
    z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, mdt), params)
    return ServerState(momentum=z, second_moment=tree_zeros_like(params), round=jnp.int32(0))


def client_state_init(params, cfg: FedConfig):
    """Stacked (N, …) per-client control variates for stateful baselines."""
    if cfg.algo not in ("scaffold", "feddyn"):
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((cfg.num_clients, *p.shape), p.dtype), params
    )


# ----------------------------------------------------------------------
# per-algorithm pieces
# ----------------------------------------------------------------------
# All ``direction`` functions share the signature
#   direction(cfg, bcast_momentum, client_state, x, x0, g) -> v
# where x is the current local iterate, x0 = x_t the round anchor, g the
# (weight-decayed) minibatch gradient.


def _dir_fedavg(cfg, m, cst, x, x0, g):
    return g


def _dir_fedcm(cfg, m, cst, x, x0, g):
    # Algorithm 2, line 8: v = α·g + (1−α)·Δ_t
    return jax.tree_util.tree_map(
        lambda gi, mi: cfg.alpha * gi + (1.0 - cfg.alpha) * mi, g, m
    )


def _dir_mimelite(cfg, m, cst, x, x0, g):
    # MimeLite w/ momentum-SGD statistics: d = (1−β)·g + β·m, β = 1−α.
    # Identical functional form to FedCM — the difference is how m is
    # *updated* (full-batch grads at x_t; see server_update + engine).
    return jax.tree_util.tree_map(
        lambda gi, mi: cfg.alpha * gi + (1.0 - cfg.alpha) * mi, g, m
    )


def _dir_scaffold(cfg, m, cst, x, x0, g):
    # SCAFFOLD option: v = g − c_i + c;  cst = (c_i, c broadcast via m slot is
    # NOT used — c rides in bcast).  Here cst is a tuple (c_i, c).
    c_i, c = cst
    return jax.tree_util.tree_map(lambda gi, ci, cg: gi - ci + cg, g, c_i, c)


def _dir_feddyn(cfg, m, cst, x, x0, g):
    # FedDyn local objective: f_i(x) − ⟨λ_i, x⟩ + (α_dyn/2)‖x − x_t‖²
    lam_i = cst
    a = cfg.feddyn_alpha
    return jax.tree_util.tree_map(
        lambda gi, li, xi, x0i: gi - li + a * (xi - x0i), g, lam_i, x, x0
    )


# --- client_finalize(cfg, x0, xK, client_state, eta_l, full_grad) -> ClientOutputs


def _fin_plain(cfg, x0, xK, cst, eta_l, full_grad):
    delta = tree_sub(xK, x0)
    return ClientOutputs(delta, tree_zeros_like(x0), tree_zeros_like(x0))


def _fin_mimelite(cfg, x0, xK, cst, eta_l, full_grad):
    delta = tree_sub(xK, x0)
    return ClientOutputs(delta, tree_zeros_like(x0), full_grad)


def _fin_scaffold(cfg, x0, xK, cst, eta_l, full_grad):
    c_i, c = cst
    delta = tree_sub(xK, x0)
    K = cfg.local_steps
    # option II: c_i⁺ = c_i − c + (x_t − x_{i,K}) / (K·η_l)
    c_new = jax.tree_util.tree_map(
        lambda ci, cg, d: ci - cg - d / (K * eta_l), c_i, c, delta
    )
    return ClientOutputs(delta, tree_sub(c_new, c_i), tree_zeros_like(x0))


def _fin_feddyn(cfg, x0, xK, cst, eta_l, full_grad):
    delta = tree_sub(xK, x0)
    # λ_i ← λ_i − α_dyn·(θ_i − x_t)
    state_delta = tree_scale(delta, -cfg.feddyn_alpha)
    return ClientOutputs(delta, state_delta, tree_zeros_like(x0))


# --- server_update(cfg, params, st, mean_delta, mean_state_delta, mean_extra,
#                   n_active, eta_l) -> (params, ServerState)


def _eta_g_eff(cfg: FedConfig, eta_l) -> jax.Array:
    # appendix C.2: η_g is reported in "averaging" units; effective server
    # step on Δ_{t+1} is η_g·η_l·K, i.e. x ← x + η_g·mean(Δ_i).
    return cfg.eta_g * eta_l * cfg.local_steps


def _pseudo_grad(mean_delta, eta_l, K):
    """Δ_{t+1} = −(1/(η_l·K))·mean_i(Δ_i) — Algorithm 1/2 line 13."""
    return tree_scale(mean_delta, -1.0 / (eta_l * K))


def _srv_fedavg(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
    pg = _pseudo_grad(mean_delta, eta_l, cfg.local_steps)
    new_params = tree_axpy(-_eta_g_eff(cfg, eta_l), pg, params)
    return new_params, st._replace(momentum=pg, round=st.round + 1)


def _srv_fedcm(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
    # Algorithm 2 lines 13–14: Δ_{t+1} IS the new momentum (Lemma 4.1 shows it
    # equals α·Δ̃_t + (1−α)·Δ_t because clients descend along v, not g).
    pg = _pseudo_grad(mean_delta, eta_l, cfg.local_steps)
    new_params = tree_axpy(-_eta_g_eff(cfg, eta_l), pg, params)
    mdt = jnp.dtype(getattr(cfg, "momentum_dtype", "float32"))
    m_store = jax.tree_util.tree_map(lambda x: x.astype(mdt), pg)
    return new_params, st._replace(momentum=m_store, round=st.round + 1)


def _srv_fedadam(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
    # Reddi+20 server Adam on the pseudo-gradient; β1 = 1−α, τ = adam_tau.
    pg = _pseudo_grad(mean_delta, eta_l, cfg.local_steps)
    m = jax.tree_util.tree_map(
        lambda mi, gi: (1.0 - cfg.alpha) * mi + cfg.alpha * gi, st.momentum, pg
    )
    v = jax.tree_util.tree_map(
        lambda vi, gi: cfg.adam_beta2 * vi + (1.0 - cfg.adam_beta2) * jnp.square(gi),
        st.second_moment,
        pg,
    )
    new_params = jax.tree_util.tree_map(
        lambda p, mi, vi: p - cfg.eta_g * mi / (jnp.sqrt(vi) + cfg.adam_tau),
        params,
        m,
        v,
    )
    return new_params, ServerState(momentum=m, second_moment=v, round=st.round + 1)


def _srv_scaffold(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
    new_params = tree_axpy(cfg.eta_g, mean_delta, params)  # x + η_g·mean(Δ_i)
    # c ← c + (|S|/N)·mean(Δc_i); the server's c rides in st.momentum.
    frac = n_active.astype(jnp.float32) / cfg.num_clients
    c = tree_axpy(frac, mean_sd, st.momentum)
    return new_params, st._replace(momentum=c, round=st.round + 1)


def _srv_feddyn(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
    # h ← h − α_dyn·(|S|/N)·mean(Δ_i);  x ← (mean of client models) − h/α_dyn
    a = cfg.feddyn_alpha
    frac = n_active.astype(jnp.float32) / cfg.num_clients
    h = tree_axpy(-a * frac, mean_delta, st.momentum)
    mean_model = tree_add(params, mean_delta)
    new_params = tree_axpy(-1.0 / a, h, mean_model)
    return new_params, st._replace(momentum=h, round=st.round + 1)


def _srv_mimelite(cfg, params, st, mean_delta, mean_sd, mean_extra, n_active, eta_l):
    # x ← x + η_g·mean(Δ_i);  m ← (1−α)·m + α·mean_i ∇f_i(x_t) (FULL batch —
    # Appendix A: this is the FedCM-vs-MimeLite distinction).
    new_params = tree_axpy(cfg.eta_g, mean_delta, params)
    m = jax.tree_util.tree_map(
        lambda mi, gi: (1.0 - cfg.alpha) * mi + cfg.alpha * gi, st.momentum, mean_extra
    )
    return new_params, st._replace(momentum=m, round=st.round + 1)


# ----------------------------------------------------------------------
# flat-plane fast path
# ----------------------------------------------------------------------


class FlatClientOutputs(NamedTuple):
    """Per-client uplink on the flat plane.  Unused planes are ``None`` —
    the tree path materializes (and aggregates) zeros trees for them, which
    for a stateless algorithm is two full (C, P) writes + reductions of
    nothing; skipping them is part of the flat engine's win."""

    delta: Any  # (P,) x_{i,K} − x_t
    state_delta: Optional[Any]  # (P,) SCAFFOLD Δc_i / FedDyn Δλ_i, or None
    extra: Optional[Any]  # (P,) MimeLite full-batch grad, or None


def sparse_client_finalize(
    algo: Algorithm, cfg: FedConfig, x0, xK, cst, eta_l, full_grad
) -> FlatClientOutputs:
    """``algo.client_finalize`` minus the zeros trees it materializes:
    unused planes come back ``None``.  Array-polymorphic — the flat
    engine's kernel path feeds it bare ``(P,)`` buffers (single-leaf
    pytrees), the jnp path feeds it leaf trees.  Op order deliberately
    mirrors the tree finalizers exactly (e.g. SCAFFOLD computes ``c_new``
    then subtracts ``c_i`` instead of the algebraically-equal
    ``−c − Δ/(K·η_l)``) so flat and tree trajectories agree bitwise, not
    just to tolerance."""
    delta = tree_sub(xK, x0)
    state_delta = None
    if algo.name == "scaffold":
        c_i, c = cst
        K = cfg.local_steps
        c_new = jax.tree_util.tree_map(
            lambda ci, cg, d: ci - cg - d / (K * eta_l), c_i, c, delta
        )
        state_delta = tree_sub(c_new, c_i)
    elif algo.name == "feddyn":
        state_delta = tree_scale(delta, -cfg.feddyn_alpha)
    extra = full_grad if algo.needs_full_grad else None
    return FlatClientOutputs(delta, state_delta, extra)


ALGORITHMS: Dict[str, Algorithm] = {
    "fedavg": Algorithm(
        "fedavg", False, False, False, _dir_fedavg, _fin_plain, _srv_fedavg
    ),
    "fedcm": Algorithm(
        "fedcm", False, True, False, _dir_fedcm, _fin_plain, _srv_fedcm
    ),
    "fedadam": Algorithm(
        "fedadam", False, False, False, _dir_fedavg, _fin_plain, _srv_fedadam
    ),
    "scaffold": Algorithm(
        "scaffold", True, True, False, _dir_scaffold, _fin_scaffold, _srv_scaffold
    ),
    "feddyn": Algorithm(
        "feddyn", True, False, False, _dir_feddyn, _fin_feddyn, _srv_feddyn
    ),
    "mimelite": Algorithm(
        "mimelite", False, True, True, _dir_mimelite, _fin_mimelite, _srv_mimelite
    ),
}


def get_algorithm(name: str) -> Algorithm:
    if name not in ALGORITHMS:
        raise KeyError(f"unknown federated algorithm {name!r}; known: {sorted(ALGORITHMS)}")
    return ALGORITHMS[name]
