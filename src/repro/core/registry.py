"""Declarative algorithm registry: one ``AlgorithmSpec`` drives every path.

FedCM is one point in a family of momentum-corrected local-update methods —
FedACG's accelerated server momentum (Kim et al., arXiv:2201.03172) and
generalized heavy-ball methods (Zaccone et al., arXiv:2311.18578) are the
same affine-blend shape with different coefficients.  This module makes
that family structure the API: an algorithm is DATA — three declarative
pieces the engine, the flat plane, the fused kernels, and the async ring
all consume without ever branching on an algorithm name.

An ``AlgorithmSpec`` declares:

(a) **client direction** — an affine coefficient row (``DirectionRow``)
    consumed directly by the ``fed_direction`` kernel::

        v = c_g·g + c_x·(x − x_t) + Σ_s c_s·stream_s

    where the named streams are ``"momentum"`` (the broadcast buffer Δ_t /
    c) and ``"client_state"`` (this client's c_i / λ_i).  Coefficients are
    floats or ``cfg -> float`` callables, resolved at trace time — static
    zeros are dropped, so unused streams cost nothing on either path.  An
    escape-hatch ``direction_fn(cfg, m, cst, x, x0, g) -> v`` replaces the
    row for non-affine directions (array-polymorphic: it runs on leaf
    trees AND flat ``(P,)`` buffers).

(b) **server fold** — a tuple of ``FoldPass`` coefficient rows, each one a
    ``server_update``-kernel SMEM row over one uplink plane::

        mean = Σ_c wn_c · plane_c        (masked cohort mean)
        m'   = c_mm·m + c_md·(γ·mean)    (momentum EMA / pseudo-grad store)
        x'   = x + c_xd·(γ·mean)         (server param step)

    (γ is the async staleness discount; 1.0 on the sync path) plus an
    optional pure ``server_post_fn(cfg, x, server, dmean, n_active,
    eta_l) -> (x, server)`` for the part a streaming pass cannot express
    (FedAdam's preconditioner, FedDyn's ``−h/α`` shift, FedACG's Nesterov
    lookahead).  Coefficients are floats or ``(cfg, eta_l, n_active) ->
    scalar`` callables — η_l decays per round and |S| is traced, so they
    resolve inside the jitted program.  A full escape hatch ``server_fn``
    (legacy ``server_update`` signature) replaces fold + post entirely;
    such algorithms run the jnp reduction path even under
    ``use_fused_kernel``.

(c) **state planes** — ``needs_client_state`` / ``needs_momentum_broadcast``
    / ``needs_full_grad`` / ``needs_second_moment`` flags from which
    ``FedState`` allocation (stacked ``(N, …)`` control variates, the
    second-moment plane, the f32 master cache) and uplink payload shapes
    are derived; ``client_state_uplink`` marks whether the per-client
    state delta rides the wire (SCAFFOLD's Δc_i does, FedDyn's λ_i never
    leaves the client).

Registering a new algorithm is therefore a pure data definition::

    @register_algorithm
    def _fedavgm():
        return AlgorithmSpec(
            name="fedavgm",
            direction_row=DirectionRow(),            # plain local SGD
            fold=(FoldPass("delta",
                           c_mm=lambda cfg: 1.0 - cfg.alpha,
                           c_md=_c_pseudo_grad, c_xd=0.0),),
            server_post_fn=_post_momentum_step,
        )

and it immediately runs on the tree path, the flat plane, the fused
Pallas kernels, and the async pipelined ring — plus the registry-
parametrized cross-path equivalence tests (tests/test_registry.py) pick
it up automatically.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.utils.trees import tree_sub, tree_zeros_like

# coefficient forms: a static python float, or a callable resolved at
# trace time — cfg-only for direction rows, (cfg, eta_l, n_active) for
# fold rows (η_l decays per round, |S| is traced under bernoulli
# participation)
DirCoef = Union[float, Callable[[Any], float]]
FoldCoef = Union[float, Callable[[Any, Any, Any], Any]]

#: stream names a DirectionRow may reference
DIRECTION_STREAMS = ("momentum", "client_state")
#: uplink plane names a FoldPass may reference
FOLD_PLANES = ("delta", "state_delta", "extra")


class DirectionRow(NamedTuple):
    """Affine client-direction coefficients (see module docstring (a))."""

    c_g: DirCoef = 1.0  # on the minibatch gradient g
    c_x: DirCoef = 0.0  # on the proximal drift (x − x_t)
    aux: Tuple[Tuple[str, DirCoef], ...] = ()  # (stream name, coefficient)


class FoldPass(NamedTuple):
    """One ``server_update`` SMEM coefficient row over one uplink plane.

    Statically-zero coefficients are structural: ``c_xd == 0.0`` means the
    pass leaves params untouched, ``c_md == 0.0 and c_mm == 1.0`` means it
    leaves the momentum buffer untouched — both paths (jnp interpreter and
    fused kernel) skip the corresponding write, so e.g. SCAFFOLD's params
    pass never re-rounds the momentum plane."""

    plane: str  # "delta" | "state_delta" | "extra"
    c_mm: FoldCoef = 1.0  # momentum carry-over
    c_md: FoldCoef = 0.0  # momentum ← mean coupling
    c_xd: FoldCoef = 0.0  # param step on the mean


class ServerState(NamedTuple):
    """Server-side state shared by all algorithms.

    ``second_moment`` is ``None`` unless the spec sets
    ``needs_second_moment`` — stateless-in-v algorithms never allocate
    (or checkpoint, or donate) the extra params-sized plane."""

    momentum: Any  # FedCM Δ_t / FedAdam m / MimeLite m / FedDyn h / SCAFFOLD c
    second_moment: Any  # FedAdam/FedAdagrad/FedYogi v, or None
    round: jax.Array  # int32 round counter t


class ClientOutputs(NamedTuple):
    delta: Any  # x_{i,K} − x_t  (the uplink payload of every algorithm)
    state_delta: Any  # per-client state update (SCAFFOLD Δc_i, FedDyn Δλ_i) or zeros
    extra: Any  # extra uplink pytree (MimeLite full-batch grad) or zeros


def _dir_coef(c: DirCoef, cfg) -> float:
    return float(c(cfg)) if callable(c) else float(c)


def _fold_coef(c: FoldCoef, cfg, eta_l, n_active):
    return c(cfg, eta_l, n_active) if callable(c) else c


def _is_static_zero(c) -> bool:
    return isinstance(c, (int, float)) and float(c) == 0.0  # repro: noqa REP003 -- isinstance-guarded Python scalar, static at trace time


def _is_static_one(c) -> bool:
    return isinstance(c, (int, float)) and float(c) == 1.0  # repro: noqa REP003 -- isinstance-guarded Python scalar, static at trace time


class AlgorithmSpec(NamedTuple):
    """One federated algorithm as data (see module docstring).

    The methods (``direction`` / ``client_finalize`` / ``server_update``)
    are the generic interpreters of the declarative fields — they are
    array-polymorphic (a bare ``(P,)`` buffer is a single-leaf pytree), so
    the tree path and the flat plane share them verbatim.  The fused
    kernel path consumes the SAME rows through
    ``kernels/fed_direction/ops.flat_direction_step`` and
    ``kernels/server_update/ops.fused_fold``.
    """

    name: str
    # --- (a) client direction ---
    direction_row: Optional[DirectionRow] = DirectionRow()
    direction_fn: Optional[Callable] = None  # (cfg, m, cst, x, x0, g) -> v
    # round-close per-client state update, or None (stateless):
    #   (cfg, x0, xK, cst, m, delta, eta_l) -> state_delta
    state_update_fn: Optional[Callable] = None
    # --- (b) server fold ---
    fold: Tuple[FoldPass, ...] = (FoldPass("delta"),)
    # (cfg, x, server, dmean, n_active, eta_l) -> (x, server)
    server_post_fn: Optional[Callable] = None
    # full escape hatch, legacy signature (cfg, params, st, mean_delta,
    # mean_sd, mean_extra, n_active, eta_l) -> (params, ServerState)
    server_fn: Optional[Callable] = None
    # --- (c) state-plane requirements ---
    needs_client_state: bool = False
    needs_momentum_broadcast: bool = False
    needs_full_grad: bool = False
    needs_second_moment: bool = False
    client_state_uplink: bool = False  # does Δstate ride the uplink (payload)
    # stored-momentum dtype policy: "float32", or "momentum_dtype" to honor
    # cfg.momentum_dtype (FedCM's broadcastable Δ_t)
    momentum_store: str = "float32"
    # --- (d) uplink compression (repro.core.compress) ---
    # spec-declared default uplink compression kind ("int8"/"bf16"/"topk",
    # None = uncompressed).  cfg.compression overrides it; the engine
    # resolves ``effective = cfg.compression or spec default``.
    uplink_compression: Optional[str] = None
    # top-k sparsification carries error-feedback residuals as a NEW
    # per-client state stream (resident (N, P) plane / host-store rows,
    # checkpointed with the run).  A spec that declares lossy
    # sparsification must also declare the residual stream — validation
    # refuses "topk" without it (sparsifying with no residual silently
    # biases every uplink; see core/compress.py).
    needs_residual: bool = False

    # ------------------------------------------------------------------
    # derived uplink / ring layout (cohort-parallel engine consumes these)
    # ------------------------------------------------------------------
    @property
    def uplink_planes(self) -> Tuple[str, ...]:
        """Names of the cohort-stacked uplink planes this spec produces —
        the ``(C, P)`` buffers that ride the async ring (``CohortUplink``)
        and, under cohort sharding, the planes whose leading axis is
        partitioned over the ``"clients"`` mesh axis.  Derived purely from
        the state-plane flags: ``delta`` always, ``state_delta`` iff the
        spec keeps per-client state, ``extra`` iff it uplinks a full-batch
        gradient.  Ring and shard_map in/out specs are built from this —
        never from algorithm names."""
        names = ["delta"]
        if self.needs_client_state and self.state_update_fn is not None:
            names.append("state_delta")
        if self.needs_full_grad:
            names.append("extra")
        return tuple(names)

    @property
    def wire_uplink_planes(self) -> Tuple[str, ...]:
        """Uplink planes that actually cross the client→server WIRE —
        §4.2's payload accounting as data.  ``uplink_planes`` minus the
        state planes that stay client-local: feddyn's λ_i rides the ring
        as a ``state_delta`` plane but never leaves the client in the real
        system (``client_state_uplink=False``), so it costs no uplink
        bytes.  The engine's payload metrics and ``fed_train --list-algos``
        both derive bytes/round from this."""
        names = ["delta"]
        if self.needs_client_state and self.client_state_uplink:
            names.append("state_delta")
        if self.needs_full_grad:
            names.append("extra")
        return tuple(names)

    @property
    def fold_planes(self) -> Tuple[str, ...]:
        """Uplink planes the ROUND CLOSE consumes (in first-use order).
        For declarative folds these are the planes named by the
        ``FoldPass`` rows — the set the scattered (reduce-scatter) fold
        must transpose; a ``server_fn`` escape hatch consumes the masked
        mean of every uplink plane."""
        if self.server_fn is not None:
            return self.uplink_planes
        return tuple(dict.fromkeys(p.plane for p in self.fold))

    # ------------------------------------------------------------------
    # generic interpreters (array-polymorphic: trees OR flat planes)
    # ------------------------------------------------------------------
    def direction(self, cfg, m, cst, x, x0, g):
        """Per-local-step direction v from the affine row (or escape hatch)."""
        if self.direction_fn is not None:
            return self.direction_fn(cfg, m, cst, x, x0, g)
        row = self.direction_row
        c_g = _dir_coef(row.c_g, cfg)
        c_x = _dir_coef(row.c_x, cfg)
        streams = {"momentum": m, "client_state": cst}
        aux = [(streams[s], _dir_coef(c, cfg)) for s, c in row.aux]
        aux = [(t, c) for t, c in aux if c != 0.0]  # static-zero streams drop
        trees = [g] + ([x, x0] if c_x != 0.0 else []) + [t for t, _ in aux]
        coefs = [c for _, c in aux]

        def leaf(g_l, *rest):
            v = c_g * g_l
            if c_x != 0.0:
                v = v + c_x * (rest[0] - rest[1])
                rest = rest[2:]
            for c_s, s_l in zip(coefs, rest):
                v = v + c_s * s_l
            return v

        return jax.tree_util.tree_map(leaf, *trees)

    def client_finalize(self, cfg, x0, xK, cst, m, eta_l, full_grad) -> ClientOutputs:
        """Round-close uplink on the TREE path: unused planes materialize
        as zeros (the tree path aggregates them — part of why the flat
        path wins; see ``sparse_client_finalize`` in core.algorithms)."""
        delta = tree_sub(xK, x0)
        if self.state_update_fn is not None:
            sd = self.state_update_fn(cfg, x0, xK, cst, m, delta, eta_l)
        else:
            sd = tree_zeros_like(x0)
        extra = full_grad if self.needs_full_grad else tree_zeros_like(x0)
        return ClientOutputs(delta, sd, extra)

    def server_update(self, cfg, params, st, mean_delta, mean_sd, mean_extra,
                      n_active, eta_l, discount=1.0):
        """Round-close from the aggregated means: interpret the fold rows
        (plus post-step), or defer to the ``server_fn`` escape hatch.
        ``discount`` is the async staleness weight γ (static 1.0 on the
        sync path — skipped, so sync stays bitwise)."""
        if self.server_fn is not None:
            if not _is_static_one(discount):
                scale = lambda t: None if t is None else jax.tree_util.tree_map(
                    lambda a: discount * a, t)
                mean_delta, mean_sd, mean_extra = (
                    scale(mean_delta), scale(mean_sd), scale(mean_extra))
            return self.server_fn(cfg, params, st, mean_delta, mean_sd,
                                  mean_extra, n_active, eta_l)
        planes = {"delta": mean_delta, "state_delta": mean_sd, "extra": mean_extra}
        x, m = params, st.momentum
        dmean_delta = None
        for p in self.fold:
            mean = planes[p.plane]
            dmean = mean if _is_static_one(discount) else jax.tree_util.tree_map(
                lambda a: discount * a, mean)
            if p.plane == "delta":
                dmean_delta = dmean
            c_mm = _fold_coef(p.c_mm, cfg, eta_l, n_active)
            c_md = _fold_coef(p.c_md, cfg, eta_l, n_active)
            c_xd = _fold_coef(p.c_xd, cfg, eta_l, n_active)
            if not (_is_static_zero(p.c_md) and _is_static_one(p.c_mm)):
                if _is_static_zero(p.c_mm):
                    m = jax.tree_util.tree_map(lambda d: c_md * d, dmean)
                else:
                    m = jax.tree_util.tree_map(
                        lambda mi, d: c_mm * mi + c_md * d, m, dmean)
            if not _is_static_zero(p.c_xd):
                x = jax.tree_util.tree_map(lambda xi, d: xi + c_xd * d, x, dmean)
        if self.momentum_store == "momentum_dtype":
            mdt = jnp.dtype(getattr(cfg, "momentum_dtype", "float32"))
            m = jax.tree_util.tree_map(lambda a: a.astype(mdt), m)
        new_st = st._replace(momentum=m, round=st.round + 1)
        if self.server_post_fn is not None:
            x, new_st = self.server_post_fn(cfg, x, new_st, dmean_delta,
                                            n_active, eta_l)
        return x, new_st


#: back-compat alias — PR-2/3 code and tests name the spec ``Algorithm``
Algorithm = AlgorithmSpec


# ----------------------------------------------------------------------
# the registry
# ----------------------------------------------------------------------

_REGISTRY: Dict[str, AlgorithmSpec] = {}


def _validate(spec: AlgorithmSpec) -> None:
    if not spec.name or not isinstance(spec.name, str):
        raise ValueError(f"AlgorithmSpec needs a non-empty string name, got {spec.name!r}")
    if spec.momentum_store not in ("float32", "momentum_dtype"):
        raise ValueError(
            f"{spec.name}: momentum_store must be 'float32' or 'momentum_dtype'"
        )
    if (spec.direction_row is None) == (spec.direction_fn is None):
        raise ValueError(
            f"{spec.name}: exactly one of direction_row / direction_fn required"
        )
    if spec.direction_row is not None:
        for stream, _ in spec.direction_row.aux:
            if stream not in DIRECTION_STREAMS:
                raise ValueError(
                    f"{spec.name}: unknown direction stream {stream!r}; "
                    f"known: {DIRECTION_STREAMS}"
                )
            if stream == "client_state" and not spec.needs_client_state:
                raise ValueError(
                    f"{spec.name}: direction consumes 'client_state' but "
                    f"needs_client_state is False — no plane would be allocated"
                )
            if stream == "momentum" and not spec.needs_momentum_broadcast:
                raise ValueError(
                    f"{spec.name}: direction consumes 'momentum' but "
                    f"needs_momentum_broadcast is False — payload accounting "
                    f"would undercharge the downlink"
                )
    if spec.needs_client_state and spec.state_update_fn is None:
        raise ValueError(
            f"{spec.name}: needs_client_state requires state_update_fn "
            f"(how does the per-client plane evolve?)"
        )
    if spec.client_state_uplink and not spec.needs_client_state:
        raise ValueError(f"{spec.name}: client_state_uplink without client state")
    if spec.uplink_compression not in (None, "int8", "bf16", "topk"):
        raise ValueError(
            f"{spec.name}: unknown uplink_compression "
            f"{spec.uplink_compression!r}; known: int8 | bf16 | topk"
        )
    if spec.uplink_compression == "topk" and not spec.needs_residual:
        raise ValueError(
            f"{spec.name}: uplink_compression='topk' without needs_residual "
            f"— lossy sparsification needs the error-feedback residual "
            f"stream or every uplink is silently biased (repro.core.compress)"
        )
    if spec.needs_residual and spec.uplink_compression != "topk":
        raise ValueError(
            f"{spec.name}: needs_residual declared but uplink_compression is "
            f"{spec.uplink_compression!r} — only 'topk' carries residuals"
        )
    if spec.server_fn is not None:
        if spec.server_post_fn is not None:
            raise ValueError(f"{spec.name}: server_fn replaces fold+post — drop server_post_fn")
    else:
        if not spec.fold:
            raise ValueError(f"{spec.name}: empty fold and no server_fn escape hatch")
        for p in spec.fold:
            if p.plane not in FOLD_PLANES:
                raise ValueError(
                    f"{spec.name}: unknown fold plane {p.plane!r}; known: {FOLD_PLANES}"
                )
            if p.plane == "state_delta" and not spec.needs_client_state:
                raise ValueError(f"{spec.name}: fold over state_delta without client state")
            if p.plane == "extra" and not spec.needs_full_grad:
                raise ValueError(f"{spec.name}: fold over extra without needs_full_grad")
        if not any(p.plane == "delta" for p in spec.fold):
            raise ValueError(
                f"{spec.name}: fold needs a pass over 'delta' (metrics and "
                f"post-steps consume the cohort mean)"
            )
        def identity(p):
            return (_is_static_zero(p.c_xd) and _is_static_zero(p.c_md)
                    and _is_static_one(p.c_mm))
        if spec.server_post_fn is None and all(identity(p) for p in spec.fold):
            raise ValueError(
                f"{spec.name}: every fold pass is the identity "
                f"(c_mm=1, c_md=0, c_xd=0) and there is no server_post_fn — "
                f"the server would never move; give a pass real "
                f"coefficients, or add server_post_fn / server_fn"
            )


def register_algorithm(spec_or_builder=None, *, override: bool = False):
    """Register an ``AlgorithmSpec``.  Three forms::

        register_algorithm(spec)                  # direct
        @register_algorithm                       # decorator on a zero-arg
        def _myalgo(): return AlgorithmSpec(...)  #   builder function
        register_algorithm(spec, override=True)   # replace an existing name

    Validates the spec (stream/plane names, state-flag consistency) and
    returns it.  Duplicate names raise unless ``override=True``.
    """
    if spec_or_builder is None:  # @register_algorithm(override=True)
        return lambda sb: register_algorithm(sb, override=override)
    spec = spec_or_builder() if callable(spec_or_builder) else spec_or_builder
    if not isinstance(spec, AlgorithmSpec):
        raise TypeError(f"expected AlgorithmSpec, got {type(spec).__name__}")
    _validate(spec)
    if spec.name in _REGISTRY and not override:
        raise ValueError(
            f"algorithm {spec.name!r} already registered "
            f"(pass override=True to replace)"
        )
    _REGISTRY[spec.name] = spec
    return spec


def unregister_algorithm(name: str) -> None:
    """Remove a registered algorithm (tests / interactive use)."""
    _REGISTRY.pop(name, None)


def get_algorithm(name: str) -> AlgorithmSpec:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown federated algorithm {name!r}; registered: "
            f"{sorted(_REGISTRY)} (see repro.core.registry.register_algorithm)"
        )
    return _REGISTRY[name]


def list_algorithms() -> Tuple[str, ...]:
    """Registered algorithm names, sorted."""
    return tuple(sorted(_REGISTRY))


# ----------------------------------------------------------------------
# state-plane allocation (derived from spec flags)
# ----------------------------------------------------------------------


def server_init(params, momentum_dtype="float32",
                needs_second_moment: bool = True) -> ServerState:
    """Allocate the server planes a spec requires.  The momentum plane is
    universal (it doubles as SCAFFOLD's c and FedDyn's h); the second
    moment only exists for ``needs_second_moment`` specs."""
    mdt = jnp.dtype(momentum_dtype)
    z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, mdt), params)
    sm = tree_zeros_like(params) if needs_second_moment else None
    return ServerState(momentum=z, second_moment=sm, round=jnp.int32(0))


def client_state_init(params, cfg):
    """Stacked ``(N, …)`` per-client control variates — allocated iff the
    registered spec sets ``needs_client_state`` (new stateful algorithms
    get their planes automatically; nothing is keyed on names).

    Under an out-of-core population store (``cfg.population_store`` other
    than "resident") the per-client planes live in host memory
    (``repro.data.population``) — no ``(N, …)`` device array exists, so
    this returns None and the engine attaches the store at ``init()``."""
    if not get_algorithm(cfg.algo).needs_client_state:
        return None
    if getattr(cfg, "population_store", "resident") != "resident":
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((cfg.num_clients, *p.shape), p.dtype), params
    )


# ----------------------------------------------------------------------
# routing description (kernels/README.md table + fed_train --list-algos)
# ----------------------------------------------------------------------


def describe_algorithm(spec: AlgorithmSpec) -> Dict[str, str]:
    """Human-readable routing summary of one spec (pure function of the
    registry — the README table and ``--list-algos`` both render it)."""
    if spec.direction_fn is not None:
        direction = "custom jnp (`direction_fn`)"
    else:
        row = spec.direction_row
        terms = ["g"]
        if not _is_static_zero(row.c_x):
            terms.append("(x−x₀)")
        terms += [s for s, _ in row.aux]
        direction = f"`fed_direction` affine: {' + '.join(terms)}"
    if spec.server_fn is not None:
        server = "custom jnp (`server_fn`)"
    else:
        server = f"`server_update` ×{len(spec.fold)}"
        if spec.server_post_fn is not None:
            server += " + post"
    planes = [
        flag for flag, on in (
            ("client_state", spec.needs_client_state),
            ("momentum_bcast", spec.needs_momentum_broadcast),
            ("full_grad", spec.needs_full_grad),
            ("second_moment", spec.needs_second_moment),
        ) if on
    ] or ["—"]
    wire = spec.wire_uplink_planes
    comp = spec.uplink_compression or "f32"
    if spec.needs_residual:
        comp += " + residual"
    return {
        "algorithm": spec.name,
        "local step": direction,
        "server fold": server,
        "state planes": ", ".join(planes),
        # §4.2 payload accounting: planes that cross the client→server wire
        "uplink": f"{len(wire)}×P ({'+'.join(wire)})",
        # spec-declared default wire format (cfg.compression overrides)
        "wire": comp,
    }


def routing_table_md() -> str:
    """The per-algorithm routing table as markdown, generated FROM the
    registry (tests/test_registry.py asserts kernels/README.md embeds this
    verbatim — regenerate with ``python -m repro.core.registry --write``)."""
    rows = [describe_algorithm(get_algorithm(n)) for n in list_algorithms()]
    cols = ["algorithm", "local step", "server fold", "state planes",
            "uplink", "wire"]
    widths = {c: max(len(c), *(len(r[c]) for r in rows)) for c in cols}
    fmt = lambda r: "| " + " | ".join(r[c].ljust(widths[c]) for c in cols) + " |"
    head = fmt({c: c for c in cols})
    sep = "|" + "|".join("-" * (widths[c] + 2) for c in cols) + "|"
    return "\n".join([head, sep] + [fmt(r) for r in rows])


README_BEGIN = "<!-- registry-routing:begin (generated by repro.core.registry) -->"
README_END = "<!-- registry-routing:end -->"


def sync_readme(write: bool = False) -> bool:
    """True if kernels/README.md embeds the current routing table; with
    ``write=True`` regenerate the block between the markers in place."""
    from pathlib import Path

    import repro.core.algorithms  # noqa: F401  (builtin specs register on import)

    readme = Path(__file__).resolve().parents[1] / "kernels" / "README.md"
    text = readme.read_text()
    block = f"{README_BEGIN}\n{routing_table_md()}\n{README_END}"
    if README_BEGIN not in text or README_END not in text:
        if not write:
            return False
        raise RuntimeError(f"{readme}: routing-table markers missing")
    start = text.index(README_BEGIN)
    end = text.index(README_END) + len(README_END)
    if text[start:end] == block:
        return True
    if write:
        readme.write_text(text[:start] + block + text[end:])
        return True
    return False


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="print (or sync into kernels/README.md) the registry routing table"
    )
    ap.add_argument("--write", action="store_true",
                    help="rewrite the README block between the markers")
    args = ap.parse_args(argv)
    # under ``python -m`` this file executes as __main__, a SEPARATE module
    # instance with its own empty _REGISTRY — delegate to the canonical
    # import (which repro.core.algorithms populates)
    from repro.core import registry as canonical

    print(canonical.routing_table_md())
    if args.write:
        canonical.sync_readme(write=True)
        print("\n(README block synced)")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
