"""The flat parameter plane: ravel a pytree ONCE, compute on one buffer.

Every update FedCM (and each registered algorithm — see
``repro.core.registry``) performs — the client blend
``v = α·g + (1−α)·Δ_t``, SCAFFOLD's ``g − c_i + c``, the masked cohort
mean, the server momentum/param step — is elementwise over the parameter
vector.  The pytree structure only matters to the *loss function*; carrying
it through the update phase costs a tree_map dispatch per leaf per op and,
on the fused-kernel path, a full concatenate/split round-trip per local
step.  ``FlatSpec`` fixes the representation instead:

* ``ravel(tree)``      → ONE contiguous ``(P,)`` buffer (default f32),
* ``unravel(flat)``    → the original tree (shapes AND dtypes restored) —
  leaves are slices of the buffer, essentially free under jit,
* ``view_leaf(flat, key)`` → a single leaf without materializing the tree.

The layout is the static offset table ``spec.leaves`` (path, shape, dtype,
offset, size) in treedef order, no alignment padding — kernels pad the
*tail* of the whole plane to their block size instead (see
``src/repro/kernels/README.md``).  Buffers with leading batch axes reuse the
same table: a cohort delta plane is ``(C, P)``, stacked per-client control
variates are ``(N, P)``; ``unravel`` restores ``(..., *shape)`` leaves.

``FederatedEngine`` ravels params/momentum/client-state once per
``run_rounds`` call and carries the planes through the local-step scan, the
cohort vmap, aggregation, and the server update (``cfg.use_flat_plane``;
the tree path remains as the numerical oracle).

``CohortUplink`` is the in-flight cohort store of the async pipelined
engine (``FederatedEngine.run_rounds_async``): a static depth-D ring of
uplink planes plus per-cohort metadata, carried through the pipelined
``lax.scan`` as a python tuple the body rotates (``ring_push``).  An
uplink launched at round t is folded D−1 rounds later when the server
folds the (by then stale) cohort in — the kernel path's ``(C, P)`` slot
layout is the same layout a cohort-axis reduce-scatter wants, which is
what makes the ring the natural seam for multi-host cohort sharding.

Under the out-of-core population store (``cfg.population_store="host"``,
see ``repro.data.population``) the ring's client-state planes are the
host-gathered ``(C, P)`` cohort rows — device memory never holds an
``(N, ·)`` per-client plane; the population axis exists only in the host
store's sparse row map.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.trees import ravel_leaves, split_flat


class LeafSpec(NamedTuple):
    """Static layout of one leaf inside the flat plane."""

    path: str  # jax.tree_util.keystr of the leaf's key path
    shape: Tuple[int, ...]
    dtype: Any  # numpy dtype (hashable)
    offset: int  # first element in the plane
    size: int  # number of elements


class FlatSpec:
    """Static per-leaf offset/shape/dtype table for one pytree structure.

    Hashable and comparable so it can serve as (part of) a jit cache key;
    building one is pure python and happens at trace time.
    """

    __slots__ = ("treedef", "leaves", "size")

    def __init__(self, treedef, leaves: Tuple[LeafSpec, ...]):
        self.treedef = treedef
        self.leaves = leaves
        self.size = (leaves[-1].offset + leaves[-1].size) if leaves else 0

    # ------------------------------------------------------------- build
    @classmethod
    def from_tree(cls, tree, require_float: bool = True) -> "FlatSpec":
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs, off = [], 0
        for path, leaf in flat:
            dt = np.dtype(leaf.dtype)
            if require_float and not jnp.issubdtype(dt, jnp.floating):
                raise TypeError(
                    f"flat plane requires floating leaves; "
                    f"{jax.tree_util.keystr(path)} has dtype {dt} "
                    f"(set cfg.use_flat_plane=False for non-float params)"
                )
            size = math.prod(leaf.shape)
            specs.append(
                LeafSpec(jax.tree_util.keystr(path), tuple(leaf.shape), dt, off, size)
            )
            off += size
        return cls(treedef, tuple(specs))

    # ------------------------------------------------------------- ravel
    def ravel(self, tree, dtype=jnp.float32, batch_dims: int = 0) -> jax.Array:
        """Tree → one contiguous ``(*lead, P)`` buffer in ``dtype``.

        ``batch_dims`` leading axes of every leaf (e.g. the stacked-client
        axis of ``(N, *shape)`` state) are preserved in front of the plane
        axis.  This is the ONE concatenate of the flat engine — everything
        downstream operates on the buffer.
        """
        leaves = self.treedef.flatten_up_to(tree)
        return ravel_leaves(leaves, dtype=dtype, batch_dims=batch_dims)

    def unravel(self, flat: jax.Array, dtype=None):
        """Buffer ``(*lead, P)`` → tree of ``(*lead, *shape)`` leaves.

        Leaf dtypes are restored from the table (pass ``dtype`` to override,
        e.g. a uniform momentum dtype).  Under jit the slices fuse into
        their consumers — no per-step copy.
        """
        dtypes = [dtype or l.dtype for l in self.leaves]
        leaves = split_flat(flat, [l.shape for l in self.leaves], dtypes)
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def view_leaf(self, flat: jax.Array, key: Union[int, str], dtype=None):
        """One leaf of the plane by index or key path, without the tree."""
        if isinstance(key, str):
            matches = [i for i, l in enumerate(self.leaves) if l.path == key]
            if not matches:
                raise KeyError(f"no leaf {key!r}; paths: {[l.path for l in self.leaves]}")
            key = matches[0]
        spec = self.leaves[key]
        seg = jax.lax.slice_in_dim(flat, spec.offset, spec.offset + spec.size, axis=-1)
        seg = seg.reshape(*flat.shape[:-1], *spec.shape)
        return seg.astype(dtype or spec.dtype)

    # ------------------------------------------------------------- misc
    @property
    def nbytes(self) -> int:
        """Bytes of the ORIGINAL tree (per-leaf dtypes) — payload accounting
        must charge the wire format, not the f32 compute plane."""
        return sum(l.size * l.dtype.itemsize for l in self.leaves)

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, FlatSpec)
            and self.treedef == other.treedef
            and self.leaves == other.leaves
        )

    def __hash__(self) -> int:
        return hash((self.treedef, self.leaves))

    def __repr__(self) -> str:
        return f"FlatSpec(n_leaves={len(self.leaves)}, size={self.size})"


# ----------------------------------------------------------------------
# in-flight cohort ring (async pipelined engine)
# ----------------------------------------------------------------------


class CohortUplink(NamedTuple):
    """ONE in-flight cohort's uplink on the flat plane — the unit the
    async engine's depth-D ring carries (a python tuple of D−1 pending
    uplinks in the scan carry; the D-th is the one being launched).

    Plane layout is PATH-DEPENDENT, mirroring the sync engine's own rule
    about when the ``(C, P)`` cohort plane is worth materializing:

    * kernel path (``use_fused_kernel``): ``delta``/``extra`` are raw
      ``(C, P)`` planes — the fused server kernel folds mean + EMA + param
      step in ONE streaming pass over the cohort axis at fold time.
    * jnp path: ``delta``/``extra`` are the FOLD-READY masked means,
      ``(P,)`` each — the mean's weights are launch-time constants, so
      pre-reducing at launch is mathematically identical and the ring
      carries C× less state (the sync jnp path never materializes the
      cohort plane either; see ``flat_client_update``).

    ``state_delta`` stays a raw ``(C, P)`` plane on BOTH paths: the
    client-state scatter at fold time is inherently per-client.
    ``state_delta``/``extra`` are ``None`` for algorithms without client
    state / full-batch gradients — never allocated, never copied.
    """

    delta: jax.Array  # (C, P) kernel path / (P,) jnp path (pre-reduced)
    state_delta: Optional[jax.Array]  # (C, P) or None (SCAFFOLD/FedDyn)
    extra: Optional[jax.Array]  # (C, P) / (P,) or None (MimeLite)
    ids: jax.Array  # (C,) int32 sampled client ids
    w: jax.Array  # (C,) f32 active-mask weights
    eta_l: jax.Array  # f32 η_l at launch (the fold must reuse it)


def pad_cohort(tree, target: int, mode: str = "edge"):
    """Pad the leading (cohort) axis of every leaf to ``target`` rows.

    The cohort-parallel engine pads the sampled cohort to a multiple of the
    ``"clients"`` mesh axis AFTER the minibatch/state gathers (so the rng
    stream and every real client's data are bitwise those of the unsharded
    round) and gives the pad rows zero weight: a trailing ``+ 0.0`` in the
    masked fold is exact, which is what keeps the ragged-cohort case
    bitwise against the unsharded oracle.  ``None`` passes through.

    ``mode="edge"`` (default, for DATA: batches, gathered client states,
    ids) repeats the last real row — the pad clients then run their local
    steps on a real client's finite inputs, so a loss_fn that is
    non-finite on all-zero input (batch-statistic normalizers) cannot
    poison the fold through ``0 · NaN = NaN``.  ``mode="zero"`` is for
    the WEIGHT row, whose pad entries must stay exactly 0.
    """
    if tree is None:
        return None

    def p(a):
        C = a.shape[0]
        if C >= target:
            return a
        widths = [(0, target - C)] + [(0, 0)] * (a.ndim - 1)
        if mode == "edge":
            return jnp.pad(a, widths, mode="edge")
        return jnp.pad(a, widths)

    return jax.tree_util.tree_map(p, tree)


def cohort_to_columns(plane, axis_name: str, n_shards: int):
    """Clients-sharded ``(C, P)`` plane → plane-column shards, INSIDE
    ``shard_map``: pad the plane axis to a multiple of ``n_shards`` and
    ``all_to_all`` so each device holds ``(C, ceil(P/n_shards))`` — the
    COMPLETE cohort for its columns.  This is the reduce-scatter's first
    half, decomposed so the subsequent device-local reduce runs over all
    C clients in the unsharded reduction order (a ``psum_scatter`` would
    pre-reduce per device and re-associate the f32 sum — the bitwise
    oracle breaks).  Shared by every scattered reduction
    (``cohort_mean_scatter`` here, ``scatter_fold`` in the server kernel
    ops) — the decomposition is load-bearing, keep it in one place."""
    Pn = plane.shape[-1]
    chunk = -(-Pn // n_shards)
    plane = jnp.pad(plane, ((0, 0), (0, chunk * n_shards - Pn)))
    return jax.lax.all_to_all(plane, axis_name, split_axis=1, concat_axis=0,
                              tiled=True)


def plane_chunk(vec, axis_name: str, n_shards: int):
    """This device's column chunk of a replicated ``(P,)`` plane (the
    slice aligned with ``cohort_to_columns``'s layout)."""
    Pn = vec.shape[-1]
    chunk = -(-Pn // n_shards)
    idx = jax.lax.axis_index(axis_name)
    return jax.lax.dynamic_slice(jnp.pad(vec, (0, chunk * n_shards - Pn)),
                                 (idx * chunk,), (chunk,))


def gather_plane(vec, axis_name: str, n: int):
    """Inverse of ``plane_chunk``: all_gather the per-device column chunks
    back to the replicated ``(n,)`` plane (pad columns dropped)."""
    return jax.lax.all_gather(vec, axis_name, tiled=True)[:n]


def cohort_mean_scatter(plane, w, n_active, axis_name: str, n_shards: int,
                        agg_dtype=jnp.float32):
    """Masked cohort mean of one ``(C, P)`` plane, lowered as an explicit
    reduce-scatter + all-gather — call INSIDE ``shard_map`` with ``plane``
    sharded over ``axis_name`` (local view ``(C/n_shards, P)``) and ``w``
    replicated.

    The reduce-scatter is decomposed as ``cohort_to_columns`` (cohort
    shards → plane-column shards) followed by a device-local full-cohort
    contraction: every device then reduces over the COMPLETE client axis
    for its plane columns, in exactly the reduction order (and with
    exactly the ``aggregate_dtype`` quantization) of the unsharded
    ``_masked_pmean``.  The trailing ``gather_plane`` rebuilds the
    replicated ``(P,)`` mean.
    """
    Pn = plane.shape[-1]
    cols = cohort_to_columns(plane, axis_name, n_shards)
    # max(n, 1) guards the empty cohort (0/0 would NaN-poison the fold);
    # exact for n ≥ 1, so non-empty rounds stay bitwise
    mean = (
        jnp.tensordot(w.astype(agg_dtype), cols.astype(agg_dtype), axes=(0, 0))
        .astype(jnp.float32) / jnp.maximum(n_active, 1.0)
    )
    return gather_plane(mean, axis_name, Pn)


def ring_push(pending: Tuple[CohortUplink, ...], entry: CohortUplink):
    """Rotate the static-depth ring: append the just-launched uplink, pop
    the OLDEST for folding.  Returns ``(oldest, new_pending)``.

    The ring is a python tuple because depth is small and STATIC: rotating
    positions at trace time gives XLA direct carry dataflow — the fold
    reads a while-loop carry buffer, no per-round
    ``dynamic_update_slice``/``dynamic_slice`` materialization.  (A
    stacked ``(D, …)`` buffer with traced slot indices measured ~10%
    slower per round on the update-bound benchmark; a traced-depth ring —
    and the cohort-axis reduce-scatter of the multi-host roadmap item —
    would bring the stacked form back.)

    ``pending`` holds D−1 uplinks in launch order (oldest first); with
    D = 1 it is empty and the entry folds the round it launches — the
    sync schedule.
    """
    fifo = (*pending, entry)
    return fifo[0], fifo[1:]
