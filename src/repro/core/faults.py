"""Fault injection + uplink quarantine primitives (pure mask/plane math).

The fault model lives entirely in :class:`repro.configs.base.FaultConfig`;
this module turns it into arrays.  Everything here is a pure function of
``(fault, round, client ids, uplink planes)`` — no engine state, no host
side effects — so the engine can splice the transforms between cohort
launch and server fold on every execution path (sync scan, async ring,
host-store loop) and the same draws can be reproduced independently by
tests and benchmarks.

Determinism contract: draws are keyed by ``(fault.seed, absolute server
round t, client id)`` via ``jax.random.fold_in`` chains — NOT by cohort
slot — so a client's fate in a round is invariant to where the sampler
placed it, and a kill/resume (the round counter rides the checkpoint)
replays the identical fault sequence.

Representation-generic: the payload helpers accept either the kernel
path's ``(C, P)``/``(C_pad, P)`` uplink planes or the jnp/tree paths'
``(C, leaf…)`` pytrees (they tree_map over leaves; a plane is just a
one-leaf tree).  ``None`` payloads pass through (the flat engine skips
unused planes by design).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class FaultPlan(NamedTuple):
    """Per-(round, client) fault draws for one sampled cohort.

    drop: (C,) bool — uplink lost (drop_rate) or past deadline (straggler)
    corrupt: (C,) bool — payload arrives corrupted
    noise_keys: (C,) per-client PRNG keys for "noise" mode, else None
    """

    drop: jax.Array
    corrupt: jax.Array
    noise_keys: Optional[jax.Array]


def _per_client_keys(kt, stream: int, ids):
    """One key per client id for an independent fault stream."""
    ks = jax.random.fold_in(kt, stream)
    return jax.vmap(lambda cid: jax.random.fold_in(ks, cid))(ids)


def fault_masks(fault, t, ids) -> FaultPlan:
    """Reproducible per-client fault draws for absolute round ``t``."""
    C = ids.shape[0]
    kt = jax.random.fold_in(jax.random.PRNGKey(fault.seed), t)
    drop = jnp.zeros((C,), bool)
    if fault.drop_rate > 0.0:
        u = jax.vmap(lambda k: jax.random.uniform(k, ()))(
            _per_client_keys(kt, 1, ids))
        drop = u < jnp.float32(fault.drop_rate)
    if fault.deadline > 0.0:
        # round time ~ LogNormal(0, σ) in units of the median client
        z = jax.vmap(lambda k: jax.random.normal(k, ()))(
            _per_client_keys(kt, 2, ids))
        late = jnp.exp(jnp.float32(fault.straggler_sigma) * z) > jnp.float32(
            fault.deadline)
        drop = drop | late
    corrupt = jnp.zeros((C,), bool)
    noise_keys = None
    if fault.corrupt_rate > 0.0:
        u = jax.vmap(lambda k: jax.random.uniform(k, ()))(
            _per_client_keys(kt, 3, ids))
        corrupt = u < jnp.float32(fault.corrupt_rate)
        if fault.corrupt_mode == "noise":
            noise_keys = _per_client_keys(kt, 4, ids)
    return FaultPlan(drop=drop, corrupt=corrupt, noise_keys=noise_keys)


def corrupt_uplink(fault, cmask, noise_keys, x):
    """Corrupt the rows of payload ``x`` where ``cmask`` is True.

    ``x`` is a (C, P) plane or a (C, leaf…) pytree; rows with
    ``cmask=False`` pass through bitwise (``jnp.where`` row select).
    """
    if x is None:
        return None
    mode = fault.corrupt_mode
    if mode not in ("nan", "inf", "noise"):
        raise ValueError(
            f"unknown corrupt_mode {mode!r}; known: nan | inf | noise")
    leaves, treedef = jax.tree_util.tree_flatten(x)
    out = []
    for i, leaf in enumerate(leaves):
        cm = cmask.reshape((-1,) + (1,) * (leaf.ndim - 1))
        if mode in ("nan", "inf"):
            fill = jnp.asarray(
                jnp.nan if mode == "nan" else jnp.inf, leaf.dtype)
            out.append(jnp.where(cm, fill, leaf))
        else:
            lkeys = jax.vmap(lambda k, i=i: jax.random.fold_in(k, i))(
                noise_keys)
            noise = jax.vmap(
                lambda k, s=leaf.shape[1:]: jax.random.normal(k, s,
                                                              jnp.float32)
            )(lkeys)
            noisy = leaf + (jnp.float32(fault.noise_scale)
                            * jnp.abs(leaf.astype(jnp.float32))
                            * noise).astype(leaf.dtype)
            out.append(jnp.where(cm, noisy, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def rows_finite(x, C: int) -> jax.Array:
    """(C,) bool: is every element of client c's payload rows finite?

    ``None`` payloads are vacuously finite (all-True).
    """
    ok = jnp.ones((C,), bool)
    if x is None:
        return ok
    for leaf in jax.tree_util.tree_leaves(x):
        ok = ok & jnp.all(jnp.isfinite(leaf),
                          axis=tuple(range(1, leaf.ndim)))
    return ok


def rows_sqnorm(x, C: int) -> jax.Array:
    """(C,) f32: squared L2 norm of each client's payload rows."""
    s = jnp.zeros((C,), jnp.float32)
    if x is None:
        return s
    for leaf in jax.tree_util.tree_leaves(x):
        s = s + jnp.sum(jnp.square(leaf.astype(jnp.float32)),
                        axis=tuple(range(1, leaf.ndim)))
    return s


def zero_rows(x, bad):
    """Sanitize quarantined rows to exact zeros in every leaf.

    Zeroing (not just down-weighting) is load-bearing: a NaN row with
    weight 0 still poisons ``tensordot``/scatter reductions because
    0·NaN = NaN; an exact-zero row contributes ±0, which adding preserves
    sums bitwise.
    """
    if x is None:
        return None
    return jax.tree_util.tree_map(
        lambda leaf: jnp.where(
            bad.reshape((-1,) + (1,) * (leaf.ndim - 1)),
            jnp.zeros((), leaf.dtype), leaf),
        x)
