"""Uplink compression as pure plane transforms (config/spec data).

At production scale the network, not the FLOPs, is the budget: an
uncompressed cohort round moves full-precision f32 ``(C, P)`` uplink
planes, and the async engine keeps ``pipeline_depth`` of them in flight.
This module realizes :class:`repro.configs.base.CompressionConfig` as
pure plane transforms spliced between client launch and server fold on
EVERY execution path — sync jnp/kernel, async ring (ring entries carry
the compressed representation, 4–8x less in-flight memory at depth D),
cohort-sharded (``all_to_all`` moves int8/bf16 payloads instead of f32),
and the host-store loop.  ``compression=None`` traces none of this.

Representations
---------------
* ``"int8"`` → :class:`QPlane`: per-row absmax scaling
  (``scale = max|row| / 127``, zero rows get scale 1) + stochastic
  rounding ``q = clip(floor(x/scale + U[0,1)), −127, 127)``.  Unbiased:
  ``E[q·scale] = x`` elementwise (the clip never binds — ``|x/scale| ≤
  127`` by construction, and ``floor(±127 + u) = ±127`` for ``u < 1``).
  1 byte/element + one f32 scale per client row on the wire.
* ``"bf16"`` → a plain bfloat16 ``(C, P)`` array (round-to-nearest-even;
  2 bytes/element).  The fused dequant fold consumes it with unit scale.
* ``"topk"`` → :class:`TopKPlane`: per-row magnitude top-k of the DELTA
  plane (``k = max(1, round(topk_frac · P))``) with error feedback —
  the unsent remainder accumulates in a per-client residual plane
  (resident ``(N, P)`` or a host-store row stream) and is added to that
  client's next uplink, the standard fix for sparsification bias
  (memory/EF-SGD).  8 bytes/kept element (f32 value + int32 index).
  Non-delta wire planes (SCAFFOLD's control-variate deltas, MimeLite's
  full-batch grads) stay f32 under top-k: sparsifying a *state* stream
  without its own residual would bias the stored state itself — the
  registry refuses specs that declare it (see
  ``repro.core.registry._validate``).

Seeding: the stochastic-rounding draw is keyed
``fold_in(PRNGKey(comp.seed), absolute round t)`` then ``fold_in`` by a
static per-plane index — reproducible and kill/resume-stable.
Compression runs OUTSIDE ``shard_map`` on the full cohort plane (per-row
scales involve no cross-row reduction), so sharded and unsharded runs of
the same cohort draw identically whenever the cohort shape matches —
i.e. when ``cohort_size`` divides the mesh; a padded cohort changes the
draw SHAPE and therefore the realized rounding noise (still unbiased,
just a different sample).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# plane-name → static fold_in index for the per-plane rounding streams
PLANE_STREAMS = {"delta": 0, "state_delta": 1, "extra": 2}

INT8_LEVELS = 127.0
KINDS = ("int8", "bf16", "topk")


class QPlane(NamedTuple):
    """Stochastic-rounded int8 representation of an f32 ``(C, P)`` plane.

    Also the normalized carrier for bf16 compression inside the fused
    dequant fold: ``q`` may be a bf16 plane with ``scale`` all-ones (an
    f32 multiply by 1.0 is exact, so the bf16 path shares the kernel).
    """

    q: jax.Array  # int8 (or bf16) (C, P)
    scale: jax.Array  # f32 (C, 1) per-row dequant scale


class TopKPlane(NamedTuple):
    """Top-k sparsified representation of an f32 ``(C, P)`` plane."""

    values: jax.Array  # f32 (C, k)
    idx: jax.Array  # int32 (C, k) element indices into the plane


def validate_compression(comp) -> None:
    """Raise ValueError on a malformed CompressionConfig."""
    if comp.kind not in KINDS:
        raise ValueError(
            f"unknown compression kind {comp.kind!r} — expected one of {KINDS}"
        )
    if comp.kind == "topk" and not (0.0 < comp.topk_frac <= 1.0):
        raise ValueError(
            f"topk_frac must be in (0, 1], got {comp.topk_frac}"
        )


def topk_k(comp, n: int) -> int:
    """Static kept-elements-per-row under ``kind='topk'``."""
    return max(1, min(n, int(round(comp.topk_frac * n))))


def round_key(comp, t):
    """Per-round stochastic-rounding key: (seed, absolute round t)."""
    return jax.random.fold_in(jax.random.PRNGKey(comp.seed), t)


def plane_key(key, name: str):
    """Per-plane sub-stream of a round key (static plane index)."""
    return jax.random.fold_in(key, PLANE_STREAMS[name])


# ---------------------------------------------------------------- int8


def quantize_int8(plane, key) -> QPlane:
    """Per-row absmax-scaled stochastic rounding to int8 (unbiased)."""
    amax = jnp.max(jnp.abs(plane), axis=-1, keepdims=True)
    # zero rows (dropped/quarantined clients) get scale 1 → q stays 0
    scale = jnp.where(amax > 0, amax / INT8_LEVELS, 1.0).astype(jnp.float32)
    u = jax.random.uniform(key, plane.shape, jnp.float32)
    q = jnp.clip(jnp.floor(plane / scale + u), -INT8_LEVELS, INT8_LEVELS)
    return QPlane(q=q.astype(jnp.int8), scale=scale)


def dequantize(rep: QPlane):
    """QPlane → dense f32 (the jnp oracle of the fused dequant kernel)."""
    return rep.q.astype(jnp.float32) * rep.scale


def quantize_bf16(plane):
    """Round-to-nearest-even bfloat16 (2 bytes/element on the wire)."""
    return plane.astype(jnp.bfloat16)


def as_qplane(rep) -> QPlane:
    """Normalize a compressed dense-layout rep to a QPlane for the fused
    dequant kernel: bf16 planes get a unit scale (exact under f32)."""
    if isinstance(rep, QPlane):
        return rep
    return QPlane(q=rep, scale=jnp.ones((rep.shape[0], 1), jnp.float32))


# ---------------------------------------------------------------- topk


def sparsify_topk(plane, k: int) -> TopKPlane:
    """Per-row magnitude top-k (k static)."""
    _, idx = jax.lax.top_k(jnp.abs(plane), k)
    values = jnp.take_along_axis(plane, idx, axis=-1)
    return TopKPlane(values=values, idx=idx.astype(jnp.int32))


def densify_topk(rep: TopKPlane, n: int):
    """TopKPlane → dense f32 ``(C, n)`` (top_k indices never collide)."""
    C = rep.values.shape[0]
    out = jnp.zeros((C, n), jnp.float32)
    return out.at[jnp.arange(C)[:, None], rep.idx].set(rep.values)


def error_feedback_topk(comp, plane, residual_rows, active, n: int):
    """One error-feedback round for the cohort's delta plane.

    ``plane`` (C, n) is the raw uplink, ``residual_rows`` (C, n) the
    cohort's gathered residuals, ``active`` (C,) the post-fault weight
    row (a client that did not transmit keeps its residual untouched).
    Returns ``(rep, recon, new_residual_rows)`` where ``recon`` is the
    dense plane the server folds (exactly what arrived on the wire) and
    ``new_residual_rows = accumulated − sent`` for active rows.
    """
    acc = plane + residual_rows
    rep = sparsify_topk(acc, topk_k(comp, n))
    recon = densify_topk(rep, n)
    keep = (active > 0)[:, None]
    new_rows = jnp.where(keep, acc - recon, residual_rows)
    # inactive rows must fold as zeros, not as their stale accumulator
    recon = jnp.where(keep, recon, 0.0)
    return rep, recon, new_rows


# ------------------------------------------------------------ dispatch


def compress_plane(comp, plane, key):
    """Dense f32 plane → wire representation (int8/bf16 kinds)."""
    if comp.kind == "int8":
        return quantize_int8(plane, key)
    if comp.kind == "bf16":
        return quantize_bf16(plane)
    raise ValueError(f"compress_plane does not handle kind {comp.kind!r}")


def decompress_plane(rep, n: Optional[int] = None):
    """Wire representation → dense f32 plane (any kind)."""
    if isinstance(rep, QPlane):
        return dequantize(rep)
    if isinstance(rep, TopKPlane):
        assert n is not None, "densifying a TopKPlane needs the plane length"
        return densify_topk(rep, n)
    return rep.astype(jnp.float32)


def is_compressed(rep) -> bool:
    """True when ``rep`` is a wire representation rather than dense f32."""
    return (isinstance(rep, (QPlane, TopKPlane))
            or getattr(rep, "dtype", None) == jnp.bfloat16)


# ---------------------------------------------------------- accounting


def wire_plane_bytes(comp, size: int, nbytes: int) -> int:
    """Bytes one compressed ``(P,)`` uplink plane costs on the wire.

    ``size`` is the element count, ``nbytes`` the uncompressed byte count
    (which honors sub-f32 leaf dtypes — ``comp=None`` returns it
    verbatim, preserving the pre-compression accounting bitwise).
    """
    if comp is None:
        return nbytes
    if comp.kind == "bf16":
        return 2 * size
    if comp.kind == "int8":
        return size + 4  # 1 byte/elem + one f32 row scale
    if comp.kind == "topk":
        return topk_k(comp, size) * 8  # f32 value + int32 index per kept
    raise ValueError(f"unknown compression kind {comp.kind!r}")


def uplink_bytes_per_client(comp, wire_planes, size: int, nbytes: int) -> int:
    """Total uplink bytes/client/round over a spec's wire planes.

    Under ``topk`` only the ``"delta"`` stream sparsifies (see module
    docstring); other wire planes ride f32.
    """
    total = 0
    for name in wire_planes:
        if comp is not None and comp.kind == "topk" and name != "delta":
            total += nbytes
        else:
            total += wire_plane_bytes(comp, size, nbytes)
    return total
