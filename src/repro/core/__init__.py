"""FedCM core: the paper's algorithm + baselines + round engine."""
from repro.core.algorithms import (
    ALGORITHMS,
    Algorithm,
    ClientOutputs,
    ServerState,
    client_state_init,
    get_algorithm,
    server_init,
)
from repro.core.engine import (
    FederatedEngine,
    FedState,
    RoundMetrics,
    client_update,
    cohort_capacity,
    local_learning_rate,
    make_eval_fn,
    sample_cohort,
)

__all__ = [
    "ALGORITHMS",
    "Algorithm",
    "ClientOutputs",
    "ServerState",
    "client_state_init",
    "get_algorithm",
    "server_init",
    "FederatedEngine",
    "FedState",
    "RoundMetrics",
    "client_update",
    "cohort_capacity",
    "local_learning_rate",
    "make_eval_fn",
    "sample_cohort",
]
