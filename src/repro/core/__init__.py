"""FedCM core: the declarative algorithm registry + round engine.

``repro.core.registry`` is the public algorithm API: one ``AlgorithmSpec``
(client-direction coefficient row, server-fold coefficient rows, state-
plane flags) drives the tree path, the flat plane, the fused Pallas
kernels, and the async pipelined ring.  ``repro.core.algorithms`` holds
the builtin spec definitions; ``@register_algorithm`` adds new ones as
pure data.
"""
from repro.core.algorithms import (
    ALGORITHMS,
    Algorithm,
    ClientOutputs,
    FlatClientOutputs,
    ServerState,
    client_state_init,
    sparse_client_finalize,
    get_algorithm,
    server_init,
)
from repro.core.engine import (
    AsyncRoundMetrics,
    FederatedEngine,
    FedState,
    FlatMaster,
    RoundMetrics,
    client_update,
    cohort_capacity,
    flat_client_update,
    local_learning_rate,
    make_eval_fn,
    sample_cohort,
    sample_cohort_ex,
)
from repro.core.faults import (
    FaultPlan,
    corrupt_uplink,
    fault_masks,
    rows_finite,
    zero_rows,
)
from repro.core.flat import CohortUplink, FlatSpec, LeafSpec, ring_push
from repro.core.registry import (
    AlgorithmSpec,
    DirectionRow,
    FoldPass,
    describe_algorithm,
    list_algorithms,
    register_algorithm,
    routing_table_md,
    unregister_algorithm,
)

__all__ = [
    "ALGORITHMS",
    "Algorithm",
    "AlgorithmSpec",
    "ClientOutputs",
    "CohortUplink",
    "DirectionRow",
    "FlatClientOutputs",
    "FlatMaster",
    "FlatSpec",
    "FoldPass",
    "LeafSpec",
    "ServerState",
    "client_state_init",
    "describe_algorithm",
    "sparse_client_finalize",
    "get_algorithm",
    "list_algorithms",
    "register_algorithm",
    "routing_table_md",
    "server_init",
    "unregister_algorithm",
    "AsyncRoundMetrics",
    "FaultPlan",
    "FederatedEngine",
    "FedState",
    "RoundMetrics",
    "corrupt_uplink",
    "fault_masks",
    "rows_finite",
    "zero_rows",
    "ring_push",
    "client_update",
    "cohort_capacity",
    "flat_client_update",
    "local_learning_rate",
    "make_eval_fn",
    "sample_cohort",
    "sample_cohort_ex",
]
