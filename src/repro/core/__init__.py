"""FedCM core: the paper's algorithm + baselines + round engine."""
from repro.core.algorithms import (
    ALGORITHMS,
    Algorithm,
    ClientOutputs,
    FlatClientOutputs,
    ServerState,
    client_state_init,
    sparse_client_finalize,
    get_algorithm,
    server_init,
)
from repro.core.engine import (
    FederatedEngine,
    FedState,
    RoundMetrics,
    client_update,
    cohort_capacity,
    flat_client_update,
    local_learning_rate,
    make_eval_fn,
    sample_cohort,
)
from repro.core.flat import FlatSpec, LeafSpec

__all__ = [
    "ALGORITHMS",
    "Algorithm",
    "ClientOutputs",
    "FlatClientOutputs",
    "FlatSpec",
    "LeafSpec",
    "ServerState",
    "client_state_init",
    "sparse_client_finalize",
    "get_algorithm",
    "server_init",
    "FederatedEngine",
    "FedState",
    "RoundMetrics",
    "client_update",
    "cohort_capacity",
    "flat_client_update",
    "local_learning_rate",
    "make_eval_fn",
    "sample_cohort",
]
