import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and report.

The two lines above MUST precede any jax import (jax locks the device count
at first init).  512 placeholder host devices back both the 16×16 single-pod
mesh and the 2×16×16 multi-pod mesh.

For each pair this produces the compiled artifact a real TPU run would
execute and records: per-device memory analysis (proves it fits a 16 GiB
v5e), cost analysis (FLOPs / bytes for §Roofline), and the collective op
census parsed from the partitioned HLO.  Artifacts land in
``benchmarks/artifacts/dryrun/*.json`` — benchmarks/roofline.py reads them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quiet]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.hlo_stats import collective_stats, op_census
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, n_chips
from repro.launch.steps import Knobs, build_step
from repro.utils.compat import set_mesh

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

# ----------------------------------------------------------------------
# per-arch knobs (hardware adaptation — DESIGN.md §3):
#   * llama4 (≈390 B params): bf16 AdamW moments, 8 grad-accum microbatches
#   * dbrx / chameleon / gemma3 / qwen3 / starcoder2: remat=full, f32 moments
#   * microbatches sized so train-step activations fit 16 GiB HBM
# ----------------------------------------------------------------------
# microbatches sized so the per-chip remat activation stack
# L × (B_local/M) × S × D × 2B stays ≤ ~6 GiB and total temp ≤ 16 GiB
# (verified by the dry-run memory_analysis — see EXPERIMENTS.md §Dry-run);
# llama4 additionally needs bf16 AdamW moments (f32 = 12.5 GiB/chip).
ARCH_KNOBS = {
    "llama4-maverick-400b-a17b": dict(moment_dtype="bfloat16", microbatches=16, grad_accum_dtype="bfloat16"),
    "dbrx-132b": dict(microbatches=8, grad_accum_dtype="bfloat16"),
    "chameleon-34b": dict(microbatches=16),
    "gemma3-12b": dict(microbatches=4),
    "qwen3-14b": dict(microbatches=8),
    "starcoder2-7b": dict(microbatches=4),
    "seamless-m4t-large-v2": dict(microbatches=2),
    "zamba2-7b": dict(microbatches=4),
    "llama3.2-1b": dict(microbatches=2),
    "mamba2-1.3b": dict(microbatches=4),
}

# long_500k: sub-quadratic archs only (DESIGN.md §6)
LONG_OK = {"mamba2-1.3b", "zamba2-7b", "gemma3-12b", "starcoder2-7b"}


def skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return "full-attention family: long_500k requires sub-quadratic attention (DESIGN.md §6)"
    return None


def knobs_for(arch: str, shape_name: str, overrides: dict | None = None) -> Knobs:
    kw = dict(ARCH_KNOBS.get(arch, {}))
    if shape_name != "train_4k":
        kw.pop("microbatches", None)  # grad accumulation is train-only
        kw.pop("moment_dtype", None)
    # full layer-scan unroll: XLA cost_analysis counts while-loop bodies
    # ONCE, so the dry-run lowers the unrolled program (execution uses scan)
    kw.setdefault("scan_unroll", 1024)
    if overrides:
        kw.update(overrides)
    return Knobs(**kw)


def _cost_of(cfg, shape, mesh, knobs):
    """Compile the unrolled form of ``cfg`` and return (flops, bytes, coll, census)."""
    bundle = build_step(cfg, shape, mesh, knobs)
    with set_mesh(mesh):
        compiled = bundle.lower().compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        collective_stats(hlo),
        op_census(hlo),
    )


def _extrapolated_cost(cfg, shape, mesh, knobs):
    """Per-device cost of the full-depth model, extrapolated from unrolled
    1-period and 2-period compiles: cost(N) = cost(1) + (N−1)·(cost(2)−cost(1)).
    """
    import dataclasses as _dc

    from repro.models.transformer import period_layout

    if cfg.is_encoder_decoder:
        n_eff = float(cfg.n_layers)  # encoder+decoder scale together below

        def scaled(k):
            return _dc.replace(cfg, n_layers=k, n_encoder_layers=k)
    else:
        slots, n_periods, tail = period_layout(cfg)
        period = len(slots)
        # tail layers (zamba: 3 trailing mamba slots) ride the per-period
        # slope as a fraction — a slight attention overcount for 3/81 layers
        n_eff = n_periods + (len(tail) / period if tail else 0.0)

        def scaled(k):
            return _dc.replace(cfg, n_layers=k * period)

    if n_eff <= 4:
        return _cost_of(cfg, shape, mesh, knobs)

    # anchors at 2 and 4 periods: far enough from 1-layer fusion artifacts;
    # validated on llama3.2-1b vs a true 16-layer unroll — collectives exact,
    # FLOPs −4%, bytes −28% (the unrolled "bytes accessed" itself counts
    # stacked-activation slices at full-stack size, a quadratic cost-model
    # artifact, so the linear fit is closer to physical HBM traffic).
    f1, b1, c1, census = _cost_of(scaled(2), shape, mesh, knobs)
    f2, b2, c2, _ = _cost_of(scaled(4), shape, mesh, knobs)

    def lerp(a, b):
        return a + (n_eff - 2.0) * (b - a) / 2.0

    coll = {}
    for k in set(c1) | set(c2):
        if k == "total":
            continue
        coll[k] = {
            "count": int(round(lerp(c1.get(k, {}).get("count", 0), c2.get(k, {}).get("count", 0)))),
            "bytes": lerp(c1.get(k, {}).get("bytes", 0), c2.get(k, {}).get("bytes", 0)),
        }
    coll["total"] = {
        "count": sum(v["count"] for v in coll.values()),
        "bytes": sum(v["bytes"] for v in coll.values()),
    }
    return lerp(f1, f2), lerp(b1, b2), coll, census


def run_one(arch: str, shape_name: str, multi_pod: bool, quiet: bool = False,
            overrides: dict | None = None, save: bool = True,
            exec_only: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)
    knobs = knobs_for(arch, shape_name, overrides)
    if shape.kind == "train":
        # microbatch global size must divide the fsdp axes (multi-pod has
        # 2× the data shards) — clamp M so every shard keeps ≥1 row
        fsdp_size = chips // mesh.shape["model"]
        max_m = max(1, shape.global_batch // fsdp_size)
        if knobs.microbatches > max_m:
            import dataclasses as _dc0
            knobs = _dc0.replace(knobs, microbatches=max_m)

    t0 = time.time()
    # Two views of the SAME program:
    #  * scan-form executable (scan_unroll=1) → memory_analysis: true peak
    #    residency of what a real run executes (loop buffers reused)
    #  * cost analysis — XLA counts while-loop bodies ONCE, and fully
    #    unrolling 32–81 layers is a multi-hour compile on this 1-core box,
    #    so we compile unrolled 1-period and 2-period variants of the same
    #    config and extrapolate linearly in the period count (layer stacks
    #    are homogeneous per period, so the slope is exact for FLOPs/bytes/
    #    per-layer collectives; embed/unembed/loss/optimizer live in the
    #    intercept).  Validated against a full unroll on llama3.2-1b
    #    (EXPERIMENTS.md §Dry-run) to <2%.
    import dataclasses as _dc

    exec_knobs = _dc.replace(knobs, scan_unroll=1)
    bundle_exec = build_step(cfg, shape, mesh, exec_knobs)
    with set_mesh(mesh):
        compiled_exec = bundle_exec.lower().compile()
    mem = compiled_exec.memory_analysis()
    bundle = bundle_exec

    if exec_only:
        # multi-pod pass: compile proof + memory only — roofline terms come
        # from the single-pod analysis compiles (§Roofline is single-pod)
        cost = compiled_exec.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        hlo = compiled_exec.as_text()
        coll = collective_stats(hlo)
        census = op_census(hlo)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll_bytes = float(coll["total"]["bytes"])
    else:
        flops, bytes_acc, coll, census = _extrapolated_cost(cfg, shape, mesh, knobs)
        coll_bytes = float(coll["total"]["bytes"])
    t1 = time.time()

    def _mem_attr(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips,
        "kind": bundle.meta.get("kind"),
        "knobs": dict(
            microbatches=knobs.microbatches, remat=knobs.remat,
            param_dtype=knobs.param_dtype, moment_dtype=knobs.moment_dtype,
            seq_shard_acts=knobs.seq_shard_acts,
        ),
        "compile_seconds": round(t1 - t0, 2),
        "exec_only": exec_only,
        # cost_analysis of the partitioned module = PER-DEVICE numbers
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "op_census": census,
        "memory": {
            "argument_bytes": _mem_attr("argument_size_in_bytes"),
            "output_bytes": _mem_attr("output_size_in_bytes"),
            "temp_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_bytes": _mem_attr("generated_code_size_in_bytes"),
            "alias_bytes": _mem_attr("alias_size_in_bytes"),
        },
        # roofline terms (seconds) — per chip
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        },
    }
    terms = result["roofline"]
    result["roofline"]["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)

    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{result['mesh']}"
        if overrides:
            tag += "_" + "_".join(f"{k}-{v}" for k, v in sorted(overrides.items()))
        (ARTIFACT_DIR / f"{tag}.json").write_text(json.dumps(result, indent=1))

    if not quiet:
        print(f"== {arch} × {shape_name} × {result['mesh']} ({bundle.meta.get('kind')}) ==")
        print(f"  compile: {result['compile_seconds']}s   knobs: {result['knobs']}")
        print(f"  memory_analysis: args={_fmt(result['memory']['argument_bytes'])} "
              f"out={_fmt(result['memory']['output_bytes'])} "
              f"temp={_fmt(result['memory']['temp_bytes'])}")
        print(f"  per-device: FLOPs={flops:.3e}  bytes={bytes_acc:.3e}  "
              f"collective_bytes={coll_bytes:.3e}")
        print(f"  roofline: compute={terms['compute_s']*1e3:.2f}ms  "
              f"memory={terms['memory_s']*1e3:.2f}ms  "
              f"collective={terms['collective_s']*1e3:.2f}ms  "
              f"→ {result['roofline']['bottleneck']}")
    return result


def _fmt(b):
    if b is None:
        return "n/a"
    for unit in ("B", "KiB", "MiB", "GiB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.2f}TiB"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all arch × shape pairs")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--exec-only", action="store_true",
                    help="skip the unrolled analysis compile (memory/compile proof only)")
    args = ap.parse_args()

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape_name in pairs:
        reason = skip_reason(arch, shape_name)
        if reason:
            print(f"-- SKIP {arch} × {shape_name}: {reason}")
            continue
        for mp in meshes:
            try:
                run_one(arch, shape_name, mp, quiet=args.quiet, exec_only=args.exec_only)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape_name, mp, repr(e)))
                print(f"!! FAIL {arch} × {shape_name} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nALL DRY-RUNS PASSED")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
