"""Federated training driver — the paper's experiment runner.

Runs any algorithm in {fedcm, fedavg, fedadam, scaffold, feddyn, mimelite}
on Dirichlet-partitioned synthetic classification (paper §6.1 scaled; see
EXPERIMENTS.md §Repro) or on a federated LM task where every client holds a
*different* Markov chain (natural heterogeneity).

Rounds between evaluations execute as ONE fused ``engine.run_rounds`` scan
(cohort sampling + minibatch draws on-device, state donated) — per-round
python dispatch only happens with ``--per-round``, kept for A/B timing
against the fused path (benchmarks/fused_rounds.py measures the gap).

    PYTHONPATH=src python -m repro.launch.fed_train --algo fedcm \
        --clients 100 --cohort 10 --rounds 100 --dirichlet 0.6
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier
from repro.utils.metrics import MetricLogger


def run_federated(
    cfg: FedConfig,
    dirichlet: float,
    *,
    dim: int = 32,
    n_classes: int = 10,
    n_train: int = 50_000,
    n_test: int = 10_000,
    batch_size: int = 50,
    hidden: int = 128,
    eval_every: int = 25,
    seed: int = 0,
    echo: bool = True,
    fused: bool = True,
):
    """Returns (final_test_acc, history MetricLogger)."""
    x_tr, y_tr, x_te, y_te = make_synthetic_classification(
        n_classes=n_classes, dim=dim, n_train=n_train, n_test=n_test, seed=seed
    )
    data = FederatedData(x_tr, y_tr, cfg.num_clients, dirichlet_alpha=dirichlet, seed=seed)
    model = mlp_classifier((dim, hidden, hidden, n_classes))
    loss_fn = classification_loss(model.apply)
    eng = FederatedEngine(cfg, loss_fn, batch_size=batch_size)
    state = eng.init(model.init(jax.random.PRNGKey(seed)), jax.random.PRNGKey(seed + 1))
    evaluate = make_eval_fn(model.apply)

    log = MetricLogger(
        ["round", "algo", "loss", "test_acc", "n_active", "mb_down", "mb_up"],
        echo=echo, echo_every=1,
    )
    x_te_j, y_te_j = jnp.asarray(x_te), jnp.asarray(y_te)
    acc = 0.0
    if fused:
        # eval_every rounds per jitted scan; metrics come back stacked and
        # we log the chunk's final round (same cadence as the --per-round path)
        r = 0
        while r < cfg.rounds:
            chunk = min(eval_every, cfg.rounds - r)
            state, ms = eng.run_rounds(state, data, chunk)
            r += chunk
            acc = evaluate(state.params, x_te_j, y_te_j)
            log.log(round=r, algo=cfg.algo, loss=round(float(ms.loss[-1]), 4),
                    test_acc=round(acc, 4), n_active=int(ms.n_active[-1]),
                    mb_down=round(float(ms.bytes_down[-1]) / 2**20, 2),
                    mb_up=round(float(ms.bytes_up[-1]) / 2**20, 2))
        return acc, log
    for r in range(cfg.rounds):
        state, m = eng.run_round(state, data)
        if (r + 1) % eval_every == 0 or r == cfg.rounds - 1:
            acc = evaluate(state.params, x_te_j, y_te_j)
            log.log(round=r + 1, algo=cfg.algo, loss=round(float(m.loss), 4),
                    test_acc=round(acc, 4), n_active=int(m.n_active),
                    mb_down=round(float(m.bytes_down) / 2**20, 2),
                    mb_up=round(float(m.bytes_up) / 2**20, 2))
    return acc, log


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--algo", default="fedcm",
                    choices=["fedcm", "fedavg", "fedadam", "scaffold", "feddyn", "mimelite"])
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--cohort", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--eta-l", type=float, default=0.1)
    ap.add_argument("--eta-g", type=float, default=1.0)
    ap.add_argument("--dirichlet", type=float, default=0.6,
                    help="label-skew concentration; inf = IID")
    ap.add_argument("--participation", default="bernoulli", choices=["fixed", "bernoulli"])
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--per-round", action="store_true",
                    help="dispatch each round separately (A/B against fused scan)")
    ap.add_argument("--fused-kernel", action="store_true",
                    help="route local steps through the Pallas fedcm_update kernel")
    args = ap.parse_args()

    cfg = FedConfig(
        algo=args.algo, num_clients=args.clients, cohort_size=args.cohort,
        local_steps=args.local_steps, alpha=args.alpha, eta_l=args.eta_l,
        eta_g=args.eta_g, participation=args.participation, rounds=args.rounds,
        seed=args.seed, use_fused_kernel=args.fused_kernel,
    )
    acc, _ = run_federated(cfg, args.dirichlet, eval_every=args.eval_every,
                           seed=args.seed, fused=not args.per_round)
    print(f"\n{args.algo}: final test accuracy = {acc:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
