"""Federated training driver — the paper's experiment runner.

Runs any REGISTERED algorithm (``repro.core.registry``; ``--list-algos``
prints each spec's state planes + kernel routing, ``--algo`` choices are
the registry itself) on Dirichlet-partitioned synthetic classification
(paper §6.1 scaled; see EXPERIMENTS.md §Repro) or on a federated LM task
where every client holds a *different* Markov chain (natural
heterogeneity).

Rounds between evaluations execute as ONE fused ``engine.run_rounds`` scan
(cohort sampling + minibatch draws on-device, state donated) — per-round
python dispatch only happens with ``--per-round``, kept for A/B timing
against the fused path (benchmarks/fused_rounds.py measures the gap).

With ``--pipeline-depth D`` / ``--staleness S`` (or ``--async``) the run
switches to the overlapping-cohort engine ``run_rounds_async``: ONE
pipelined scan for the whole run, with evaluation device-resident INSIDE
the scan at the ``--eval-every`` cadence — zero host round-trips between
round 0 and the final metrics fetch.

``--population-store host`` switches to the out-of-core population engine
(``run_rounds_store``): per-client state lives in a sparse host store
(gathered/scattered per cohort as ``(C, P)`` blocks) and client shards
stream on demand (``repro.data.population``), so ``--num-clients 1000000``
runs without any ``(N, ·)`` device array.  ``--availability`` picks the
cohort-sampling process (zipf traffic skew, time-of-day sinusoid);
``--dropout-rate`` adds straggler dropout.  Both work on the resident
engine too.

``--dryrun`` resolves the full config, writes it (plus the engine's
payload accounting) to ``benchmarks/artifacts/fed_train_dryrun.json``, and
exits without training — the artifact is how CLI-flag wiring is asserted
in tests (a flag that never reaches FedConfig, like the PR-2
``use_flat_plane`` gap, shows up as a wrong resolved value here).

    PYTHONPATH=src python -m repro.launch.fed_train --algo fedcm \
        --clients 100 --cohort 10 --rounds 100 --dirichlet 0.6
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from pathlib import Path

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_fed_run, save_fed_run
from repro.configs.base import CompressionConfig, FaultConfig, FedConfig
from repro.core import (
    FederatedEngine,
    RoundMetrics,
    describe_algorithm,
    get_algorithm,
    list_algorithms,
    make_eval_fn,
)
from repro.core.engine import metrics_to_host
from repro.fleet.telemetry import FAULT_COUNTERS, ROUND_FIELDS, TELEMETRY_SCHEMA
from repro.data import FederatedData, StreamingClientData, make_synthetic_classification
from repro.data.population import AVAILABILITY_PROCESSES, POPULATION_STORES
from repro.models.small import classification_loss, mlp_classifier
from repro.utils.metrics import MetricLogger

DRYRUN_ARTIFACT = (
    Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"
    / "fed_train_dryrun.json"
)


def run_federated(
    cfg: FedConfig,
    dirichlet: float,
    *,
    dim: int = 32,
    n_classes: int = 10,
    n_train: int = 50_000,
    n_test: int = 10_000,
    batch_size: int = 50,
    hidden: int = 128,
    eval_every: int = 25,
    seed: int = 0,
    echo: bool = True,
    fused: bool = True,
    async_pipeline: bool = False,
    ckpt_every: int = 0,
    ckpt_dir: str = "",
    resume: bool = False,
    die_after: int = 0,
    serve: bool = False,
    health_port: int = 0,
    round_deadline_s: float = 120.0,
    telemetry_path: str = "",
    publish_retain: int = 4,
):
    """Returns (final_test_acc, history MetricLogger).

    ``ckpt_every`` > 0 publishes an atomic whole-run snapshot (FedState +
    host population store, one ``save_fed_run`` file) every N rounds on
    the fused path; ``resume`` restores the latest snapshot from
    ``ckpt_dir`` and CONTINUES the trajectory bitwise (same fused-scan
    chunking relative to absolute round).  ``die_after`` R kills the
    process with exit code 75 right after the first snapshot at round
    ≥ R — the chaos half of the kill-and-resume CI smoke.

    ``serve`` turns the run into the round-to-serving fleet loop
    (``repro.fleet``): rounds keep the main thread, a serving thread
    continuously decodes against the latest published params (published
    at every ``ckpt_every`` boundary, hot-swapped atomically between
    decode steps), a health endpoint reports round liveness, and an
    append-only telemetry JSONL records one row per round.  Fleet is
    observation-only — the training trajectory is bit-identical with or
    without it."""
    if cfg.population_store == "host":
        # out-of-core path: no (N, n_per, …) device stack exists — shards
        # regenerate on demand per sampled cohort (label skew replaces the
        # Dirichlet partition; --dirichlet is a no-op here)
        data = StreamingClientData(cfg.num_clients, dim=dim,
                                   n_classes=n_classes, seed=seed)
        x_te, y_te = data.test_set(min(n_test, 2_000))
    else:
        x_tr, y_tr, x_te, y_te = make_synthetic_classification(
            n_classes=n_classes, dim=dim, n_train=n_train, n_test=n_test, seed=seed
        )
        data = FederatedData(x_tr, y_tr, cfg.num_clients, dirichlet_alpha=dirichlet, seed=seed)
    model = mlp_classifier((dim, hidden, hidden, n_classes))
    loss_fn = classification_loss(model.apply)
    eng = FederatedEngine(cfg, loss_fn, batch_size=batch_size)
    state = eng.init(model.init(jax.random.PRNGKey(seed)), jax.random.PRNGKey(seed + 1))
    evaluate = make_eval_fn(model.apply)

    log = MetricLogger(
        ["round", "algo", "loss", "test_acc", "n_active", "mb_down", "mb_up",
         "dropped", "quar", "retries", "qskip"],
        echo=echo, echo_every=1,
    )
    x_te_j, y_te_j = jnp.asarray(x_te), jnp.asarray(y_te)
    acc = 0.0
    if async_pipeline:
        if cfg.population_store == "host":
            # store-backed async is a host loop (gathers/scatters between
            # rounds); in-scan eval doesn't exist — evaluate once at the end
            state, ms = eng.run_rounds_async(state, data, cfg.rounds)
            acc = evaluate(state.params, x_te_j, y_te_j)
            log.log(round=cfg.rounds, algo=cfg.algo,
                    loss=round(float(ms.loss[-1]), 4),
                    test_acc=round(acc, 4), n_active=int(ms.n_active[-1]),
                    mb_down=round(float(ms.bytes_down[-1]) / 2**20, 2),
                    mb_up=round(float(ms.bytes_up[-1]) / 2**20, 2))
            return acc, log
        # the WHOLE run — cohort overlap, minibatch draws, eval — is one
        # jitted pipelined scan; eval accuracies come back in the stacked
        # metrics (−1.0 off-cadence)
        state, ms = eng.run_rounds_async(
            state, data, cfg.rounds,
            eval_every=eval_every, eval_data=(x_te_j, y_te_j),
            predict_fn=model.apply,
        )
        accs = np.asarray(ms.eval_acc)
        for r in np.flatnonzero(accs >= 0.0):
            acc = float(accs[r])
            log.log(round=int(r) + 1, algo=cfg.algo,
                    loss=round(float(ms.loss[r]), 4),
                    test_acc=round(acc, 4), n_active=int(ms.n_active[r]),
                    mb_down=round(float(ms.bytes_down[r]) / 2**20, 2),
                    mb_up=round(float(ms.bytes_up[r]) / 2**20, 2))
        if (cfg.pipeline_depth > 1 or not np.any(accs >= 0.0)
                or (cfg.rounds % eval_every) != 0):
            # one host-side eval of the RETURNED params: the final round
            # fell off the eval cadence, or the pipeline drained after the
            # last in-scan eval (which sees pre-drain params — the
            # returned state additionally folds the ≤depth−1 cohorts
            # still in flight)
            acc = evaluate(state.params, x_te_j, y_te_j)
        return acc, log
    if fused:
        # eval_every rounds per jitted scan; metrics come back stacked and
        # we log the chunk's final round (same cadence as the --per-round path)
        r = 0
        if resume:
            step = latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"--resume: no checkpoints in {ckpt_dir!r}")
            state, population, residuals, meta = load_fed_run(
                ckpt_dir, step, state, num_clients=cfg.num_clients
            )
            if population is not None and eng.population is not None:
                # restore INTO the engine's store, bypassing any chaos
                # wrapper (FaultyStore) so the restore itself cannot fail
                getattr(eng.population, "inner", eng.population)._rows = (
                    population._rows
                )
            if residuals is not None and eng.residual_population is not None:
                getattr(
                    eng.residual_population, "inner", eng.residual_population
                )._rows = residuals._rows
            r = int(meta["step"])
        fleet = None
        if serve:
            # the fleet loop: serving + health + telemetry threads around
            # the SAME chunk loop (observation-only — fleet never touches
            # FedState or the traced programs)
            from repro.fleet.driver import FleetDriver

            fleet = FleetDriver(
                ckpt_dir=ckpt_dir,
                telemetry_path=telemetry_path or None,
                retain=publish_retain,
                deadline_s=round_deadline_s,
                health_port=health_port,
                meta={"algo": cfg.algo, "rounds": cfg.rounds,
                      "num_clients": cfg.num_clients,
                      "cohort_size": cfg.cohort_size,
                      "ckpt_every": ckpt_every, "resumed_at": r},
            )
            # version 1 = the params entering the run, so the serving
            # thread never decodes against unpublished (random) weights
            fleet.publish(r, state.params)
            fleet.start_serving(
                model.apply, template=state.params,
                batch_x=x_te_j[: min(128, x_te_j.shape[0])],
            )
            print(f"fleet: serving + health at {fleet.health.url} "
                  f"(telemetry: {fleet.telemetry.path})")
        while r < cfg.rounds:
            chunk = min(eval_every, cfg.rounds - r)
            if ckpt_every > 0:
                # align scan chunks to snapshot boundaries so a resumed run
                # replays the SAME chunking relative to absolute round —
                # bitwise continuation needs identical scan programs
                nxt = ckpt_every * (r // ckpt_every + 1)
                chunk = min(chunk, nxt - r)
            t0 = time.perf_counter()
            state, ms = eng.run_rounds(state, data, chunk)
            # ONE host transfer per chunk for ALL metric consumers (log +
            # telemetry + fault counters) — REP003: never per round
            host = metrics_to_host(ms)
            dt = time.perf_counter() - t0
            r += chunk
            acc = evaluate(state.params, x_te_j, y_te_j)
            pub_version = None
            snapshot = ckpt_every > 0 and (r % ckpt_every == 0 or r >= cfg.rounds)
            if snapshot:
                pop = eng.population
                res = eng.residual_population
                save_fed_run(
                    ckpt_dir, r, state,
                    population=getattr(pop, "inner", pop) if pop is not None else None,
                    residuals=getattr(res, "inner", res) if res is not None else None,
                )
                if fleet is not None:
                    pub_version = fleet.publish(r, state.params)
            if fleet is not None:
                fleet.record_chunk(start_round=r - chunk, host=host,
                                   seconds=dt, eval_acc=acc,
                                   published_version=pub_version)
            log.log(round=r, algo=cfg.algo, loss=round(float(host["loss"][-1]), 4),
                    test_acc=round(acc, 4), n_active=int(host["n_active"][-1]),
                    mb_down=round(float(host["bytes_down"][-1]) / 2**20, 2),
                    mb_up=round(float(host["bytes_up"][-1]) / 2**20, 2),
                    dropped=int(host["n_dropped"].sum()) if "n_dropped" in host else None,
                    quar=int(host["n_quarantined"].sum()) if "n_quarantined" in host else None,
                    retries=int(host["n_retries"].sum()) if "n_retries" in host else None,
                    qskip=int(host["quorum_skipped"].sum()) if "quorum_skipped" in host else None)
            if snapshot and die_after > 0 and r >= die_after:
                # simulate preemption: no cleanup, no atexit — the
                # snapshot just published is all a resume may rely on
                # (the fleet telemetry rows above are already fsynced)
                os._exit(75)
        if fleet is not None:
            summary = fleet.stop()
            print(f"fleet: {summary.get('swaps', 0)} hot-swaps "
                  f"({summary.get('swaps_mid_session', 0)} under decode load) "
                  f"over {summary.get('steps', 0)} decode steps; "
                  f"health={summary.get('health_status')}")
        return acc, log
    for r in range(cfg.rounds):
        state, m = eng.run_round(state, data)
        if (r + 1) % eval_every == 0 or r == cfg.rounds - 1:
            host = metrics_to_host(m)  # one transfer for the whole row
            acc = evaluate(state.params, x_te_j, y_te_j)
            log.log(round=r + 1, algo=cfg.algo,
                    loss=round(float(host["loss"][-1]), 4),
                    test_acc=round(acc, 4), n_active=int(host["n_active"][-1]),
                    mb_down=round(float(host["bytes_down"][-1]) / 2**20, 2),
                    mb_up=round(float(host["bytes_up"][-1]) / 2**20, 2))
    return acc, log


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n:.0f} B"
        n /= 1024
    return f"{n:.1f} GiB"


def list_algos_text(dim: int = 32, hidden: int = 128, n_classes: int = 10,
                    compression: "CompressionConfig | None" = None) -> str:
    """One line per registered algorithm: state-plane requirements + kernel
    routing, rendered from the registry (the same ``describe_algorithm``
    rows the kernels/README.md table is generated from), plus the §4.2
    wire cost: per-client uplink bytes/round over the spec's wire planes
    for this driver's default model (abstract shapes only — nothing is
    materialized).  ``compression`` (the resolved ``--uplink-compress``)
    reprices the column through the SAME accounting the engine bills
    (``repro.core.compress.uplink_bytes_per_client``), so the table shows
    what the configured run would actually ship."""
    from repro.core.compress import uplink_bytes_per_client

    model = mlp_classifier((dim, hidden, hidden, n_classes))
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    P = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
    rows = []
    for n in list_algorithms():
        spec = get_algorithm(n)
        r = describe_algorithm(spec)
        up = uplink_bytes_per_client(
            compression, spec.wire_uplink_planes, P, P * 4
        )
        r["uplink bytes/round"] = f"{_fmt_bytes(up)}/client"
        rows.append(r)
    cols = ["algorithm", "local step", "server fold", "state planes",
            "uplink", "uplink bytes/round"]
    widths = {c: max(len(c), *(len(r[c]) for r in rows)) for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols)]
    lines += ["  ".join(r[c].ljust(widths[c]) for c in cols) for r in rows]
    wire = "f32 wire" if compression is None else f"{compression.kind} wire"
    lines.append(f"(P = {P:,} params: mlp {dim}-{hidden}-{hidden}-{n_classes}, {wire})")
    return "\n".join(lines)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    # choices come FROM the registry: a freshly-registered algorithm is
    # immediately runnable, and an unknown name errors with the registered
    # list (argparse renders the choices)
    ap.add_argument("--algo", default="fedcm", choices=list_algorithms())
    ap.add_argument("--list-algos", action="store_true",
                    help="print every registered algorithm (state-plane "
                         "requirements + kernel routing) and exit")
    ap.add_argument("--clients", "--num-clients", dest="clients",
                    type=int, default=100)
    ap.add_argument("--cohort", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--eta-l", type=float, default=0.1)
    ap.add_argument("--eta-g", type=float, default=1.0)
    ap.add_argument("--dirichlet", type=float, default=0.6,
                    help="label-skew concentration; inf = IID")
    ap.add_argument("--participation", default="bernoulli", choices=["fixed", "bernoulli"])
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--per-round", action="store_true",
                    help="dispatch each round separately (A/B against fused scan)")
    ap.add_argument("--fused-kernel", action="store_true",
                    help="route the flat-plane update phase through the Pallas "
                         "fed_direction/server_update kernels")
    ap.add_argument("--flat-plane", action=argparse.BooleanOptionalAction,
                    default=FedConfig.use_flat_plane,
                    help="carry the round state on the ravelled (P,) parameter "
                         "plane (--no-flat-plane keeps the per-leaf tree path)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="cohorts in flight (>1 switches to the async "
                         "overlapping-cohort engine; folds are depth-1 rounds stale)")
    ap.add_argument("--staleness", type=int, default=0,
                    help="rounds of momentum staleness the clients descend "
                         "against (>0 switches to the async engine)")
    ap.add_argument("--staleness-discount", type=float, default=1.0,
                    help="FedACG-style per-round-of-staleness fold weight γ")
    ap.add_argument("--async", dest="async_pipeline", action="store_true",
                    help="force the async engine even at depth 1 / staleness 0")
    ap.add_argument("--population-store", default="resident",
                    choices=list(POPULATION_STORES),
                    help="'host' offloads per-client state to an out-of-core "
                         "host store (gather/scatter per cohort; no (N, P) "
                         "device plane) and streams client shards on demand "
                         "— the N=1e6 path")
    ap.add_argument("--availability", default="uniform",
                    choices=list(AVAILABILITY_PROCESSES),
                    help="client availability process driving cohort "
                         "sampling (uniform keeps the legacy bitwise draw)")
    ap.add_argument("--zipf-exponent", type=float, default=1.1,
                    help="skew s of the zipf availability process (w_i ∝ (i+1)^-s)")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="per-round straggler probability: sampled clients "
                         "drop out of the cohort mask with this rate")
    ap.add_argument("--uplink-compress", default="none",
                    choices=["none", "int8", "bf16", "topk"],
                    help="wire-compress client uplinks (repro.core.compress): "
                         "stochastic-rounded int8 (+per-row f32 scale), "
                         "bf16, or top-k sparsification with error-feedback "
                         "residuals; 'none' keeps the f32 wire bitwise")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="fraction of plane coordinates top-k keeps "
                         "(only with --uplink-compress topk)")
    ap.add_argument("--cohort-shard", type=int, default=0,
                    help="shard the client axis over N devices (a "
                         "('clients',) mesh; each device runs C/N clients "
                         "end-to-end and the fold is a reduce-scatter). "
                         "Requires --fused-kernel; 0 = single-device")
    # ---- fault tolerance (ISSUE PR-7): faults are CONFIG DATA ----------
    fault = ap.add_argument_group(
        "fault injection / degradation",
        "any nonzero rate builds a FaultConfig (faults as pure config "
        "data, seeded and reproducible); quarantine of non-finite uplinks "
        "is always on when a FaultConfig is present")
    fault.add_argument("--fault-drop-rate", type=float, default=0.0,
                       help="per-client per-round uplink drop probability")
    fault.add_argument("--fault-corrupt-rate", type=float, default=0.0,
                       help="per-client per-round payload corruption probability")
    fault.add_argument("--fault-corrupt-mode", default="nan",
                       choices=["nan", "inf", "noise"],
                       help="corruption model: NaN/Inf plane fill, or scaled "
                            "bit-noise added to the delta plane")
    fault.add_argument("--fault-noise-scale", type=float, default=1.0,
                       help="noise corruption magnitude (x |leaf| stddev)")
    fault.add_argument("--fault-deadline", type=float, default=0.0,
                       help="straggler deadline (log-normal compute-time "
                            "model; >0 drops clients exceeding it)")
    fault.add_argument("--fault-store-failure-rate", type=float, default=0.0,
                       help="transient host-store gather/scatter failure "
                            "probability (engine retries with capped "
                            "exponential backoff)")
    fault.add_argument("--fault-seed", type=int, default=0,
                       help="seed of the fault PRNG chain (independent of "
                            "--seed; same seed => same fault realization)")
    fault.add_argument("--quarantine-norm-mult", type=float, default=0.0,
                       help=">0 additionally quarantines uplinks whose delta "
                            "norm exceeds mult x cohort median")
    ap.add_argument("--min-quorum", type=int, default=0,
                    help="skip the server fold (params carried unchanged) "
                         "when surviving clients fall below this count")
    ap.add_argument("--allow-empty-cohort", action="store_true",
                    help="let dropout empty the cohort entirely (the fold "
                         "degrades to a guarded no-op round) instead of the "
                         "legacy keep-first-client guard")
    # ---- preemption-safe runs ------------------------------------------
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="publish an atomic whole-run snapshot (FedState + "
                         "host population store) every N rounds; fused "
                         "path only")
    ap.add_argument("--ckpt-dir", default="",
                    help="snapshot directory (required with --ckpt-every / "
                         "--resume)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest snapshot from --ckpt-dir and "
                         "continue the trajectory bitwise")
    ap.add_argument("--die-after", type=int, default=0,
                    help="chaos: exit(75) right after the first snapshot at "
                         "round >= N (pair with --resume in a second "
                         "invocation)")
    # ---- round-to-serving fleet loop (repro.fleet) ---------------------
    fleet = ap.add_argument_group(
        "fleet serving",
        "--serve runs the round-to-serving loop: a serving thread "
        "continuously decodes against the latest published params "
        "(published at every --ckpt-every boundary, hot-swapped atomically "
        "between decode steps), /healthz-/metrics-/telemetry-tail health "
        "endpoint, append-only per-round telemetry JSONL")
    fleet.add_argument("--serve", action="store_true",
                       help="run serving + health + telemetry alongside "
                            "the fused round loop (needs --ckpt-every and "
                            "--ckpt-dir: publication rides the snapshot "
                            "cadence)")
    fleet.add_argument("--health-port", type=int, default=0,
                       help="health endpoint port (0 = ephemeral; the "
                            "bound port is printed at startup)")
    fleet.add_argument("--round-deadline", type=float, default=120.0,
                       help="/healthz liveness deadline: 503 when the last "
                            "completed round is older than this many seconds")
    fleet.add_argument("--telemetry", default="",
                       help="telemetry JSONL path (default "
                            "<ckpt-dir>/telemetry.jsonl)")
    fleet.add_argument("--publish-retain", type=int, default=4,
                       help="published model versions kept on disk (the "
                            "atomic publication ring; >= 2)")
    ap.add_argument("--dryrun", action="store_true",
                    help="resolve + persist the config artifact and exit "
                         "without training")
    return ap


def resolve_config(args: argparse.Namespace) -> FedConfig:
    """argv → FedConfig.  EVERY engine-relevant flag must be wired here —
    the dryrun artifact (and tests/test_fed_train_cli.py) assert the
    resolved values, which is what caught ``use_flat_plane`` silently
    falling back to its default."""
    # faults are pure config data: any nonzero rate materializes a
    # FaultConfig; all-defaults keeps fault=None — the engine's injection
    # code then never traces, preserving the bitwise-vs-pre-PR contract
    fault = None
    if (args.fault_drop_rate > 0.0 or args.fault_corrupt_rate > 0.0
            or args.fault_deadline > 0.0 or args.fault_store_failure_rate > 0.0
            or args.quarantine_norm_mult > 0.0):
        fault = FaultConfig(
            drop_rate=args.fault_drop_rate,
            deadline=args.fault_deadline,
            corrupt_rate=args.fault_corrupt_rate,
            corrupt_mode=args.fault_corrupt_mode,
            noise_scale=args.fault_noise_scale,
            store_failure_rate=args.fault_store_failure_rate,
            quarantine_norm_mult=args.quarantine_norm_mult,
            seed=args.fault_seed,
        )
    # compression is config data exactly like faults: "none" keeps
    # cfg.compression=None — the engine's wire-encode code then never
    # traces, preserving the bitwise-vs-pre-PR contract
    compression = None
    if args.uplink_compress != "none":
        compression = CompressionConfig(
            kind=args.uplink_compress, topk_frac=args.topk_frac,
            seed=args.seed,
        )
    return FedConfig(
        algo=args.algo, num_clients=args.clients, cohort_size=args.cohort,
        local_steps=args.local_steps, alpha=args.alpha, eta_l=args.eta_l,
        eta_g=args.eta_g, participation=args.participation, rounds=args.rounds,
        seed=args.seed, use_fused_kernel=args.fused_kernel,
        use_flat_plane=args.flat_plane,
        pipeline_depth=args.pipeline_depth, staleness=args.staleness,
        staleness_discount=args.staleness_discount,
        cohort_shard=args.cohort_shard,
        population_store=args.population_store,
        availability=args.availability,
        zipf_exponent=args.zipf_exponent,
        dropout_rate=args.dropout_rate,
        fault=fault,
        min_quorum=args.min_quorum,
        allow_empty_cohort=args.allow_empty_cohort,
        compression=compression,
    )


def _static_contracts(cfg: FedConfig, args: argparse.Namespace) -> dict:
    """One-path Layer-2 contract summary for the dryrun artifact
    (memoized inside repro.analysis.trace, so repeated in-process dryruns
    compile the tiny probe program once per path)."""
    from repro.analysis.trace import quick_contracts

    use_async = (args.async_pipeline or cfg.pipeline_depth > 1
                 or cfg.staleness > 0)
    return quick_contracts(use_async=use_async,
                           use_fused_kernel=cfg.use_fused_kernel)


def write_dryrun_artifact(cfg: FedConfig, args: argparse.Namespace) -> Path:
    """Persist the RESOLVED config (not the argv) so flag-wiring is
    asserted against what the engine will actually see."""
    # the wiring contract, asserted here so a --dryrun in CI trips on
    # regressions even before any test reads the artifact back
    assert cfg.use_flat_plane == args.flat_plane
    assert cfg.use_fused_kernel == args.fused_kernel
    assert cfg.pipeline_depth == args.pipeline_depth
    assert cfg.staleness == args.staleness
    assert cfg.cohort_shard == args.cohort_shard
    assert cfg.population_store == args.population_store
    assert cfg.availability == args.availability
    assert cfg.dropout_rate == args.dropout_rate
    assert cfg.min_quorum == args.min_quorum
    assert cfg.allow_empty_cohort == args.allow_empty_cohort
    if args.uplink_compress != "none":
        assert cfg.compression is not None
        assert cfg.compression.kind == args.uplink_compress
        assert cfg.compression.topk_frac == args.topk_frac
    else:
        assert cfg.compression is None
    if (args.fault_drop_rate > 0.0 or args.fault_corrupt_rate > 0.0
            or args.fault_deadline > 0.0 or args.fault_store_failure_rate > 0.0
            or args.quarantine_norm_mult > 0.0):
        assert cfg.fault is not None
        assert cfg.fault.drop_rate == args.fault_drop_rate
        assert cfg.fault.corrupt_rate == args.fault_corrupt_rate
        assert cfg.fault.corrupt_mode == args.fault_corrupt_mode
        assert cfg.fault.deadline == args.fault_deadline
        assert cfg.fault.store_failure_rate == args.fault_store_failure_rate
        assert cfg.fault.seed == args.fault_seed
    else:
        assert cfg.fault is None
    # telemetry/--dryrun agreement: every fault counter a telemetry row
    # carries must BE a RoundMetrics field (one rename breaks this loudly)
    assert set(FAULT_COUNTERS) <= set(RoundMetrics._fields), (
        set(FAULT_COUNTERS) - set(RoundMetrics._fields)
    )
    payload = {
        "resolved_config": dataclasses.asdict(cfg),
        "engine_mode": (
            "async_pipeline" if (args.async_pipeline or cfg.pipeline_depth > 1
                                 or cfg.staleness > 0)
            else ("per_round" if args.per_round else "fused_scan")
        ),
        "eval_every": args.eval_every,
        "dirichlet": args.dirichlet,
        "ckpt_every": args.ckpt_every,
        # fleet loop wiring: the serving/telemetry knobs the run would use
        "serve": {
            "enabled": args.serve,
            "health_port": args.health_port,
            "round_deadline_s": args.round_deadline,
            "telemetry_path": (args.telemetry
                               or (os.path.join(args.ckpt_dir, "telemetry.jsonl")
                                   if args.ckpt_dir else None)),
            "publish_retain": args.publish_retain,
            "publish_every": args.ckpt_every if args.serve else None,
        },
        # the telemetry row schema this build emits — asserted against
        # repro.fleet.telemetry so --dryrun and the rows a --serve run
        # writes can never disagree (RoundMetrics is the source of truth
        # for the counter names)
        "telemetry": {
            "schema": TELEMETRY_SCHEMA,
            "round_fields": list(ROUND_FIELDS),
            "fault_counters": list(FAULT_COUNTERS),
        },
        # the mesh the engine would build for cfg.cohort_shard — recorded
        # so CI (which runs dryrun single-device AND multi-device) asserts
        # the flag actually reaches the mesh constructor
        "cohort_mesh": (
            {"axes": ["clients"], "shape": [cfg.cohort_shard],
             "devices_visible": len(jax.devices())}
            if cfg.cohort_shard > 0 else None
        ),
        # Layer-2 contract state per rev (repro.analysis.trace): the
        # resolved execution path's tiny program is lowered and checked —
        # donation aliased, transfer-guard clean, exactly-once tracing
        "static_contracts": _static_contracts(cfg, args),
    }
    DRYRUN_ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
    DRYRUN_ARTIFACT.write_text(json.dumps(payload, indent=1))
    return DRYRUN_ARTIFACT


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.list_algos:
        comp = (None if args.uplink_compress == "none" else
                CompressionConfig(kind=args.uplink_compress,
                                  topk_frac=args.topk_frac, seed=args.seed))
        print(list_algos_text(compression=comp))
        return 0
    use_async = args.async_pipeline or args.pipeline_depth > 1 or args.staleness > 0
    if args.per_round and use_async:
        ap.error("--per-round dispatches one round per jit call; the async "
                 "pipelined engine is a single fused program — drop one of "
                 "--per-round / --async / --pipeline-depth / --staleness")
    if args.cohort_shard > 0 and not args.fused_kernel:
        ap.error("--cohort-shard rides the flat+kernel path (clients emit "
                 "(C, P) planes, the fold is the scattered server kernel) "
                 "— add --fused-kernel")
    if args.cohort_shard > 0 and not args.flat_plane:
        ap.error("--cohort-shard shards the flat (C, P) uplink planes — "
                 "drop --no-flat-plane")
    if args.population_store == "host" and not args.flat_plane:
        ap.error("--population-store host gathers/scatters flat (C, P) "
                 "state rows — drop --no-flat-plane")
    if args.population_store == "host" and args.cohort_shard > 0:
        ap.error("--population-store host is a single-device host loop; "
                 "it does not compose with --cohort-shard yet")
    if args.ckpt_every > 0 and use_async:
        ap.error("--ckpt-every snapshots between fused-scan chunks; the "
                 "async pipelined engine is one uninterruptible scan — "
                 "drop --async / --pipeline-depth / --staleness")
    if args.ckpt_every > 0 and args.per_round:
        ap.error("--ckpt-every rides the fused chunk loop — drop --per-round")
    if (args.ckpt_every > 0 or args.resume) and not args.ckpt_dir:
        ap.error("--ckpt-every / --resume need --ckpt-dir")
    if args.die_after > 0 and args.ckpt_every <= 0:
        ap.error("--die-after kills AFTER a snapshot — add --ckpt-every")
    if args.resume and args.ckpt_every <= 0:
        ap.error("--resume continues a snapshotted run — add --ckpt-every")
    if args.serve and args.ckpt_every <= 0:
        # (transitively this also excludes --per-round and the async
        # engine: both conflict with --ckpt-every above)
        ap.error("--serve publishes at snapshot boundaries — add "
                 "--ckpt-every N --ckpt-dir DIR")
    if args.serve and not args.ckpt_dir:
        ap.error("--serve needs --ckpt-dir (publisher + telemetry live "
                 "under it)")
    if args.publish_retain < 2:
        ap.error("--publish-retain must be >= 2: the publication ring must "
                 "outlive a reader's just-resolved version")
    cfg = resolve_config(args)
    if args.dryrun:
        path = write_dryrun_artifact(cfg, args)
        print(f"dryrun: resolved config written to {path}")
        return 0
    acc, _ = run_federated(cfg, args.dirichlet, eval_every=args.eval_every,
                           seed=args.seed, fused=not args.per_round,
                           async_pipeline=use_async,
                           ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                           resume=args.resume, die_after=args.die_after,
                           serve=args.serve, health_port=args.health_port,
                           round_deadline_s=args.round_deadline,
                           telemetry_path=args.telemetry,
                           publish_retain=args.publish_retain)
    print(f"\n{args.algo}: final test accuracy = {acc:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
