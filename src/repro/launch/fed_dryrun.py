import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""FedCM round-step dry-run — §Perf hillclimb C (the paper's technique).

Lowers ONE full federated round (Algorithm 2) of a llama3-family LM on the
production mesh: cohort-parallel over the "data" axis, each client's model
tensor-sharded over "model", FSDP parameter storage.  The broadcast of
(x_t, Δ_t) and the Δ-aggregation — the paper's server/client messages —
become XLA collectives whose bytes this dry-run measures.

A fixed 4-layer depth keeps the per-layer compute small so the ROUND
structure (momentum gathers, delta reduction, server update) dominates the
measurement — that structure is what FedCM adds over FedAvg and what the
hillclimb optimizes.  All scans (K local steps, layers, cohort vmap) are
unrolled for honest cost analysis.

    PYTHONPATH=src python -m repro.launch.fed_dryrun [--algo fedavg]
        [--momentum-dtype bfloat16] [--cohort 16] [--k 2] [--variant tag]
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import FedConfig, get_config
from repro.core.algorithms import get_algorithm, server_init
from repro.core.engine import FederatedEngine, FedState
from repro.launch.hlo_stats import collective_stats, op_census
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, n_chips
from repro.launch.steps import _ns
from repro.models import build_model
from repro.sharding.rules import param_specs
from repro.utils.compat import set_mesh

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun_fed"

N_LAYERS = 4
BATCH = 8
SEQ = 1024


def _tensor_sharded_reason(spec_tree) -> "str | None":
    """Why the flat plane cannot serve this lowering, or None if it can.

    The flat engine's single (P,) concatenate is only free when every leaf
    is replicated along non-cohort axes; a leaf partitioned over the
    tensor-parallel "model" axis would have to be all-gathered into the
    plane every round.
    """
    leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    for spec in leaves:
        for part in spec:
            names = part if isinstance(part, (tuple, list)) else (part,)
            if "model" in names:
                return ("params are tensor-sharded over the 'model' axis — "
                        "a flat (P,) concatenate would all-gather them; "
                        "using the per-leaf tree path")
    return None


def build_and_lower(
    mesh,
    *,
    algo: str = "fedcm",
    cohort: int = 16,
    local_steps: int = 2,
    momentum_dtype: str = "float32",
    param_dtype: str = "float32",
    aggregate_dtype: str = "float32",
):
    base = get_config("llama3.2-1b")
    cfg = dataclasses.replace(base, n_layers=N_LAYERS, name="llama3-fedround",
                              param_dtype=param_dtype)
    model = build_model(cfg)

    def loss_fn(params, batch):
        loss, _ = model.loss_fn(params, batch, scan_unroll=64)
        return loss

    p_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    # flat plane vs tree path is decided by the LOWERING, not hard-coded:
    # the flat engine concatenates every leaf into one (P,) buffer, which
    # for tensor-sharded ("model"-axis) leaves would force all-gathers of
    # the sharded dimensions — so only then fall back to the per-leaf tree
    # path, and say so.  The fallback is PER-AXIS: losing the flat plane
    # to "model"-sharded params does NOT surrender cohort parallelism —
    # the tree path keeps the client axis sharded over "data" via the
    # engine's cohort-axis sharding constraints (client_sharding below)
    # plus the batch in_shardings.
    probe_specs = param_specs(p_sds, cfg, mesh)
    flat_fallback_reason = _tensor_sharded_reason(probe_specs)
    use_flat = flat_fallback_reason is None
    if not use_flat:
        print(f"fed_dryrun: use_flat_plane=False ({flat_fallback_reason}; "
              f"cohort axis stays sharded over 'data')")

    fed = FedConfig(
        algo=algo, num_clients=4096, cohort_size=cohort, local_steps=local_steps,
        alpha=0.1, eta_l=0.05, eta_g=1.0, participation="fixed",
        weight_decay=1e-4, momentum_dtype=momentum_dtype,
        aggregate_dtype=aggregate_dtype,
        use_flat_plane=use_flat,
    )
    eng = FederatedEngine(
        fed, loss_fn,
        # cohort-axis sharding survives the flat-plane fallback: pin the
        # leading axis of every cohort-stacked array to the "data" axis
        client_sharding=_ns(mesh, P("data")),
    )
    eng.analysis_unroll = True
    pd = jnp.dtype(param_dtype)
    p_sds = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, pd)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, p_sds
    )
    # state planes derive from the registered spec's flags — a spec without
    # a second moment never allocates (or shards) the extra plane
    algo_spec = get_algorithm(algo)
    srv_sds = jax.eval_shape(lambda: server_init(
        p_sds, momentum_dtype,
        needs_second_moment=algo_spec.needs_second_moment))
    state_sds = FedState(
        params=p_sds, server=srv_sds, client_states=None,
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    batches_sds = {
        "tokens": jax.ShapeDtypeStruct((cohort, local_steps, BATCH, SEQ), jnp.int32),
        "labels": jax.ShapeDtypeStruct((cohort, local_steps, BATCH, SEQ), jnp.int32),
    }
    ids_sds = jax.ShapeDtypeStruct((cohort,), jnp.int32)
    mask_sds = jax.ShapeDtypeStruct((cohort,), jnp.bool_)
    full_sds = jax.tree_util.tree_map(lambda b: jax.ShapeDtypeStruct(
        (b.shape[0], *b.shape[2:]), b.dtype), batches_sds)

    p_spec = param_specs(p_sds, cfg, mesh)
    srv_spec = type(srv_sds)(
        momentum=p_spec,
        second_moment=p_spec if srv_sds.second_moment is not None else None,
        round=P(),
    )
    state_spec = FedState(params=p_spec, server=srv_spec, client_states=None, rng=P())
    batch_spec = jax.tree_util.tree_map(
        lambda _: P("data", None, None, None), batches_sds
    )
    full_spec = jax.tree_util.tree_map(lambda _: P("data", None, None), full_sds)

    metrics_spec = jax.tree_util.tree_map(lambda _: P(), {
        "loss": 0, "n_active": 0, "delta_norm": 0, "momentum_norm": 0,
        "eta_l": 0, "bytes_down": 0, "bytes_up": 0, "n_clipped": 0,
        "n_dropped": 0, "n_quarantined": 0, "n_retries": 0,
        "quorum_skipped": 0})
    from repro.core.engine import RoundMetrics
    fn = jax.jit(
        eng._round_step_impl,
        in_shardings=(
            _ns(mesh, state_spec), _ns(mesh, batch_spec),
            _ns(mesh, P()), _ns(mesh, P()), _ns(mesh, full_spec),
        ),
        # FSDP out_shardings: the cohort-mean Δ aggregation then lowers to
        # reduce-scatter instead of all-reduce (hillclimb C iteration 2)
        out_shardings=(
            _ns(mesh, state_spec),
            _ns(mesh, RoundMetrics(**metrics_spec)),
        ),
        donate_argnums=(0,),
    )
    with set_mesh(mesh):
        lowered = fn.lower(state_sds, batches_sds, ids_sds, mask_sds, full_sds)
        compiled = lowered.compile()
    return compiled, cfg, fed, flat_fallback_reason


def run(variant: str, *, algo="fedcm", cohort=16, local_steps=2,
        momentum_dtype="float32", param_dtype="float32",
        aggregate_dtype="float32", multi_pod=False, quiet=False, save=True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    compiled, cfg, fed, flat_reason = build_and_lower(
        mesh, algo=algo, cohort=cohort, local_steps=local_steps,
        momentum_dtype=momentum_dtype, param_dtype=param_dtype,
        aggregate_dtype=aggregate_dtype,
    )
    t1 = time.time()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_bytes = float(coll["total"]["bytes"])
    result = {
        "variant": variant,
        "algo": algo,
        "cohort": cohort,
        "local_steps": local_steps,
        "momentum_dtype": momentum_dtype,
        "param_dtype": param_dtype,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": n_chips(mesh),
        # the RESOLVED mesh + which axis carries the cohort: the flat-plane
        # fallback is per-axis (tensor-sharded params disable only the
        # (P,) plane; cohort parallelism stays on "data")
        "resolved_mesh": {
            "axes": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        },
        "cohort_axis": "data",
        "cohort_parallelism": "gspmd-constraint",
        "use_flat_plane": fed.use_flat_plane,
        "flat_fallback_reason": flat_reason,
        "compile_seconds": round(t1 - t0, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "memory_temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0) or 0),
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        },
    }
    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        (ARTIFACT_DIR / f"fedround_{variant}.json").write_text(json.dumps(result, indent=1))
    if not quiet:
        r = result["roofline"]
        print(f"== fed round [{variant}] algo={algo} cohort={cohort} K={local_steps} "
              f"mdtype={momentum_dtype} ==")
        print(f"  compile {result['compile_seconds']}s  temp={result['memory_temp_bytes']/2**30:.2f}GiB")
        print(f"  FLOPs={flops:.3e} bytes={bytes_acc:.3e} coll={coll_bytes:.3e}")
        print(f"  roofline: compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms")
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--algo", default="fedcm")
    ap.add_argument("--cohort", type=int, default=16)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--momentum-dtype", default="float32")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    run(args.variant, algo=args.algo, cohort=args.cohort, local_steps=args.k,
        momentum_dtype=args.momentum_dtype, param_dtype=args.param_dtype,
        multi_pod=args.multi_pod)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
