"""Post-SPMD HLO introspection: collective bytes + op census.

``collective_stats(compiled.as_text())`` sums the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the partitioned module (cost_analysis does not report
collectives — this is the roofline's third term).  Result-shape bytes are
the per-device payload entering the interconnect; ring-algorithm hop
inflation is applied by the roofline model, not here.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = bf16[1,2,3]{...} all-gather(...)` / tuple results
#   `%x = (f32[8,128], f32[8,128]) all-reduce(...)`
_OP_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {"count": n, "bytes": result_bytes}} + "total"."""
    out: Dict[str, Dict[str, float]] = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        # async pairs (-start/-done) would double count: the regex strips the
        # suffix, so count every match but skip "-done(" lines.
        start = m.start()
        line_end = hlo_text.find("(", m.end() - 1)
        window = hlo_text[m.start(): m.end()]
        if "-done(" in hlo_text[m.start(): m.end() + 8]:
            continue
        b = _shape_bytes(m.group("shapes"))
        out[op]["count"] += 1
        out[op]["bytes"] += b
    total = {
        "count": sum(v["count"] for v in out.values()),
        "bytes": sum(v["bytes"] for v in out.values()),
    }
    out["total"] = total
    return out


def op_census(hlo_text: str, top: int = 12) -> Dict[str, int]:
    """Frequency of HLO op kinds — used to spot remat recompute blowups."""
    ops = re.findall(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+([a-z0-9-]+)\(", hlo_text)
    census: Dict[str, int] = {}
    for o in ops:
        census[o] = census.get(o, 0) + 1
    return dict(sorted(census.items(), key=lambda kv: -kv[1])[:top])
