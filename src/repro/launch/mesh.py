"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS *before* first init).
"""
from __future__ import annotations

import jax

from repro.utils.compat import device_mesh, make_mesh

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)  # 256 chips
MULTI_POD_SHAPE = (2, 16, 16)  # 2 pods × 256 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_mesh((data, model), ("data", "model"))


def make_cohort_mesh(n_shards: int = 0):
    """1-D ``("clients",)`` mesh over the first ``n_shards`` devices — the
    cohort-parallel engine's axis (each device owns C/n_shards clients
    end-to-end).  ``n_shards=0`` takes every visible device.  Real
    multi-host: initialize ``jax.distributed`` first and the same call
    spans hosts; CI emulates with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    devs = jax.devices()
    n = len(devs) if n_shards in (0, None) else n_shards
    if n > len(devs):
        raise ValueError(
            f"cohort mesh wants {n} devices but only {len(devs)} are visible "
            f"(emulate with XLA_FLAGS=--xla_force_host_platform_device_count={n})"
        )
    return device_mesh(devs[:n], ("clients",))


def n_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
