"""Step builders: the jit-able train / prefill / serve programs + shardings.

Everything the dry-run lowers and the drivers execute comes from here, so
the compiled artifact measured by the roofline IS the program a real run
would execute.

``build_step(cfg, shape, mesh, knobs)`` dispatches on ``shape.kind``:

* train   → ``train_step(params, opt_state, batch)`` — loss → grads (with
  optional microbatch grad accumulation) → clip → AdamW → new params.
* prefill → ``prefill_step(params, batch)`` — forward, emit last-token
  logits + a *filled* KV/SSM cache (true prefill, not a logits-only pass).
* decode  → ``serve_step(params, token, cache, pos)`` — one token against a
  seq_len-deep cache, greedy next token.

Knobs (per-arch adaptation lives in launch.dryrun.ARCH_KNOBS):
  microbatches     — grad-accumulation chunks of the global batch
  remat            — "none" | "full" | "dots"
  param_dtype      — storage dtype for weights (bf16 on the TPU target)
  moment_dtype     — AdamW moment dtype (bf16 halves optimizer HBM)
  seq_shard_acts   — shard the residual stream's sequence dim over "model"
                     between layers (sequence parallelism; §Perf lever)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import build_model
from repro.models.layers import ParallelContext
from repro.optim.optimizers import adamw, clip_by_global_norm
from repro.sharding.rules import batch_specs, cache_specs, param_specs
from repro.utils.trees import tree_add


@dataclass(frozen=True)
class Knobs:
    microbatches: int = 1
    remat: str = "full"
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    seq_shard_acts: bool = False
    learning_rate: float = 3e-4
    grad_clip: float = 1.0
    use_kernels: bool = False
    scan_unroll: int = 1  # dry-run: fully unroll layer scans so
                          # cost_analysis counts every trip
    serve_params: str = "fsdp"  # "fsdp" | "replicated" — decode param layout
                                # (replicated = TP-only; §Perf hillclimb B)
    grad_accum_dtype: str = "float32"  # microbatch grad accumulator; bf16
                                       # halves the two biggest train buffers
                                       # for 100B+ models


@dataclass
class StepBundle:
    name: str
    fn: Callable
    args_sds: Tuple[Any, ...]  # ShapeDtypeStructs, positional
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any] = field(default_factory=dict)

    def lower(self):
        fn = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        return fn.lower(*self.args_sds)


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------


def make_parallel(mesh, global_batch: int) -> ParallelContext:
    names = mesh.axis_names
    model_axis = "model"
    data_axes = tuple(n for n in names if n != model_axis)
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    if global_batch % dsize != 0:
        data_axes = ()  # unshardable batch (long_500k B=1): replicate acts
    return ParallelContext(mesh=mesh, data_axes=data_axes, model_axis=model_axis)


def _ns(mesh, spec_tree):
    is_p = lambda x: isinstance(x, P)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=is_p
    )


def _params_sds(model, cfg: ModelConfig, param_dtype: str):
    sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    dt = jnp.dtype(param_dtype)

    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dt)
        return x

    return jax.tree_util.tree_map(cast, sds)


# ----------------------------------------------------------------------
# train
# ----------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh, knobs: Knobs) -> StepBundle:
    model = build_model(cfg)
    parallel = make_parallel(mesh, shape.global_batch)
    opt = adamw(knobs.learning_rate, moment_dtype=jnp.dtype(knobs.moment_dtype))

    M = knobs.microbatches
    assert shape.global_batch % M == 0, (shape.global_batch, M)

    def loss_fn(params, mb):
        loss, metrics = model.loss_fn(
            params, mb, parallel=parallel, remat=knobs.remat,
            use_kernels=knobs.use_kernels, scan_unroll=knobs.scan_unroll,
        )
        return loss, metrics

    def train_step(params, opt_state, batch):
        if M == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            # grad accumulation: scan over M microbatches, f32 accumulator
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch
            )

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + l), None

            acc_dt = jnp.dtype(knobs.grad_accum_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )
            # analysis form (scan_unroll>1): unroll so cost_analysis counts
            # every microbatch trip, mirroring the layer-scan unroll
            (grads, loss_sum), _ = jax.lax.scan(
                acc_step, (g0, jnp.float32(0.0)), micro,
                unroll=M if knobs.scan_unroll > 1 else 1,
            )
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
            loss = loss_sum / M
            metrics = {"xent": loss, "aux": jnp.float32(0.0)}

        grads, gnorm = clip_by_global_norm(grads, knobs.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
            params, updates,
        )
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, out_metrics

    p_sds = _params_sds(model, cfg, knobs.param_dtype)
    o_sds = jax.eval_shape(opt.init, p_sds)
    b_sds = model.input_specs(shape)

    p_spec = param_specs(p_sds, cfg, mesh)
    o_spec = (P(), p_spec, p_spec)
    b_spec = batch_specs(cfg, shape, mesh)
    m_spec = {"loss": P(), "grad_norm": P()}

    return StepBundle(
        name=f"train:{cfg.name}:{shape.name}",
        fn=train_step,
        args_sds=(p_sds, o_sds, b_sds),
        in_shardings=(_ns(mesh, p_spec), _ns(mesh, o_spec), _ns(mesh, b_spec)),
        out_shardings=(_ns(mesh, p_spec), _ns(mesh, o_spec), _ns(mesh, m_spec)),
        donate_argnums=(0, 1),
        meta=dict(kind="train", microbatches=M, remat=knobs.remat),
    )


# ----------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh, knobs: Knobs) -> StepBundle:
    model = build_model(cfg)
    parallel = make_parallel(mesh, shape.global_batch)

    p_sds = _params_sds(model, cfg, knobs.param_dtype)
    p_spec = param_specs(p_sds, cfg, mesh)
    b_sds = model.input_specs(shape)
    b_spec = batch_specs(cfg, shape, mesh)
    fsdp = tuple(n for n in mesh.axis_names if n != "model")
    b_ax = fsdp if shape.global_batch % _prod(mesh, fsdp) == 0 else None

    if cfg.is_encoder_decoder:
        # prefill = encode the source + precompute per-layer cross-K/V
        from repro.models import encdec

        def prefill_step(params, batch):
            enc_out = encdec.encode(
                params, batch["src_embeds"], cfg=cfg, parallel=parallel,
                remat=knobs.remat, scan_unroll=knobs.scan_unroll,
            )
            cross = jax.vmap(lambda lp: encdec.encode_kv(lp["cross_attn"], enc_out, cfg=cfg))(
                params["dec"]
            )
            return enc_out, cross

        hd = cfg.resolved_head_dim
        B, S = shape.global_batch, shape.seq_len
        cross_spec = {"k": P(None, b_ax, "model", None, None), "v": P(None, b_ax, "model", None, None)}
        out_spec = (P(b_ax, None, None), cross_spec)
        args = (p_sds, {"src_embeds": b_sds["src_embeds"]})
        in_sh = (_ns(mesh, p_spec), _ns(mesh, {"src_embeds": b_spec["src_embeds"]}))
        return StepBundle(
            name=f"prefill:{cfg.name}:{shape.name}",
            fn=prefill_step,
            args_sds=args,
            in_shardings=in_sh,
            out_shardings=_ns(mesh, out_spec),
            donate_argnums=(),
            meta=dict(kind="prefill"),
        )

    def prefill_step(params, batch):
        logits, cache, _ = model.apply(
            params, batch["tokens"], parallel=parallel, kv_spec=None,
            remat=knobs.remat, return_cache=True, use_kernels=knobs.use_kernels,
            scan_unroll=knobs.scan_unroll,
        )
        return logits[:, -1], cache

    cache_sds = jax.eval_shape(
        lambda p, b: prefill_step(p, b)[1], p_sds, b_sds
    )
    c_spec = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _prefill_cache_spec(path, leaf, b_ax),
        cache_sds,
    )
    out_spec = (P(b_ax, None), c_spec)
    batch_in = {"tokens": b_sds["tokens"]}
    batch_sp = {"tokens": b_spec["tokens"]}
    return StepBundle(
        name=f"prefill:{cfg.name}:{shape.name}",
        fn=prefill_step,
        args_sds=(p_sds, batch_in),
        in_shardings=(_ns(mesh, p_spec), _ns(mesh, batch_sp)),
        out_shardings=_ns(mesh, out_spec),
        donate_argnums=(),
        meta=dict(kind="prefill"),
    )


def _prefill_cache_spec(path, leaf, b_ax):
    from repro.sharding.rules import _key_of

    key = _key_of(path)
    nd = len(leaf.shape)
    if key in ("k", "v") and nd == 5:  # (n, B, S, Hkv, hd)
        return P(None, b_ax, "model", None, None)
    if key == "ssm" and nd == 5:
        return P(None, b_ax, None, None, None)
    if key == "conv" and nd == 4:
        return P(None, b_ax, None, None)
    return P(*((None,) * nd))


def _prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ----------------------------------------------------------------------
# decode / serve
# ----------------------------------------------------------------------


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh, knobs: Knobs) -> StepBundle:
    model = build_model(cfg)
    parallel = make_parallel(mesh, shape.global_batch)
    c_spec = cache_specs(cfg, shape, mesh)
    kv_leaf_spec = _decode_kv_spec(c_spec)
    param_mode = "serve" if knobs.serve_params == "replicated" else "train"

    def serve_step(params, token, cache, pos):
        logits, new_cache = model.decode_step(
            params, token, cache, pos, parallel=parallel, kv_spec=kv_leaf_spec,
            scan_unroll=knobs.scan_unroll,
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_cache

    p_sds = _params_sds(model, cfg, knobs.param_dtype)
    p_spec = param_specs(p_sds, cfg, mesh, mode=param_mode)
    specs = model.input_specs(shape)
    b_spec = batch_specs(cfg, shape, mesh)

    return StepBundle(
        name=f"decode:{cfg.name}:{shape.name}",
        fn=serve_step,
        args_sds=(p_sds, specs["token"], specs["cache"], specs["pos"]),
        in_shardings=(
            _ns(mesh, p_spec),
            _ns(mesh, b_spec["token"]),
            _ns(mesh, b_spec["cache"]),
            _ns(mesh, b_spec["pos"]),
        ),
        out_shardings=(_ns(mesh, b_spec["token"]), _ns(mesh, b_spec["cache"])),
        donate_argnums=(2,),  # donate the cache
        meta=dict(kind="decode"),
    )


def _decode_kv_spec(cache_spec_tree) -> Optional[P]:
    """The per-slot (B, S, Hkv, hd) spec the layer's cache-write constraint
    uses — the stacked spec minus the leading periods axis."""
    leaves = jax.tree_util.tree_leaves(
        cache_spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    for s in leaves:
        if isinstance(s, P) and len(s) == 5:
            return P(*s[1:])
    return None


# ----------------------------------------------------------------------
# dispatch
# ----------------------------------------------------------------------


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, knobs: Knobs) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, knobs)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, knobs)
    if shape.kind == "decode":
        return build_serve_step(cfg, shape, mesh, knobs)
    raise ValueError(shape.kind)
