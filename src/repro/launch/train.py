"""Centralized LM training driver (the end-to-end example backbone).

Trains any ``--arch`` (reduced by default on CPU; pass --full on a real
mesh) on synthetic Markov-chain LM data with AdamW + warmup-cosine,
checkpointing and metric logging.  The jitted step comes from the SAME
builder the dry-run lowers — what we measure is what we run.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.configs.base import ARCH_IDS, ShapeConfig, get_config, reduced
from repro.data.synthetic import make_synthetic_lm
from repro.data.pipeline import lm_batch_iterator
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import Knobs, build_train_step
from repro.models import build_model
from repro.optim.optimizers import warmup_cosine
from repro.utils.metrics import MetricLogger


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true", help="full config (TPU mesh)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    if cfg.is_encoder_decoder:
        print("(enc-dec arch: tokens drive the decoder; src embeds are synthetic)")

    mesh = make_test_mesh()
    shape = ShapeConfig("custom_train", "train", args.seq, args.batch)
    sched = warmup_cosine(args.lr, args.warmup, args.steps)
    knobs = Knobs(remat="none", param_dtype="float32", learning_rate=sched)
    bundle = build_train_step(cfg, shape, mesh, knobs)
    step_fn = jax.jit(bundle.fn, donate_argnums=(0, 1))

    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    from repro.optim.optimizers import adamw

    opt = adamw(sched)
    opt_state = opt.init(params)

    start_step = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        tree, meta = load_checkpoint(args.ckpt_dir, None, {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        start_step = int(meta.get("step", 0))
        print(f"resumed from step {start_step}")

    toks = make_synthetic_lm(
        vocab_size=cfg.vocab_size, seq_len=args.seq + 1, n_seqs=2048, seed=args.seed
    )
    it = lm_batch_iterator(toks, args.batch, seed=args.seed)

    log = MetricLogger(["step", "loss", "grad_norm", "tok_per_s"], echo_every=1)
    t_last, toks_since = time.time(), 0
    for step in range(start_step, args.steps):
        batch = next(it)
        if cfg.is_encoder_decoder:
            batch = {
                "src_embeds": jax.random.normal(
                    jax.random.fold_in(rng, step), (args.batch, args.seq, cfg.d_model)
                ),
                "tgt_tokens": batch["tokens"],
                "labels": batch["labels"],
            }
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        toks_since += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_last
            log.log(step=step + 1, loss=float(metrics["loss"]),
                    grad_norm=float(metrics["grad_norm"]),
                    tok_per_s=round(toks_since / max(dt, 1e-9)))
            t_last, toks_since = time.time(), 0
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, {"params": params, "opt": opt_state})
    final_loss = float(metrics["loss"])
    print(f"final loss: {final_loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
