"""Serving driver: batched prefill → decode with a KV/SSM cache.

A minimal continuous-batching-style server loop: a batch of prompts is
prefilled in one forward pass (emitting the cache), then tokens are decoded
step-by-step with the jitted serve step.  Greedy sampling (temperature 0)
by default; ``--temperature`` enables categorical sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.data.synthetic import make_synthetic_lm
from repro.models import build_model


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    if cfg.is_encoder_decoder:
        return _serve_encdec(cfg, args)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompts = jnp.asarray(
        make_synthetic_lm(cfg.vocab_size, args.prompt_len, args.batch, seed=args.seed),
        jnp.int32,
    )
    max_len = args.prompt_len + args.gen

    # ---- prefill: run the prompt once, emitting per-layer K/V / SSM state
    t0 = time.time()
    prefill = jax.jit(lambda p, t: model.apply(p, t, return_cache=True))
    logits, pre_cache, _ = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # copy the prefill cache into a max_len decode buffer
    cache = model.init_cache(params, args.batch, max_len)

    def merge(dst, src):
        if dst.ndim >= 3 and src.ndim == dst.ndim and dst.shape[2] >= src.shape[2] and dst.shape[:2] == src.shape[:2]:
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim
            )
        return src.astype(dst.dtype)  # ssm/conv states replace wholesale

    cache = jax.tree_util.tree_map(merge, cache, pre_cache)

    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    rng = jax.random.PRNGKey(args.seed + 1)

    def sample(lg, key):
        if args.temperature <= 0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(key, lg[:, -1] / args.temperature)[:, None].astype(jnp.int32)

    tok = sample(logits, rng)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, tok, cache, pos)
        rng, key = jax.random.split(rng)
        tok = sample(logits, key)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms  ({args.batch*args.prompt_len/max(t_prefill,1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms  ({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generations (first 16 tokens):")
    for b in range(min(args.batch, 4)):
        print("  ", np.asarray(gen[b, :16]).tolist())
    return 0


def _serve_encdec(cfg, args) -> int:
    """Seamless-style: encode source frames once, decode target tokens."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    from repro.models import encdec

    src = jax.random.normal(
        jax.random.PRNGKey(args.seed + 2), (args.batch, args.prompt_len, cfg.d_model)
    )
    t0 = time.time()
    enc_out = jax.jit(lambda p, s: encdec.encode(p, s, cfg=cfg))(params, src)
    cache = encdec.init_decode_cache(params, cfg, args.batch, args.gen, enc_out)
    jax.block_until_ready(enc_out)
    t_enc = time.time() - t0

    decode = jax.jit(
        lambda p, t, c, pos: encdec.decode_step(p, t, c, pos, cfg=cfg),
        donate_argnums=(2,),
    )
    tok = jnp.zeros((args.batch, 1), jnp.int32)  # BOS
    outs = []
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = decode(params, tok, cache, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"encdec arch={cfg.name}: encode {t_enc*1e3:.1f}ms, "
          f"decode {t_dec*1e3:.1f}ms ({args.batch*args.gen/max(t_dec,1e-9):.0f} tok/s)")
    print("sample:", np.asarray(gen[0, :16]).tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
