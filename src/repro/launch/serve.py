"""Serving driver: batched prefill → decode with a KV/SSM cache.

A minimal continuous-batching-style server loop: a batch of prompts is
prefilled in one forward pass (emitting the cache), then tokens are decoded
step-by-step with the jitted serve step.  Greedy sampling (temperature 0)
by default; ``--temperature`` enables categorical sampling.

The decode loop itself is the reusable ``serve_loop`` consumed by the
fleet driver (``repro.fleet.driver``): it polls a ``params_provider``
BETWEEN decode steps and hot-swaps the served params at a step boundary,
so a checkpoint published mid-generation lands atomically — an in-flight
decode step always runs against exactly one complete version, never a
torn mix of two (the publisher's pointer protocol guarantees each loaded
version is complete; the step-boundary swap guarantees no step straddles
two).

``--ckpt DIR`` loads published params (``repro.fleet.publisher`` layout:
``LATEST.json`` + ``step_<v>.msgpack``) into the server instead of random
init — the params a ``fed_train --serve`` run publishes.  ``--follow``
keeps watching the directory and hot-swaps new versions as they publish.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.data.synthetic import make_synthetic_lm
from repro.models import build_model


@dataclass
class ServeStats:
    """What the serving loop did — the fleet CI smoke asserts on these
    (and the driver folds them into the telemetry ``serve_summary`` row)."""

    steps: int = 0  # decode steps executed
    sessions: int = 0  # completed sessions (prefill→gen sequences)
    swaps: int = 0  # hot-swaps taken (any step boundary)
    swaps_mid_session: int = 0  # swaps taken while a session was decoding
    swap_steps: List[int] = field(default_factory=list)  # global step at swap
    versions: List[int] = field(default_factory=list)  # version per swap
    served_version: int = 0  # version of the params currently served
    t_active_s: float = 0.0  # wall time spent inside sessions


def serve_loop(
    params: Any,
    decode_step: Callable[[Any, Any, int], Any],
    *,
    begin_session: Optional[Callable[[Any, int], Any]] = None,
    end_session: Optional[Callable[[Any, Any], None]] = None,
    params_provider: Optional[Any] = None,
    steps_per_session: int,
    max_sessions: Optional[int] = 1,
    stop_event: Optional[threading.Event] = None,
    on_swap: Optional[Callable[[int, ServeStats], None]] = None,
    on_step: Optional[Callable[[ServeStats], None]] = None,
    idle_sleep_s: float = 0.0,
    step_sleep_s: float = 0.0,
    version: int = 0,
) -> Tuple[Any, ServeStats]:
    """Run serving sessions, hot-swapping params between decode steps.

    ``decode_step(params, state, i)`` advances one decode step;
    ``begin_session(params, s)`` builds a fresh session state (prefill);
    ``end_session(params, state)`` closes one (e.g. block_until_ready).
    ``params_provider.poll()`` — when given — is called before EVERY
    decode step and must return ``None`` (unchanged) or a complete
    ``(version, params, meta)``; the swap is a single reference
    assignment at the step boundary, so the ``decode_step`` call that
    follows sees the new version in full and the one that preceded it saw
    the old version in full: atomic under decode load by construction.

    Runs until ``max_sessions`` sessions completed (``None`` = forever) or
    ``stop_event`` is set (checked between steps, so a stop request never
    kills a decode step mid-flight).  Returns the final (possibly swapped)
    params and the stats."""
    stats = ServeStats(served_version=version)

    def _swap(step_in_session: int) -> None:
        nonlocal params
        if params_provider is None:
            return
        got = params_provider.poll()
        if got is None:
            return
        new_version, new_params, _meta = got
        params = new_params
        stats.served_version = new_version
        stats.swaps += 1
        if step_in_session > 0:
            stats.swaps_mid_session += 1
        stats.swap_steps.append(stats.steps)
        stats.versions.append(new_version)
        if on_swap is not None:
            on_swap(new_version, stats)

    while max_sessions is None or stats.sessions < max_sessions:
        if stop_event is not None and stop_event.is_set():
            break
        t0 = time.perf_counter()
        _swap(0)
        state = begin_session(params, stats.sessions) if begin_session else None
        for i in range(steps_per_session):
            if stop_event is not None and stop_event.is_set():
                break
            if i > 0:
                _swap(i)
            state = decode_step(params, state, i)
            stats.steps += 1
            if on_step is not None:
                on_step(stats)
            if step_sleep_s > 0:
                # paced decoding: keeps the session live across wall-clock
                # time (so publishes land MID-session — the under-load swap
                # path) and yields the core to the co-resident training scan
                time.sleep(step_sleep_s)
        else:
            if end_session is not None:
                end_session(params, state)
            stats.sessions += 1
        stats.t_active_s += time.perf_counter() - t0
        if idle_sleep_s > 0:
            # yield the core between sessions (the fleet driver shares the
            # host with the training scan; serving must not starve it)
            time.sleep(idle_sleep_s)
    return params, stats


def load_ckpt_params(path: str, template: Any, *, follow: bool = False):
    """Resolve ``--ckpt`` → ``(version, params, provider-or-None)``.

    ``path`` is a publisher directory (``LATEST.json`` pointer) or a
    single ``step_<v>.msgpack`` payload file from one."""
    from repro.fleet.publisher import ParamsWatch, load_published

    if os.path.isdir(path):
        watcher = ParamsWatch(path, template=template)
        try:
            got = watcher.poll()
        except KeyError as e:
            raise SystemExit(
                f"--ckpt {path}: published params do not match this serving "
                f"model's template ({e}) — the directory was published by a "
                "different model (e.g. a fed_train classifier run, not "
                f"--arch)"
            ) from e
        if got is None:
            raise FileNotFoundError(
                f"--ckpt {path}: no LATEST.json — nothing published yet"
            )
        version, params, _ = got
        return version, params, (watcher if follow else None)
    d, name = os.path.split(path)
    if not (name.startswith("step_") and name.endswith(".msgpack")):
        raise ValueError(
            f"--ckpt {path}: expected a publisher directory or a "
            "step_<version>.msgpack payload"
        )
    version = int(name[len("step_"):-len(".msgpack")])
    version, params, _ = load_published(d, template, version)
    return version, params, None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="",
                    help="serve published params (fleet publisher dir or "
                         "step_<v>.msgpack) instead of random init")
    ap.add_argument("--follow", action="store_true",
                    help="with --ckpt DIR: keep watching for new published "
                         "versions and hot-swap them between decode steps")
    ap.add_argument("--sessions", type=int, default=1,
                    help="prefill→decode sessions to run (continuous "
                         "serving = more than one)")
    args = ap.parse_args(argv)
    if args.follow and not args.ckpt:
        ap.error("--follow watches the --ckpt directory — add --ckpt DIR")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    if cfg.is_encoder_decoder:
        return _serve_encdec(cfg, args)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    version, provider = 0, None
    if args.ckpt:
        version, params, provider = load_ckpt_params(
            args.ckpt, params, follow=args.follow
        )
        print(f"serving published params: version {version} from {args.ckpt}")
    prompts = jnp.asarray(
        make_synthetic_lm(cfg.vocab_size, args.prompt_len, args.batch, seed=args.seed),
        jnp.int32,
    )
    max_len = args.prompt_len + args.gen

    # ---- prefill: run the prompt once, emitting per-layer K/V / SSM state
    prefill = jax.jit(lambda p, t: model.apply(p, t, return_cache=True))
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def merge(dst, src):
        if dst.ndim >= 3 and src.ndim == dst.ndim and dst.shape[2] >= src.shape[2] and dst.shape[:2] == src.shape[:2]:
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim
            )
        return src.astype(dst.dtype)  # ssm/conv states replace wholesale

    def sample(lg, key):
        if args.temperature <= 0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(key, lg[:, -1] / args.temperature)[:, None].astype(jnp.int32)

    timings = {"prefill": 0.0, "decode": 0.0}
    last = {"gen": None}

    def begin_session(p, s):
        t0 = time.time()
        logits, pre_cache, _ = prefill(p, prompts)
        jax.block_until_ready(logits)
        timings["prefill"] += time.time() - t0
        # copy the prefill cache into a max_len decode buffer
        cache = model.init_cache(p, args.batch, max_len)
        cache = jax.tree_util.tree_map(merge, cache, pre_cache)
        rng = jax.random.PRNGKey(args.seed + 1 + s)
        tok = sample(logits, rng)
        return {"tok": tok, "cache": cache, "rng": rng,
                "out": [tok], "t0": time.time()}

    def decode_step(p, st, i):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(p, st["tok"], st["cache"], pos)
        rng, key = jax.random.split(st["rng"])
        tok = sample(logits, key)
        st["out"].append(tok)
        return {**st, "tok": tok, "cache": cache, "rng": rng}

    def end_session(p, st):
        jax.block_until_ready(st["tok"])
        timings["decode"] += time.time() - st["t0"]
        last["gen"] = jnp.concatenate(st["out"], axis=1)

    _, stats = serve_loop(
        params, decode_step,
        begin_session=begin_session, end_session=end_session,
        params_provider=provider,
        steps_per_session=args.gen - 1, max_sessions=args.sessions,
        version=version,
    )

    gen = last["gen"]
    n = max(stats.sessions, 1)
    t_prefill, t_decode = timings["prefill"] / n, timings["decode"] / n
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} sessions={stats.sessions}")
    print(f"prefill: {t_prefill*1e3:.1f} ms  ({args.batch*args.prompt_len/max(t_prefill,1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms  ({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    if provider is not None or stats.swaps:
        print(f"hot-swaps: {stats.swaps} (served version {stats.served_version})")
    print("sample generations (first 16 tokens):")
    for b in range(min(args.batch, 4)):
        print("  ", np.asarray(gen[b, :16]).tolist())
    return 0


def _serve_encdec(cfg, args) -> int:
    """Seamless-style: encode source frames once, decode target tokens."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.ckpt:
        version, params, _ = load_ckpt_params(args.ckpt, params)
        print(f"serving published params: version {version} from {args.ckpt}")
    from repro.models import encdec

    src = jax.random.normal(
        jax.random.PRNGKey(args.seed + 2), (args.batch, args.prompt_len, cfg.d_model)
    )
    t0 = time.time()
    enc_out = jax.jit(lambda p, s: encdec.encode(p, s, cfg=cfg))(params, src)
    cache = encdec.init_decode_cache(params, cfg, args.batch, args.gen, enc_out)
    jax.block_until_ready(enc_out)
    t_enc = time.time() - t0

    decode = jax.jit(
        lambda p, t, c, pos: encdec.decode_step(p, t, c, pos, cfg=cfg),
        donate_argnums=(2,),
    )
    tok = jnp.zeros((args.batch, 1), jnp.int32)  # BOS
    outs = []
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = decode(params, tok, cache, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"encdec arch={cfg.name}: encode {t_enc*1e3:.1f}ms, "
          f"decode {t_dec*1e3:.1f}ms ({args.batch*args.gen/max(t_dec,1e-9):.0f} tok/s)")
    print("sample:", np.asarray(gen[0, :16]).tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
