"""Qwen3-14B [hf:Qwen/Qwen3-8B family].

Dense decoder with QK-RMSNorm (qk_norm — Qwen3's signature stabilization),
GQA 40Q/8KV (head_dim 128), gated-SiLU MLP d_ff=17408, 151936 vocab, RoPE.

long_500k SKIPPED (pure full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    use_rope=True,
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp_type="gated_silu",
    dtype="bfloat16",
    source="hf:Qwen/Qwen3-8B",
)
