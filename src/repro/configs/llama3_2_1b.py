"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B].

Small dense llama3: 16 layers, d_model=2048, GQA 32Q/8KV heads (head_dim
64), gated-SiLU MLP d_ff=8192, 128256 vocab, tied embeddings, RoPE
theta=500k.

This is the default arch for the federated-LM examples (it is the smallest
dense member of the pool).  long_500k SKIPPED (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    use_rope=True,
    rope_theta=500_000.0,
    mlp_type="gated_silu",
    tie_embeddings=True,
    dtype="bfloat16",
    source="hf:meta-llama/Llama-3.2-1B",
)
