"""Config system: model / federated / shape configs + registry.

Every assigned architecture lives in ``src/repro/configs/<id>.py`` exposing a
module-level ``CONFIG: ModelConfig``.  ``get_config(name)`` resolves it;
``reduced(cfg)`` produces the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads

    # --- attention variants ---
    use_rope: bool = True
    rope_theta: float = 10000.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # window for "local" attention layers
    # (n_local, n_global) per repeating period; None = all-global.
    local_global_pattern: Optional[Tuple[int, int]] = None

    # --- mlp ---
    mlp_type: str = "gated_silu"  # gated_silu | gelu
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1  # a layer is MoE iff (layer_idx % moe_every == moe_every-1)
    shared_expert: bool = False
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # --- hybrid (zamba2-style): shared attention block every N layers ---
    attn_every: int = 0  # 0 = never; >0: layer i is (shared) attention iff i % attn_every == attn_every-1

    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontend (stubbed per assignment) ---
    input_mode: str = "tokens"  # tokens | embeddings

    # --- numerics ---
    dtype: str = "float32"  # activation dtype ("bfloat16" on TPU target)
    param_dtype: str = "float32"

    # --- provenance ---
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding table rows — the assigned vocab rounded up
        to 256 so the vocab dim shards over any production mesh axis (an
        unshardable 256206-row unembed costs a 31 GiB/chip logits tensor).
        Token ids stay < vocab_size; the pad rows are dead weight."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer_idx % self.moe_every == self.moe_every - 1

    def is_attn_layer(self, layer_idx: int) -> bool:
        """hybrid family: which decoder layers are (shared) attention blocks."""
        if self.family != "hybrid":
            return True
        return self.attn_every > 0 and layer_idx % self.attn_every == self.attn_every - 1

    def is_global_attn_layer(self, layer_idx: int) -> bool:
        """local:global pattern — global layers attend fully."""
        if self.local_global_pattern is None:
            return self.sliding_window is None
        n_local, n_global = self.local_global_pattern
        period = n_local + n_global
        return layer_idx % period >= n_local

    # ------------------------------------------------------------------
    # parameter count estimate (for MODEL_FLOPS = 6*N*D in the roofline)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        D = self.d_model
        hd = self.resolved_head_dim if self.n_heads > 0 else 0
        n = 0
        # embeddings
        emb = self.vocab_size * D
        n += emb if self.tie_embeddings else 2 * emb

        def attn_params() -> int:
            q = D * self.n_heads * hd
            kv = 2 * D * self.n_kv_heads * hd
            o = self.n_heads * hd * D
            return q + kv + o

        def mlp_params(d_ff: int) -> int:
            if self.mlp_type == "gated_silu":
                return 3 * D * d_ff
            return 2 * D * d_ff

        def mamba_params() -> int:
            d_inner = self.ssm_expand * D
            nheads = self.ssm_heads
            # in_proj -> [z, x, B, C, dt]
            zxbcdt = 2 * d_inner + 2 * self.ssm_state + nheads
            in_p = D * zxbcdt
            conv = (d_inner + 2 * self.ssm_state) * self.ssm_conv
            out_p = d_inner * D
            head = 2 * nheads  # A_log, D skip
            return in_p + conv + out_p + head

        layers = self.n_layers
        if self.family in ("ssm",):
            n += layers * mamba_params()
        elif self.family == "hybrid":
            n_attn = sum(1 for i in range(layers) if self.is_attn_layer(i))
            n_mamba = layers - n_attn
            n += n_mamba * mamba_params()
            # shared attention block: counted once (weights shared)
            n += attn_params() + mlp_params(self.d_ff)
        else:
            for i in range(layers):
                n += attn_params()
                if self.is_moe_layer(i):
                    e = self.n_experts
                    if active_only:
                        e = self.top_k + (1 if self.shared_expert else 0)
                    n += e * mlp_params(self.d_ff) + D * self.n_experts  # + router
                    if self.shared_expert and not active_only:
                        n += mlp_params(self.d_ff)
                else:
                    n += mlp_params(self.d_ff)
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder additionally cross-attn
            n += self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff))
            n += self.n_layers * attn_params()  # cross attention in decoder
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class FaultConfig:
    """Fault injection as pure config data (seeded, reproducible).

    Every fault is a mask/plane transform applied between cohort launch
    and server fold, drawn from a PRNG chain keyed by
    ``(seed, absolute round, client id)`` — NOT by cohort slot — so a
    client's fate in a round is invariant to sampler placement and a
    kill/resume replays the identical fault sequence.  ``fault=None`` on
    :class:`FedConfig` traces no fault code at all: those paths stay
    f32-bitwise against the fault-free engine.
    """

    # per-client per-round probability the uplink is lost entirely
    drop_rate: float = 0.0
    # straggler deadline model: client round time ~ LogNormal(0, σ) in
    # units of the median client; a client slower than ``deadline`` misses
    # the round (its uplink is treated as dropped).  0 = no deadline.
    deadline: float = 0.0
    straggler_sigma: float = 0.5
    # payload corruption: per-client probability the uplink delta plane
    # arrives corrupted, and how — "nan"/"inf" overwrite the row with that
    # value (a dead-accelerator payload); "noise" adds relative Gaussian
    # bit-noise of scale ``noise_scale × |value|`` (a flaky-link payload).
    corrupt_rate: float = 0.0
    corrupt_mode: str = "nan"  # nan | inf | noise
    noise_scale: float = 1.0
    # transient host-store failures: gather/scatter raise
    # TransientStoreError with this probability; the engine retries with
    # capped exponential backoff (base·2^attempt, capped, then re-raise
    # after max_retries).  Retries never change math — a run with store
    # failures is bitwise-equal to one without.
    store_failure_rate: float = 0.0
    store_max_retries: int = 6
    store_backoff_base: float = 0.02
    store_backoff_cap: float = 0.5
    # uplink quarantine: zero the fold-weight row (and sanitize the
    # payload rows to exact zeros, so 0·NaN never reaches a reduction) of
    # any client whose uplink is non-finite; when quarantine_norm_mult
    # > 0 also quarantine finite rows whose ‖Δ‖ exceeds
    # mult × median(‖Δ‖ of the surviving cohort) — a norm-outlier fence.
    quarantine: bool = True
    quarantine_norm_mult: float = 0.0
    # fault-stream seed — independent of FedConfig.seed so the same
    # trajectory can be replayed under different fault realizations
    seed: int = 0


@dataclass(frozen=True)
class CompressionConfig:
    """Uplink compression as pure config data (repro.core.compress).

    Every compressed representation is a plane transform applied to the
    cohort uplink between client launch and server fold — on the sync,
    async-ring, cohort-sharded, and host-store paths alike — so the
    f32 ``(C, P)`` uplink never has to exist on the wire (or in the
    async ring).  ``compression=None`` on :class:`FedConfig` traces no
    compression code at all: those paths stay f32-bitwise against the
    uncompressed engine.

    Kinds:
      ``"int8"`` — per-row absmax-scaled stochastic-rounded int8
                   (unbiased: E[dequant(q)] = x); 1 byte/element + one
                   f32 scale per client row.
      ``"bf16"`` — round-to-nearest-even bfloat16; 2 bytes/element.
      ``"topk"`` — magnitude top-k sparsification (k = topk_frac·P)
                   with error-feedback residuals: what a client does
                   not send this round is carried in a per-client
                   residual plane and added to its next uplink.  The
                   residual stream rides the population machinery
                   (resident ``(N, P)`` plane or host store) and is
                   checkpointed with the run.
    """

    kind: str = "int8"  # int8 | bf16 | topk
    # fraction of plane elements kept per client row under "topk"
    topk_frac: float = 0.01
    # stochastic-rounding stream seed — independent of FedConfig.seed
    # and keyed by absolute round, so kill/resume replays the identical
    # quantization noise and cohort-sharded runs agree with unsharded
    seed: int = 0


@dataclass(frozen=True)
class FedConfig:
    """Federated round configuration (paper §6.1 defaults)."""

    # any name in the algorithm registry (repro.core.registry) — builtins:
    # fedcm | fedavg | fedadam | scaffold | feddyn | mimelite | fedavgm |
    # fedadagrad | fedyogi | fedacg; resolved (and validated) by
    # get_algorithm at engine construction.  ``--list-algos`` on
    # launch/fed_train prints each spec's state planes + kernel routing.
    algo: str = "fedcm"
    num_clients: int = 100
    cohort_size: int = 10  # |S|
    local_steps: int = 10  # K
    alpha: float = 0.1  # FedCM / FedAdam server beta1-like; FedDyn reg strength reuses own field
    eta_l: float = 0.1
    eta_g: float = 1.0
    eta_l_decay: float = 0.998  # exponential decay per round (appendix C.2)
    weight_decay: float = 1e-3
    # FedAdam
    adam_beta2: float = 0.99
    adam_tau: float = 1e-2
    # FedDyn
    feddyn_alpha: float = 0.01
    # FedProx: proximal strength μ of the registered "fedprox" spec
    # (local direction v = g + μ·(x − x_t) — a pure c_x DirectionRow)
    fedprox_mu: float = 0.01
    # FedACG-style server acceleration: lookahead/momentum coefficient λ of
    # the registered "fedacg" spec (m' = λ·m + Δ_{t+1}; the server steps
    # along Δ_{t+1} + λ·m')
    acg_lambda: float = 0.85
    # participation model: "fixed" = exactly cohort_size w/o replacement,
    # "bernoulli" = each client independently with prob cohort_size/num_clients
    participation: str = "fixed"
    rounds: int = 100
    seed: int = 0
    # server momentum Δ_t storage/broadcast dtype — bf16 halves the extra
    # FedCM downlink (§4.2) and the per-local-step momentum gathers (§Perf C)
    momentum_dtype: str = "float32"
    # cohort-aggregation dtype: the Δ mean over the (pod, data) axes is an
    # all-reduce of a params-shaped tree — bf16 halves its bytes (production
    # FL systems quantize aggregation much harder than this)
    aggregate_dtype: str = "float32"
    # flat parameter plane (repro.core.flat): ravel params/momentum/client
    # state ONCE per run_rounds call and carry (P,)/(C,P)/(N,P) buffers
    # through the local-step scan, cohort vmap, aggregation, and server
    # update.  The tree path (False) is kept as the numerical oracle and
    # for tensor-sharded lowering (launch/fed_dryrun pins it off: a flat
    # concat of model-sharded leaves would force all-gathers).
    use_flat_plane: bool = True
    # route the per-local-step update x ← x − η_l·v through the fused
    # Pallas kernels instead of unfused jnp arithmetic — flat plane only:
    # kernels/fed_direction (all algorithms) plus the fused
    # kernels/server_update round-close (fedavg/fedcm/scaffold/mimelite).
    # The legacy whole-tree kernels/fedcm_update launch is retired; on the
    # tree path this flag is inert.  ref.py files are the oracles
    # (tests/test_run_rounds.py, tests/test_kernels.py).
    use_fused_kernel: bool = False
    # async pipelined engine (engine.run_rounds_async): number of cohorts
    # in flight.  1 = the sync schedule (each cohort folds the round it
    # launches); D > 1 overlaps D cohorts — a fold is D−1 rounds stale.
    pipeline_depth: int = 1
    # rounds of momentum staleness the clients descend against (the
    # broadcast Δ_t / c is read from an S-deep delay line).  0 = current.
    staleness: int = 0
    # FedACG-style per-round-of-staleness discount γ: a fold that is
    # (pipeline_depth−1) rounds stale is weighted γ^(depth−1) — rides the
    # fused server kernel's SMEM coefficient row.  1.0 = no discount.
    staleness_discount: float = 1.0
    # cohort-parallel execution: number of devices to shard the client
    # axis over (engine builds a ("clients",) mesh over the first N
    # visible devices and runs the cohort via shard_map; the fold lowers
    # to a reduce-scatter/all-gather).  0 = single-device execution.
    # Requires use_flat_plane + use_fused_kernel.  An explicit mesh can
    # instead be passed as FederatedEngine(..., cohort_mesh=...).
    cohort_shard: int = 0
    # ---- population store / streaming availability (million-client axis) --
    # Where per-client state planes (scaffold c_i, feddyn λ_i) live:
    #   "resident" — the stacked (N, P) device plane (the bitwise oracle),
    #   "host"     — a sparse host-memory store (repro.data.population);
    #                the engine gathers a (C, P) block on participation and
    #                scatters updated rows back after the fold, so device
    #                memory scales with the COHORT and host memory with the
    #                set of touched clients, never with N.  N=1e6 becomes a
    #                literal config value.  Requires use_flat_plane.
    population_store: str = "resident"
    # availability process driving the streaming cohort sampler:
    #   "uniform" — every client equally likely (the legacy draw, kept
    #               bitwise-identical to the pre-store sampler),
    #   "zipf"    — traffic skew w_i ∝ (i+1)^-zipf_exponent,
    #   "diurnal" — time-of-day sinusoid over the round counter; client i
    #               peaks at phase i/N of a diurnal_period-round "day".
    availability: str = "uniform"
    zipf_exponent: float = 1.1
    diurnal_period: float = 24.0  # rounds per simulated day
    diurnal_amplitude: float = 0.8  # 0 = uniform, →1 = full day/night swing
    # straggler model: each SELECTED client independently drops out of the
    # round with this probability (mask-only thinning after selection; a
    # fully-dropped cohort keeps its first client so n_active ≥ 1).
    dropout_rate: float = 0.0
    # bernoulli cohort capacity = mean + σ·sd tail bound.  5σ makes the
    # static pad overflow ~never (p < 3e-7); either way an overflow is now
    # COUNTED in RoundMetrics.n_clipped instead of silently truncated.
    bernoulli_capacity_sigma: float = 5.0
    # ---- fault tolerance ------------------------------------------------
    # fault injection model (None = no fault code traced; see FaultConfig)
    fault: Optional[FaultConfig] = None
    # minimum surviving cohort for the server fold to apply: when fewer
    # than max(1, min_quorum) clients survive drops + quarantine, the
    # round becomes a no-op — params/momentum carried unchanged, client
    # state writes suppressed, RoundMetrics.quorum_skipped = 1.  The
    # implicit floor of 1 is the empty-cohort guard (an all-zero weight
    # row used to 0/0-poison the masked mean with NaN).
    min_quorum: int = 0
    # let sample_cohort_ex produce an EMPTY cohort (bernoulli draw of 0 /
    # total dropout) instead of force-keeping one client.  Safe now that
    # empty rounds degrade to guarded no-ops; default off preserves the
    # legacy keep-first sampler bitwise.
    allow_empty_cohort: bool = False
    # ---- uplink compression --------------------------------------------
    # uplink compression model (None = no compression code traced; see
    # CompressionConfig).  Requires use_flat_plane — the transforms are
    # flat-plane ops; the tree path stays the uncompressed oracle.
    compression: Optional[CompressionConfig] = None
    # host-store loop double-buffering: prefetch the NEXT round's cohort
    # sample + host batch generation (and, optimistically, its store
    # gather) on a background thread while the current round runs on
    # device.  Bitwise-identical to the synchronous loop — overlapping
    # rows are re-gathered after the scatter they depend on.
    store_prefetch: bool = True


@dataclass(frozen=True)
class TrainConfig:
    """Centralized training driver config."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    remat: str = "none"  # none | full | dots
    seed: int = 0


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
ARCH_IDS = [
    "starcoder2-7b",
    "llama4-maverick-400b-a17b",
    "seamless-m4t-large-v2",
    "dbrx-132b",
    "zamba2-7b",
    "llama3.2-1b",
    "qwen3-14b",
    "gemma3-12b",
    "chameleon-34b",
    "mamba2-1.3b",
]

_MODULE_FOR: Dict[str, str] = {
    "starcoder2-7b": "starcoder2_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "dbrx-132b": "dbrx_132b",
    "zamba2-7b": "zamba2_7b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-14b": "qwen3_14b",
    "gemma3-12b": "gemma3_12b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-1.3b": "mamba2_1_3b",
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-") if name not in _MODULE_FOR else name
    if key not in _MODULE_FOR:
        # allow passing module-style names too
        for k, mod in _MODULE_FOR.items():
            if mod == name:
                key = k
                break
    if key not in _MODULE_FOR:
        raise KeyError(f"unknown architecture {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[key]}")
    return mod.CONFIG


def list_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests.

    ≤2 layers, d_model ≤ 512, ≤4 experts — per the assignment contract.
    """
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    if n_heads > 0:
        head_dim = max(d_model // n_heads, 32)
        n_kv = min(cfg.n_kv_heads, n_heads)
        if n_heads % n_kv != 0:
            n_kv = 1
    else:  # attention-free (ssm)
        head_dim = None
        n_kv = 0
    updates = dict(
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.n_experts:
        updates["n_experts"] = min(cfg.n_experts, 4)
        updates["top_k"] = min(cfg.top_k, 2)
        updates["moe_every"] = min(cfg.moe_every, 2)
    if cfg.family in ("ssm", "hybrid"):
        updates["ssm_state"] = min(cfg.ssm_state, 16)
        updates["ssm_head_dim"] = 32
        updates["ssm_chunk"] = 16
        if cfg.family == "hybrid":
            updates["n_layers"] = 2
            updates["attn_every"] = 2  # layer 1 is the shared attention block
    if cfg.is_encoder_decoder:
        updates["n_encoder_layers"] = 2
    if cfg.sliding_window is not None:
        updates["sliding_window"] = min(cfg.sliding_window, 8)
    if cfg.local_global_pattern is not None:
        updates["local_global_pattern"] = (1, 1)
    return replace(cfg, **updates)


def to_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
