"""Chameleon-34B [arXiv:2405.09818].

Early-fusion mixed-modal decoder: images are VQ-quantized into discrete
tokens drawn from the same 65536-entry vocabulary as text, so the backbone
is a plain (large) dense decoder — 48 layers, d_model=8192, GQA 64Q/8KV,
gated-SiLU d_ff=22016, RoPE, QK-norm (Chameleon uses qk-norm for training
stability at scale).

The VQ-VAE image tokenizer is the assignment's allowed stub: inputs are
already token ids (text + image tokens interleaved).  long_500k SKIPPED
(pure full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    use_rope=True,
    rope_theta=10000.0,
    qk_norm=True,
    mlp_type="gated_silu",
    dtype="bfloat16",
    source="arXiv:2405.09818",
)
