"""Gemma3-12B [hf:google/gemma-3-1b-pt family].

Dense decoder with 5:1 local:global attention pattern (5 sliding-window
layers with w=1024, then 1 global layer, repeating — 128k context), GQA
16Q/8KV with head_dim=256, QK-norm, gated-GELU... we use gated_silu (GeGLU
and SwiGLU are isomorphic for system purposes), d_ff=15360, 262144 vocab.

The 5:1 sliding pattern makes 40 of 48 layers sub-quadratic; the 8 global
layers hold the (sequence-sharded) full cache → runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    use_rope=True,
    rope_theta=1_000_000.0,
    qk_norm=True,
    sliding_window=1024,
    local_global_pattern=(5, 1),
    mlp_type="gated_silu",
    dtype="bfloat16",
    source="hf:google/gemma-3-1b-pt",
)
