"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

MoE decoder: 128 routed experts, top-1 routing, plus one shared expert;
MoE layers interleaved every other layer (interleave step 2 — this is what
reconciles 128 experts x 48 layers with the ~400B total / ~17B active
parameter budget).  GQA with 8 KV heads, RoPE, early-fusion multimodal (the
vision frontend is stubbed per the assignment; text/image tokens share the
202048-entry vocabulary).

long_500k is SKIPPED for this arch (global attention layers are
full-attention here; see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    use_rope=True,
    rope_theta=500_000.0,
    mlp_type="gated_silu",
    n_experts=128,
    top_k=1,
    moe_every=2,
    shared_expert=True,
    capacity_factor=1.25,
    dtype="bfloat16",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
