from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    FedConfig,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    list_configs,
    reduced,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "FedConfig",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "get_config",
    "list_configs",
    "reduced",
]
