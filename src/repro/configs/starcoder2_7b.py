"""StarCoder2-7B [arXiv:2402.19173].

Dense decoder, GQA (36 query heads, 4 KV heads), RoPE, 4096-token sliding
window attention (per the StarCoder2 paper), standard (non-gated) GELU MLP
with 4x expansion, learned absolute-free (RoPE only).

Because every layer is sliding-window (w=4096), this arch is sub-quadratic
and runs the long_500k decode shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    use_rope=True,
    rope_theta=1_000_000.0,
    mlp_type="gelu",
    sliding_window=4096,
    dtype="bfloat16",
    source="arXiv:2402.19173",
)
