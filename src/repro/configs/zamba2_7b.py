"""Zamba2-7B [arXiv:2411.15242].

Hybrid: a Mamba2 backbone with a *shared* attention+MLP block inserted
periodically (weights reused at every insertion — Zamba's signature trick
for attention quality at near-SSM parameter cost).  81 layers total,
d_model=3584, ssm_state=64; the shared attention block is 32-head MHA
(kv=32) with d_ff=14336.

We realize the insertion as: layer i is the shared attention block iff
i % attn_every == attn_every-1 with attn_every=6 → 13 attention
applications + 68 mamba2 layers (all attention applications share one
parameter set).

Sub-quadratic overall (SSM layers O(1) state; the 13 shared-attn layers
hold a sharded KV cache) → runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    use_rope=True,
    rope_theta=10000.0,
    mlp_type="gated_silu",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=64,
    attn_every=6,
    dtype="bfloat16",
    source="arXiv:2411.15242",
)
