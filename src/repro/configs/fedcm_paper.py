"""The paper's own experimental settings (§6.1, appendix C).

Setting I: 100 clients, 10% participation.  Setting II: 500 clients, 2%
participation.  Dirichlet(0.6) label skew for the non-IID split.  The paper
trains ResNet-18(GN) on CIFAR10/100 for 4000 rounds; on this CPU container
we reproduce the *comparative* claims at reduced scale (see EXPERIMENTS.md),
with the scaling knobs kept here so the full-paper settings remain the
defaults of record.
"""
from dataclasses import replace

from repro.configs.base import FedConfig

# --- paper-faithful settings (as-published) ---
SETTING_I = FedConfig(
    algo="fedcm",
    num_clients=100,
    cohort_size=10,
    participation="bernoulli",  # "each client is activated independently" (§6.1)
    local_steps=50,  # 5 local epochs x (500 pts / 50 batch) = 50 steps
    alpha=0.1,
    eta_l=0.1,
    eta_g=1.0,
    eta_l_decay=0.998,
    weight_decay=1e-3,
    rounds=4000,
)

SETTING_II = replace(
    SETTING_I,
    num_clients=500,
    cohort_size=10,  # 2% of 500
    local_steps=10,  # 5 local epochs x (100 pts / 50 batch)
    alpha=0.05,
)

DIRICHLET_ALPHA = 0.6  # the paper's non-IID concentration

# --- scaled settings actually run on this container (EXPERIMENTS.md §Repro) ---
SCALED_I = replace(SETTING_I, local_steps=10, rounds=300)
SCALED_II = replace(SETTING_II, local_steps=10, rounds=300)

ALPHA_SWEEP = [0.01, 0.03, 0.05, 0.1, 0.3, 1.0]  # table 3
