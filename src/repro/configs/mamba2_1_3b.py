"""Mamba2-1.3B [arXiv:2405.21060].

Attention-free SSM decoder using SSD (state-space duality): 48 layers,
d_model=2048, ssm_state=128, expand=2, head_dim=64 (→ 64 SSD heads),
short causal conv k=4, vocab 50280 (GPT-NeoX tokenizer).

O(1) decode state → runs long_500k natively (the whole point of the SSD
family).  d_ff=0: blocks are pure mamba2 (no separate MLP).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=None,
    d_ff=0,
    vocab_size=50280,
    use_rope=False,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=64,
    tie_embeddings=True,
    dtype="bfloat16",
    source="arXiv:2405.21060",
)
