"""DBRX-132B [hf:databricks/dbrx-base].

Fine-grained MoE decoder: 16 experts, top-4 routing, every layer is MoE.
GQA (48 query heads, 8 KV heads), RoPE, gated-GLU experts with d_ff=10752.

long_500k is SKIPPED (pure full attention; see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    use_rope=True,
    rope_theta=500_000.0,
    mlp_type="gated_silu",
    n_experts=16,
    top_k=4,
    moe_every=1,
    capacity_factor=1.25,
    dtype="bfloat16",
    source="hf:databricks/dbrx-base",
)
