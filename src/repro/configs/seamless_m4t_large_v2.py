"""SeamlessM4T-Large v2 [arXiv:2308.11596].

Encoder-decoder multimodal translation backbone: 24 encoder + 24 decoder
layers, d_model=1024, 16 heads MHA (kv=16), d_ff=8192, 256k vocabulary.

The speech frontend (mel-spectrogram + conformer feature extractor) is the
assignment's allowed stub: ``input_specs`` provides precomputed frame
embeddings of shape (batch, src_len, d_model) which the text/unit encoder
consumes directly (input_mode="embeddings").

Decode shapes run the *decoder* serve_step (1 new target token with a
seq_len-deep self-attention KV cache + cross-attention to the encoder
output).  long_500k is SKIPPED (full-attention enc-dec; see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    use_rope=False,  # sinusoidal/relative in the original; we use learned-free attn
    mlp_type="gelu",
    is_encoder_decoder=True,
    n_encoder_layers=24,
    input_mode="embeddings",
    dtype="bfloat16",
    source="arXiv:2308.11596",
)
