"""Logical-axis sharding rules → PartitionSpec trees (DESIGN.md §5).

One function per pytree kind.  Rules are *path-based*: the leaf's dict-key
name (``wq``, ``w_down``, ``embed`` …) plus its rank decide the spec — layer
stacks add a leading ``periods`` axis which is always unsharded (it is the
scan axis).

Mesh axes:
  ``fsdp``  = the (pod?, data) axes — batch / cohort parallel AND the
              parameter-storage (ZeRO-3) axes; XLA inserts the per-layer
              all-gathers.
  ``model`` = tensor-parallel axis (attention heads, MLP hidden, experts,
              vocab).

The same rules serve: params, grads (same specs), AdamW moments (same
specs), FedCM server momentum Δ_t (same specs — it is a params-shaped
pytree!), and cohort-stacked client params (extra leading cohort axis →
fsdp).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------


def _axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (fsdp_axes, model_axis) for a production mesh."""
    names = mesh.axis_names
    model = "model" if "model" in names else names[-1]
    fsdp = tuple(n for n in names if n != model)
    return fsdp, model


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _key_of(path) -> str:
    """Last dict key in a tree path."""
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _path_str(path) -> str:
    return "/".join(
        str(getattr(e, "key", getattr(e, "name", getattr(e, "idx", "?")))) for e in path
    )


# ----------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------


def param_specs(params_shape: Any, cfg: ModelConfig, mesh, mode: str = "train") -> Any:
    """PartitionSpec tree matching ``jax.eval_shape(model.init, rng)``.

    ``mode="train"``: FSDP storage — every large leaf additionally sharded
    over the (pod, data) axes; XLA all-gathers per layer on use.
    ``mode="serve"``: decode amortizes nothing over a 1-token step, so the
    per-layer FSDP all-gathers DOMINATE the decode collective term (§Perf
    hillclimb B) — serve mode replicates non-expert weights across the data
    axes (tensor-parallel only), keeping FSDP only for MoE expert banks
    (whose replicated copies would not fit HBM).

    Divisibility guard: a dim is only sharded if the axis size divides it —
    otherwise that dim falls back to replicated (correct, just less
    distributed; the dry-run table records the per-arch outcome).
    """
    fsdp, model = _axes(mesh)
    serve = mode == "serve"
    fsdp_size = 1
    for a in fsdp:
        fsdp_size *= mesh.shape[a]
    model_size = mesh.shape[model]

    def ok(dim: int, size: int) -> bool:
        return dim % size == 0 and dim >= size

    def spec_for(path, leaf) -> P:
        key = _key_of(path)
        pstr = _path_str(path)
        shape = leaf.shape
        nd = len(shape)

        def lead(n_used: int) -> Tuple[Optional[str], ...]:
            """None-padding for leading stack axes (periods / enc-dec layer)."""
            return (None,) * (nd - n_used)

        def f(dim_idx: int):
            # serve mode: replicate over fsdp except inside MoE expert banks
            if serve and "moe" not in pstr:
                return None
            return fsdp if ok(shape[dim_idx], fsdp_size) else None

        def m(dim_idx: int):
            return model if ok(shape[dim_idx], model_size) else None

        if key == "embed":  # (V, D) — V→model, D→fsdp
            return P(m(0), f(1))
        if key == "unembed":  # (D, V)
            return P(f(0), m(1))
        if key in ("wq", "wk", "wv"):  # (…, D, H|Hkv, hd)
            return P(*lead(3), f(nd - 3), m(nd - 2), None)
        if key == "wo":  # (…, H, hd, D)
            return P(*lead(3), m(nd - 3), None, f(nd - 1))
        if key == "router":  # (…, D, E) — replicated router (small, f32)
            return P(*lead(2), f(nd - 2), None)
        if key in ("w_gate", "w_up"):
            if "moe" in pstr:  # (…, E, D, F): experts→model, D→fsdp
                return P(*lead(3), m(nd - 3), f(nd - 2), None)
            return P(*lead(2), f(nd - 2), m(nd - 1))  # (…, D, F)
        if key == "w_down":
            if "moe" in pstr:  # (…, E, F, D)
                return P(*lead(3), m(nd - 3), None, f(nd - 1))
            return P(*lead(2), m(nd - 2), f(nd - 1))  # (…, F, D)
        if key == "w_in":  # mamba (…, D, zxbcdt)
            return P(*lead(2), f(nd - 2), m(nd - 1))
        if key == "w_out":  # mamba (…, d_inner, D)
            return P(*lead(2), m(nd - 2), f(nd - 1))
        if key == "conv_w":  # (…, k, conv_ch)
            return P(*lead(2), None, m(nd - 1))
        if key in ("conv_b", "norm_z"):  # (…, conv_ch)/(…, d_inner)
            return P(*lead(1), m(nd - 1))
        # norms, biases, A_log, D_skip, dt_bias, gn_*, fc, small-model leaves
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def opt_state_specs(opt_state_shape: Any, params_specs: Any) -> Any:
    """AdamW state = (step, m, v); moments share the param specs."""
    step_spec, m_spec, v_spec = P(), params_specs, params_specs
    return (step_spec, m_spec, v_spec)


# ----------------------------------------------------------------------
# batches / inputs
# ----------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Any:
    """Specs matching ``model.input_specs(shape)``."""
    fsdp, model = _axes(mesh)
    fsdp_size = 1
    for a in fsdp:
        fsdp_size *= mesh.shape[a]
    B = shape.global_batch
    b_ax = fsdp if B % fsdp_size == 0 else None

    if shape.kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            return {
                "src_embeds": P(b_ax, None, None),
                "tgt_tokens": P(b_ax, None),
                "labels": P(b_ax, None),
            }
        return {"tokens": P(b_ax, None), "labels": P(b_ax, None)}

    # decode: token + cache + pos
    return {
        "token": P(b_ax, None),
        "cache": cache_specs(cfg, shape, mesh),
        "pos": P(),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Any:
    """KV / SSM cache specs (DESIGN.md §5).

    B > 1 : batch→fsdp, sequence→model   (heads are often < model size)
    B = 1 : sequence→(fsdp+model) — the long_500k layout; each chip owns a
            contiguous S/256 slab of every layer's cache.
    """
    fsdp, model = _axes(mesh)
    fsdp_size = 1
    for a in fsdp:
        fsdp_size *= mesh.shape[a]
    B, S = shape.global_batch, shape.seq_len
    if B % fsdp_size == 0:
        # (n_periods, B, S, Hkv, hd): B→fsdp, S→model
        kv_spec = P(None, fsdp, model, None, None)
        ssm_spec = P(None, fsdp, None, None, None)  # (n, B, H, P, N)
        conv_spec = P(None, fsdp, None, None)  # (n, B, k-1, C)
        b_ax: Any = fsdp
    else:
        seq_axes = tuple(a for a in (*fsdp, model))
        kv_spec = P(None, None, seq_axes, None, None)  # S→(fsdp, model)
        ssm_spec = P(None, None, model, None, None)  # H→model
        conv_spec = P(None, None, None, model)
        b_ax = None

    def spec_for(path, leaf):
        key = _key_of(path)
        nd = len(leaf.shape)
        if key in ("k", "v"):
            if nd == 5:
                return kv_spec
            return P(*kv_spec[1:]) if nd == 4 else P(*((None,) * nd))
        if key in ("cross_k", "cross_v"):  # (L, B, S_src, Hkv, hd)
            return kv_spec
        if key == "ssm":
            return ssm_spec if nd == 5 else P(*ssm_spec[1:])
        if key == "conv":
            return conv_spec if nd == 4 else P(*conv_spec[1:])
        return P(*((None,) * nd))

    cache_shape = _cache_shape(cfg, shape)
    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def _cache_shape(cfg: ModelConfig, shape: ShapeConfig):
    from repro.models import build_model

    model = build_model(cfg)
    specs = model.input_specs(shape)
    return specs["cache"]


# ----------------------------------------------------------------------
# cohort-parallel (client-axis) sharding
# ----------------------------------------------------------------------

#: the mesh axis the cohort-parallel engine shards clients over
COHORT_AXIS = "clients"


def cohort_axis_size(mesh) -> int:
    """Validate a cohort mesh and return the ``"clients"`` axis size.

    The cohort-parallel engine accepts any mesh that carries a
    ``"clients"`` axis (a pure ``("clients",)`` mesh, or ``("clients",
    "model")`` when each client's model is additionally tensor-sharded);
    everything it shards — minibatches, gathered client states, uplink
    planes — is partitioned over that one axis.
    """
    if COHORT_AXIS not in mesh.axis_names:
        raise ValueError(
            f"cohort-parallel engine needs a {COHORT_AXIS!r} mesh axis; "
            f"got axes {mesh.axis_names} (build one with "
            f"repro.launch.mesh.make_cohort_mesh)"
        )
    return mesh.shape[COHORT_AXIS]


def padded_cohort(capacity: int, n_shards: int) -> int:
    """Static padded cohort-axis length: ``capacity`` rounded up to a
    multiple of the ``"clients"`` axis so ``shard_map`` splits evenly.
    Pad rows carry zero fold weight (see ``repro.core.flat.pad_cohort``)."""
    return -(-capacity // n_shards) * n_shards


def cohort_uplink_specs(algo, extra: Tuple[str, ...] = ()) -> dict:
    """PartitionSpec dict for a spec's cohort-stacked uplink planes: every
    plane named by ``algo.uplink_planes`` (plus ``extra`` keys, e.g. the
    per-client loss row) shards its leading axis over ``"clients"``.
    Drives the shard_map in/out specs of the cohort-parallel engine —
    derived from the registry flags, never from algorithm names."""
    return {k: P(COHORT_AXIS) for k in (*algo.uplink_planes, *extra)}


# ----------------------------------------------------------------------
# federated state
# ----------------------------------------------------------------------


def fed_state_specs(params_specs: Any, cfg_fed, mesh) -> Any:
    """Specs for FedState: params + ServerState(momentum, second_moment) are
    params-shaped; stacked client states get a leading fsdp cohort axis.
    Which planes EXIST is derived from the registered spec's state-plane
    flags, mirroring the engine's allocation."""
    from repro.core.registry import get_algorithm

    fsdp, _ = _axes(mesh)
    algo = get_algorithm(cfg_fed.algo)

    def stack(spec: P) -> P:
        return P(fsdp, *spec)

    server = dict(
        momentum=params_specs,
        second_moment=params_specs if algo.needs_second_moment else None,
        round=P(),
    )
    # stacked (N, …) planes only exist on the RESIDENT population path —
    # an out-of-core store (cfg_fed.population_store="host") keeps them in
    # host memory and FedState.client_states is None (nothing to shard)
    client_states = (jax.tree_util.tree_map(stack, params_specs)
                     if algo.needs_client_state
                     and getattr(cfg_fed, "population_store", "resident") == "resident"
                     else None)
    return dict(params=params_specs, server=server, client_states=client_states, rng=P())
