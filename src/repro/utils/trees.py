"""Pytree arithmetic helpers.

All federated algorithms in ``repro.core`` are expressed as pytree algebra
(model deltas, momenta, control variates).  These helpers keep that algebra
readable and are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """a + b, leafwise."""
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    """a - b, leafwise."""
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, s):
    """s * a for scalar s, leafwise."""
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise (BLAS axpy)."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_lerp(a, b, t):
    """(1 - t) * a + t * b, leafwise."""
    return jax.tree_util.tree_map(lambda x, y: (1.0 - t) * x + t * y, a, b)


def tree_dot(a, b):
    """Sum of elementwise products across all leaves (f32 accumulate)."""
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    """Global l2 norm across all leaves."""
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of scalar elements."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(a))


def tree_bytes(a) -> int:
    """Total bytes across leaves."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(a))


def tree_cast(a, dtype):
    """Cast every floating leaf to ``dtype`` (ints left alone)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, a)
