"""Pytree arithmetic helpers.

All federated algorithms in ``repro.core`` are expressed as pytree algebra
(model deltas, momenta, control variates).  These helpers keep that algebra
readable and are jit-safe.

``ravel_leaves`` / ``split_flat`` are the low-level flat-plane primitives:
one contiguous buffer per pytree, leaves laid out back-to-back in treedef
order.  ``repro.core.flat.FlatSpec`` builds the static offset/shape/dtype
table on top of them; the Pallas kernel wrappers use them directly.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """a + b, leafwise."""
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    """a - b, leafwise."""
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, s):
    """s * a for scalar s, leafwise."""
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise (BLAS axpy)."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_lerp(a, b, t):
    """(1 - t) * a + t * b, leafwise."""
    return jax.tree_util.tree_map(lambda x, y: (1.0 - t) * x + t * y, a, b)


def tree_dot(a, b):
    """Sum of elementwise products across all leaves (f32 accumulate)."""
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(a):
    """Global l2 norm across all leaves."""
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of scalar elements."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(a))


def tree_bytes(a) -> int:
    """Total bytes across leaves."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(a))


def ravel_leaves(leaves, dtype=jnp.float32, batch_dims: int = 0):
    """Concatenate ``leaves`` into ONE contiguous 1-D (or batched) buffer.

    ``batch_dims`` leading axes are preserved (e.g. 1 for stacked per-client
    state ``(N, *shape)`` → ``(N, P)``); everything after them is flattened
    and cast to ``dtype``.  Leaves with zero elements contribute nothing.
    """
    if not leaves:
        return jnp.zeros((0,), dtype)
    segs = [
        l.reshape(*l.shape[:batch_dims], -1).astype(dtype) for l in leaves
    ]
    if len(segs) == 1:
        return segs[0]
    return jnp.concatenate(segs, axis=-1)


def split_flat(flat, shapes: Sequence[Tuple[int, ...]], dtypes=None):
    """Inverse of :func:`ravel_leaves`: slice a flat buffer back into leaves.

    ``flat`` may carry leading batch axes — only the LAST axis is the plane
    axis.  Each slice is reshaped to ``(*lead, *shape)`` and cast to the
    matching entry of ``dtypes`` (or left in the plane dtype when None).
    Slices of one buffer are cheap under jit (no copy until fused consumers
    need one), which is what makes per-step unravel essentially free.
    """
    lead = flat.shape[:-1]
    out, off = [], 0
    for i, shape in enumerate(shapes):
        n = math.prod(shape)
        seg = jax.lax.slice_in_dim(flat, off, off + n, axis=-1)
        seg = seg.reshape(*lead, *shape)
        if dtypes is not None:
            seg = seg.astype(dtypes[i])
        out.append(seg)
        off += n
    return out


def tree_cast(a, dtype):
    """Cast every floating leaf to ``dtype`` (ints left alone)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, a)
