"""Minimal structured metric logging (CSV / stdout), no external deps."""
from __future__ import annotations

import csv
import sys
import time
from typing import Any, Dict, Iterable, List, Optional


class MetricLogger:
    """Collects rows of metrics; prints to stdout and optionally writes CSV.

    Usage::

        log = MetricLogger(["round", "algo", "test_acc"], csv_path="out.csv")
        log.log(round=0, algo="fedcm", test_acc=0.21)
        log.close()
    """

    def __init__(
        self,
        fields: Iterable[str],
        csv_path: Optional[str] = None,
        echo: bool = True,
        echo_every: int = 1,
    ) -> None:
        self.fields: List[str] = list(fields)
        self.rows: List[Dict[str, Any]] = []
        self.echo = echo
        self.echo_every = max(1, echo_every)
        self._t0 = time.time()
        self._csv_file = None
        self._writer = None
        if csv_path is not None:
            self._csv_file = open(csv_path, "w", newline="")
            self._writer = csv.DictWriter(self._csv_file, fieldnames=self.fields)
            self._writer.writeheader()

    def log(self, **kwargs: Any) -> None:
        row = {k: kwargs.get(k) for k in self.fields}
        self.rows.append(row)
        if self._writer is not None:
            self._writer.writerow(row)
            self._csv_file.flush()
        if self.echo and (len(self.rows) - 1) % self.echo_every == 0:
            msg = " ".join(
                f"{k}={_fmt(row[k])}" for k in self.fields if row[k] is not None
            )
            print(f"[{time.time() - self._t0:8.1f}s] {msg}", file=sys.stderr)

    def last(self) -> Dict[str, Any]:
        return self.rows[-1]

    def column(self, field: str) -> List[Any]:
        return [r[field] for r in self.rows]

    def close(self) -> None:
        if self._csv_file is not None:
            self._csv_file.close()
            self._csv_file = None
            self._writer = None


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)
