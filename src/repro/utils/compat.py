"""jax version-compatibility shims.

Compat policy (this repo pins nothing; the container pins jax): the code
is written against the *current* public jax API (``jax.set_mesh``,
``jax.shard_map``), and every call site that drifted across jax releases
goes through this module instead of jax directly.  Each shim resolves the
right symbol for the installed jax at call time:

* ``set_mesh(mesh)`` — context manager making ``mesh`` the ambient mesh.
  jax >= 0.5 exposes ``jax.set_mesh``; on 0.4.x a ``jax.sharding.Mesh`` is
  itself a context manager, so the mesh object is returned directly.
* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)`` —
  newer jax has top-level ``jax.shard_map`` with the ``check_vma`` kwarg;
  0.4.x has ``jax.experimental.shard_map.shard_map`` where the same knob
  is spelled ``check_rep``.
* ``make_mesh(axis_shapes, axis_names)`` — ``jax.make_mesh`` (new in
  0.4.35, device-order-aware) when present, else the
  ``mesh_utils.create_device_mesh`` + ``Mesh`` spelling.
* ``device_mesh(devices, axis_names)`` — the explicit-device-list
  ``Mesh`` constructor.  The class moved homes across releases
  (``jax.sharding.Mesh`` today, ``jax.interpreters.pxla`` before);
  constructing through here keeps call sites home-agnostic.

Resolution happens per call (cheap ``hasattr``), not at import, so tests
can exercise both paths by monkeypatching the ``jax`` module.  New code
should import from here rather than hand-rolling version checks — the
REP002 lint rule (``repro.analysis.lint``) enforces exactly that: any
direct call to the symbols above outside this module is a finding.
"""
from __future__ import annotations

import inspect

import jax


def set_mesh(mesh):
    """Context manager setting ``mesh`` as the ambient mesh.

    Usage::

        with set_mesh(mesh):
            compiled = fn.lower(...).compile()
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # jax 0.4.x: Mesh implements the context-manager protocol itself.
    return mesh


def make_mesh(axis_shapes, axis_names):
    """Version-portable ``jax.make_mesh``.

    Prefers ``jax.make_mesh`` (picks a device order that favors the
    backend's collective topology); older jax falls back to
    ``mesh_utils.create_device_mesh`` with the default device list.
    """
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    return Mesh(mesh_utils.create_device_mesh(axis_shapes), axis_names)


def device_mesh(devices, axis_names):
    """Build a ``Mesh`` over an explicit device array/list.

    The thin-but-deliberate routing point for the raw ``Mesh``
    constructor: all mesh construction in the repo goes through this
    module, so a future constructor change (e.g. ``AbstractMesh``
    plumbing) lands in one place.
    """
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices), axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """Version-portable ``shard_map``.

    Accepts the modern keyword ``check_vma``; where the resolved function
    still spells it ``check_rep`` (0.4.x experimental, and the promotion
    window where ``jax.shard_map`` exists but predates the rename) it is
    translated.  The kwarg spelling is detected from the resolved
    function's own signature — the two API changes (promotion out of
    experimental, check_rep→check_vma rename) landed in different jax
    releases, so one must not be inferred from the other.  All other
    kwargs pass through untouched.
    """
    toplevel = hasattr(jax, "shard_map")
    if toplevel:
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn

    if check_vma is not None:
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):  # C-level / wrapped callables
            params = {}
        if "check_vma" in params:
            key = "check_vma"
        elif "check_rep" in params:
            key = "check_rep"
        else:  # **kwargs-only signature: fall back on the symbol's home
            key = "check_vma" if toplevel else "check_rep"
        kwargs[key] = check_vma
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
