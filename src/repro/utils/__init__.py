from repro.utils.trees import (
    tree_add,
    tree_axpy,
    tree_dot,
    tree_norm,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    tree_size,
    tree_bytes,
    tree_cast,
)
from repro.utils.metrics import MetricLogger

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_dot",
    "tree_norm",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
    "tree_size",
    "tree_bytes",
    "tree_cast",
    "MetricLogger",
]
