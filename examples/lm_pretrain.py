"""End-to-end LM pre-training driver (deliverable (b): train a ~100M model
for a few hundred steps).

    PYTHONPATH=src python examples/lm_pretrain.py            # ~100M params
    PYTHONPATH=src python examples/lm_pretrain.py --tiny     # CI-sized

Builds a llama3-family config scaled to ~100M params, trains on synthetic
Markov-chain LM data with AdamW + warmup-cosine + grad clipping +
checkpointing, and verifies the loss drops well below the unigram entropy.
"""
import argparse
import time
from dataclasses import replace

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import lm_batch_iterator
from repro.data.synthetic import make_synthetic_lm
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import Knobs, build_train_step
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim.optimizers import adamw, warmup_cosine

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true", help="2-layer CI variant")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

base = get_config("llama3.2-1b")
if args.tiny:
    cfg = replace(base, name="llama3-tiny", n_layers=2, d_model=128, n_heads=4,
                  n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
                  dtype="float32", param_dtype="float32")
    args.steps = min(args.steps, 60)
else:
    # ~100M: 12L, d=640, 10 heads, vocab 8192
    cfg = replace(base, name="llama3-100m", n_layers=12, d_model=640, n_heads=10,
                  n_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=8192,
                  dtype="float32", param_dtype="float32")

model = build_model(cfg)
print(f"config {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params")

params = model.init(jax.random.PRNGKey(0))
sched = warmup_cosine(3e-4, 30, args.steps)
opt = adamw(sched, weight_decay=0.1)
opt_state = opt.init(params)

shape = ShapeConfig("lm", "train", args.seq, args.batch)
mesh = make_test_mesh()
bundle = build_train_step(cfg, shape, mesh, Knobs(remat="none", param_dtype="float32",
                                                  learning_rate=sched))
step_fn = jax.jit(bundle.fn, donate_argnums=(0, 1))

toks = make_synthetic_lm(cfg.vocab_size, args.seq + 1, n_seqs=4096, temperature=0.3)
it = lm_batch_iterator(toks, args.batch)

first_loss, t0 = None, time.time()
for step in range(args.steps):
    params, opt_state, m = step_fn(params, opt_state, next(it))
    if step == 0:
        first_loss = float(m["loss"])
    if (step + 1) % 25 == 0:
        toks_s = args.batch * args.seq * 25 / (time.time() - t0)
        print(f"step {step+1:4d}  loss={float(m['loss']):.4f}  "
              f"grad_norm={float(m['grad_norm']):.3f}  {toks_s:,.0f} tok/s")
        t0 = time.time()

final = float(m["loss"])
print(f"\nloss {first_loss:.3f} → {final:.3f} "
      f"(uniform = {np.log(cfg.vocab_size):.3f})")
assert final < first_loss, "training must reduce loss"
