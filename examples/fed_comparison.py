"""All six federated algorithms head-to-head (paper Tables 1–2 in miniature).

    PYTHONPATH=src python examples/fed_comparison.py [--rounds 80]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

ALGOS = ["fedcm", "fedavg", "fedadam", "scaffold", "feddyn", "mimelite"]
ETA_G = {"fedadam": 0.03}

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=80)
ap.add_argument("--clients", type=int, default=100)
ap.add_argument("--dirichlet", type=float, default=0.3)
args = ap.parse_args()

x_tr, y_tr, x_te, y_te = make_synthetic_classification(
    n_classes=20, dim=32, n_train=args.clients * 100, n_test=2000,
    separation=0.9, noise=2.0,
)
data = FederatedData(x_tr, y_tr, args.clients, dirichlet_alpha=args.dirichlet)
model = mlp_classifier((32, 128, 64, 20))
loss_fn = classification_loss(model.apply)
evaluate = make_eval_fn(model.apply)
xt, yt = jnp.asarray(x_te), jnp.asarray(y_te)

print(f"{args.clients} clients, 10% participation, Dirichlet-{args.dirichlet}, "
      f"{args.rounds} rounds\n")
results = {}
for algo in ALGOS:
    cfg = FedConfig(algo=algo, num_clients=args.clients, cohort_size=args.clients // 10,
                    local_steps=20, alpha=0.05, eta_l=0.05,
                    eta_g=ETA_G.get(algo, 1.0), participation="bernoulli",
                    weight_decay=1e-3, eta_l_decay=0.998, rounds=args.rounds)
    eng = FederatedEngine(cfg, loss_fn, batch_size=20)
    state = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    for r in range(cfg.rounds):
        state, m = eng.run_round(state, data)
    acc = evaluate(state.params, xt, yt)
    pay = eng.payload_bytes(state.params)
    results[algo] = acc
    print(f"{algo:9s} final acc={acc:.4f}   per-round per-client payload: "
          f"↓{pay['down_per_client']/2**20:.2f} MiB ↑{pay['up_per_client']/2**20:.2f} MiB")

best = max(results, key=results.get)
print(f"\nbest: {best} ({results[best]:.4f})")
