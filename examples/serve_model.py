"""Batched serving example: prefill → decode over any assigned architecture.

    PYTHONPATH=src python examples/serve_model.py --arch mamba2-1.3b
    PYTHONPATH=src python examples/serve_model.py --arch gemma3-12b --gen 48

Uses the reduced config on CPU; the identical code path serves the full
config on a TPU mesh (sharded KV cache per sharding/rules.py).
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + sys.argv[1:]

from repro.launch.serve import main

if __name__ == "__main__":
    raise SystemExit(main())
