"""FedCM over a transformer LM — the cross-silo production path.

Each "client" is an organization holding a corpus with its own token
distribution (a distinct Markov chain = natural heterogeneity).  FedCM
federates a reduced llama3-family model across them — the exact layer the
multi-pod dry-run scales to datacenter federations (DESIGN.md §2.3).

    PYTHONPATH=src python examples/federated_llm.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, get_config, reduced
from repro.core import FederatedEngine
from repro.data.synthetic import make_markov_transition, make_synthetic_lm
from repro.models import build_model

N_CLIENTS = 8
SEQ, BATCH = 64, 4

cfg = reduced(get_config("llama3.2-1b"))
model = build_model(cfg)


def loss_fn(params, batch):
    loss, _ = model.loss_fn(params, batch)
    return loss


# per-client corpora: shared base chain + per-client perturbation
base = make_markov_transition(cfg.vocab_size, temperature=0.3, seed=0)
client_tokens = []
for c in range(N_CLIENTS):
    pert = make_markov_transition(cfg.vocab_size, temperature=0.3, seed=100 + c)
    trans = 0.6 * base + 0.4 * pert  # heterogeneous but related
    client_tokens.append(make_synthetic_lm(cfg.vocab_size, SEQ + 1, 256, transition=trans, seed=c))
client_tokens = np.stack(client_tokens)  # (N, n_seq, SEQ+1)


class LMFedData:
    """Minimal FederatedData-alike for LM batches."""

    def __init__(self, toks):
        self.toks = jnp.asarray(toks)
        self.num_clients, self.n_per_client, _ = toks.shape

    def sample_round_batches(self, rng, cohort_idx, K, B):
        idx = jax.random.randint(rng, (cohort_idx.shape[0], K, B), 0, self.n_per_client)
        seqs = self.toks[cohort_idx[:, None, None], idx]  # (C, K, B, SEQ+1)
        return {"tokens": seqs[..., :-1], "labels": seqs[..., 1:]}


cfg_fed = FedConfig(algo="fedcm", num_clients=N_CLIENTS, cohort_size=3,
                    local_steps=4, alpha=0.1, eta_l=0.05, eta_g=1.0,
                    weight_decay=1e-4, rounds=20)
eng = FederatedEngine(cfg_fed, loss_fn, batch_size=BATCH)
state = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
data = LMFedData(client_tokens)

print(f"federating {cfg.name} (~{cfg.param_count()/1e6:.1f}M params) "
      f"across {N_CLIENTS} heterogeneous corpora with FedCM\n")
first = None
for r in range(cfg_fed.rounds):
    state, m = eng.run_round(state, data)
    if first is None:
        first = float(m.loss)
    if (r + 1) % 5 == 0:
        print(f"round {r+1:3d}  local-loss={float(m.loss):.4f}  "
              f"|Δ_t|={float(m.momentum_norm):.4f}  active={int(m.n_active)}")
print(f"\nloss {first:.3f} → {float(m.loss):.3f} (uniform {np.log(cfg.vocab_size):.3f})")
assert float(m.loss) < first
