"""Quickstart: FedCM in ~40 lines of public API.

    PYTHONPATH=src python examples/quickstart.py

Trains a small MLP federated across 50 heterogeneous clients with FedCM,
prints round metrics, and contrasts against FedAvg — the paper's headline
comparison at toy scale.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, make_eval_fn
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

# 1. a non-IID federated dataset (Dirichlet label skew, paper §C.1)
x_tr, y_tr, x_te, y_te = make_synthetic_classification(
    n_classes=10, dim=32, n_train=5000, n_test=1000, separation=0.9, noise=2.0
)
data = FederatedData(x_tr, y_tr, num_clients=50, dirichlet_alpha=0.3)

# 2. a model + loss
model = mlp_classifier((32, 64, 10))
loss_fn = classification_loss(model.apply)
evaluate = make_eval_fn(model.apply)

# 3. run FedCM vs FedAvg (α=1 ≡ FedAvg; α=0.05 is the paper's sweet spot)
for algo, alpha in [("fedcm", 0.05), ("fedavg", 1.0)]:
    cfg = FedConfig(algo=algo, num_clients=50, cohort_size=5, local_steps=10,
                    alpha=alpha, eta_l=0.05, eta_g=1.0, rounds=60,
                    participation="bernoulli")
    eng = FederatedEngine(cfg, loss_fn, batch_size=20)
    state = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    for r in range(cfg.rounds):
        state, m = eng.run_round(state, data)
        if (r + 1) % 20 == 0:
            acc = evaluate(state.params, jnp.asarray(x_te), jnp.asarray(y_te))
            print(f"{algo:7s} round {r+1:3d}  loss={float(m.loss):.3f}  "
                  f"test_acc={acc:.3f}  active={int(m.n_active)}  "
                  f"downlink={float(m.bytes_down)/2**20:.2f} MiB")
    print()
