"""Cohort-parallel execution engine (shard_map over the client axis).

The contract under test: a ``("clients",)`` mesh changes WHERE the cohort
executes — each device owns C/num_shards clients end-to-end and the
server fold becomes an explicit reduce-scatter/all-gather — and nothing
about WHAT is computed:

* f32-BITWISE equivalence against the unsharded flat+kernel engine,
  parametrized over every registered algorithm, for the sync scan and the
  ``(D=2, S=1)`` async pipelined scan.  Bitwise is by construction: the
  scattered fold transposes clients→plane-columns (``all_to_all``) and
  reduces over the COMPLETE cohort device-locally in the unsharded
  reduction order (a ``psum_scatter`` would pre-reduce per device and
  re-associate), and the server kernel's ≥2-step grid floor keeps its
  loop-body codegen shape-stable across shard widths.
* ragged cohorts (C not a multiple of the device count) pad with
  zero-weight rows AFTER the gathers — rng stream untouched, trailing
  ``+0.0`` fold terms exact, pad ids dropped before the client-state
  scatter (a pad id colliding with a real cohort member would make the
  duplicate-index scatter nondeterministic).

Single-device runs exercise the FULL sharded path on a 1-device mesh
(shard_map, all_to_all, scattered fold all run degenerately), so tier-1
covers the machinery; the multi-device cases skip unless the process was
started with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
multi-device CI job does).
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, list_algorithms
from repro.core.registry import (
    AlgorithmSpec,
    DirectionRow,
    FoldPass,
    register_algorithm,
    unregister_algorithm,
)
from repro.data import FederatedData, make_synthetic_classification
from repro.launch.mesh import make_cohort_mesh
from repro.models.small import classification_loss, mlp_classifier
from repro.sharding.rules import cohort_axis_size, padded_cohort

N_DEV = len(jax.devices())
multidevice = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

_DATA = {}


def _data(num_clients):
    if num_clients not in _DATA:
        x, y, *_ = make_synthetic_classification(
            n_classes=4, dim=8, n_train=3200, n_test=8
        )
        _DATA[num_clients] = FederatedData(x, y, num_clients, seed=0)
    return _DATA[num_clients]


_MODEL = mlp_classifier((8, 16, 4))
_LOSS = classification_loss(_MODEL.apply)


def _engine(algo, n_shards, cohort=16, participation="fixed", **kw):
    cfg = FedConfig(algo=algo, num_clients=32, cohort_size=cohort,
                    local_steps=2, participation=participation,
                    use_fused_kernel=True, **kw)
    mesh = make_cohort_mesh(n_shards) if n_shards else None
    eng = FederatedEngine(cfg, _LOSS, batch_size=8, cohort_mesh=mesh)
    state = eng.init(_MODEL.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    return eng, state


def _assert_tree_bitwise(a, b, what=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=what)


def _assert_state_bitwise(sharded, ref):
    _assert_tree_bitwise(sharded.params, ref.params, "params")
    _assert_tree_bitwise(sharded.server.momentum, ref.server.momentum, "momentum")
    if ref.server.second_moment is not None:
        _assert_tree_bitwise(sharded.server.second_moment,
                             ref.server.second_moment, "second_moment")
    if ref.client_states is not None:
        _assert_tree_bitwise(sharded.client_states, ref.client_states,
                             "client_states")


# ----------------------------------------------------------------------
# construction / validation (single-device)
# ----------------------------------------------------------------------


def test_cohort_mesh_requires_clients_axis():
    from repro.launch.mesh import make_test_mesh

    with pytest.raises(ValueError, match="clients"):
        cohort_axis_size(make_test_mesh())


def test_padded_cohort():
    assert padded_cohort(16, 8) == 16
    assert padded_cohort(10, 8) == 16
    assert padded_cohort(10, 1) == 10


def test_cohort_mesh_rejects_tree_and_jnp_paths():
    mesh = make_cohort_mesh(1)
    with pytest.raises(ValueError, match="use_fused_kernel"):
        FederatedEngine(FedConfig(algo="fedcm"), _LOSS, cohort_mesh=mesh)
    with pytest.raises(ValueError, match="flat"):
        FederatedEngine(FedConfig(algo="fedcm", use_flat_plane=False,
                                  use_fused_kernel=True), _LOSS,
                        cohort_mesh=mesh)


def test_cohort_mesh_rejects_client_sharding_combo():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_cohort_mesh(1)
    with pytest.raises(ValueError, match="client_sharding"):
        FederatedEngine(
            FedConfig(algo="fedcm", use_fused_kernel=True), _LOSS,
            cohort_mesh=mesh,
            client_sharding=NamedSharding(mesh, P("clients")),
        )


def test_cohort_mesh_too_many_devices_errors():
    with pytest.raises(ValueError, match="devices"):
        make_cohort_mesh(2 * N_DEV)


def test_cfg_cohort_shard_builds_mesh():
    """cohort_shard as pure config data: the engine builds the mesh."""
    eng, state = _engine("fedcm", 0, cohort_shard=1)
    assert eng.cohort_mesh is not None
    assert eng.cohort_mesh.axis_names == ("clients",)
    state, m = eng.run_rounds(state, _data(32), 2)
    assert int(state.server.round) == 2


# ----------------------------------------------------------------------
# single-shard mesh ≡ unsharded — runs everywhere, tier-1 included
# ----------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["fedcm", "scaffold", "fedadam"])
def test_single_shard_mesh_is_bitwise_unsharded(algo):
    """A 1-device ("clients",) mesh runs the FULL sharded program —
    shard_map, all_to_all transpose, scattered fold, gathers — and must
    be f32-bitwise the unsharded engine (collectives degenerate)."""
    eng_ref, st_ref = _engine(algo, 0)
    eng_sh, st_sh = _engine(algo, 1)
    ref, m_ref = eng_ref.run_rounds(st_ref, _data(32), 3)
    out, m_sh = eng_sh.run_rounds(st_sh, _data(32), 3)
    _assert_state_bitwise(out, ref)
    np.testing.assert_array_equal(np.asarray(m_sh.loss), np.asarray(m_ref.loss))
    np.testing.assert_array_equal(np.asarray(m_sh.delta_norm),
                                  np.asarray(m_ref.delta_norm))


# ----------------------------------------------------------------------
# multi-device equivalence (the multi-device CI job)
# ----------------------------------------------------------------------


@multidevice
@pytest.mark.parametrize("algo", list_algorithms())
def test_sync_sharded_bitwise_all_algorithms(algo):
    """8-way cohort sharding is f32-bitwise the unsharded sync engine for
    every registered algorithm (state AND per-round metrics)."""
    eng_ref, st_ref = _engine(algo, 0)
    eng_sh, st_sh = _engine(algo, 8)
    ref, m_ref = eng_ref.run_rounds(st_ref, _data(32), 3)
    out, m_sh = eng_sh.run_rounds(st_sh, _data(32), 3)
    _assert_state_bitwise(out, ref)
    np.testing.assert_array_equal(np.asarray(m_sh.loss), np.asarray(m_ref.loss))
    np.testing.assert_array_equal(np.asarray(m_sh.n_active),
                                  np.asarray(m_ref.n_active))
    np.testing.assert_array_equal(np.asarray(m_sh.delta_norm),
                                  np.asarray(m_ref.delta_norm))


@multidevice
@pytest.mark.parametrize("algo", list_algorithms())
def test_async_sharded_bitwise_all_algorithms(algo):
    """(D=2, S=1) overlapping-cohort schedule, 8-way sharded vs unsharded:
    the ring carries client-sharded (C_pad, P) planes and the stale fold
    goes through the scattered kernel — still f32-bitwise."""
    eng_ref, st_ref = _engine(algo, 0)
    eng_sh, st_sh = _engine(algo, 8)
    ref, _ = eng_ref.run_rounds_async(st_ref, _data(32), 4,
                                      pipeline_depth=2, staleness=1)
    out, _ = eng_sh.run_rounds_async(st_sh, _data(32), 4,
                                     pipeline_depth=2, staleness=1)
    _assert_state_bitwise(out, ref)


@multidevice
@pytest.mark.parametrize("algo,participation", [
    ("fedcm", "fixed"), ("fedcm", "bernoulli"), ("scaffold", "fixed"),
])
def test_ragged_cohort_bitwise(algo, participation):
    """C=10 over 8 devices: the cohort pads to 16 with zero-weight rows.
    Padding happens after the gathers (rng stream identical), the fold's
    trailing +0.0 terms are exact, and pad ids never reach the
    client-state scatter — so ragged sharding stays bitwise (scaffold
    pins the scatter; bernoulli pins mask-within-pad composition)."""
    eng_ref, st_ref = _engine(algo, 0, cohort=10, participation=participation)
    eng_sh, st_sh = _engine(algo, 8, cohort=10, participation=participation)
    ref, m_ref = eng_ref.run_rounds(st_ref, _data(32), 3)
    out, m_sh = eng_sh.run_rounds(st_sh, _data(32), 3)
    _assert_state_bitwise(out, ref)
    np.testing.assert_array_equal(np.asarray(m_sh.loss), np.asarray(m_ref.loss))
    np.testing.assert_array_equal(np.asarray(m_sh.n_active),
                                  np.asarray(m_ref.n_active))


@multidevice
def test_ragged_async_drain_bitwise():
    """Ragged + async + drain: the ≤D−1 in-flight padded cohorts fold in
    the epilogue dispatch through the same scattered kernel."""
    eng_ref, st_ref = _engine("scaffold", 0, cohort=10)
    eng_sh, st_sh = _engine("scaffold", 8, cohort=10)
    ref, _ = eng_ref.run_rounds_async(st_ref, _data(32), 5, pipeline_depth=3)
    out, _ = eng_sh.run_rounds_async(st_sh, _data(32), 5, pipeline_depth=3)
    _assert_state_bitwise(out, ref)


@multidevice
def test_runtime_registered_spec_with_server_fn_escape():
    """A custom spec whose round close is a ``server_fn`` escape hatch
    cannot ride the fold kernel; under cohort sharding its uplink means
    come from the scattered reduction (``cohort_mean_scatter``) and the
    escape runs replicated — bitwise vs unsharded."""
    def server_fn(cfg, params, st, mean_delta, mean_sd, mean_extra,
                  n_active, eta_l):
        new_x = jax.tree_util.tree_map(
            lambda x, d: x + cfg.eta_g * d, params, mean_delta)
        return new_x, st._replace(round=st.round + 1)

    spec = AlgorithmSpec(
        name="_test_escape",
        direction_row=DirectionRow(),
        server_fn=server_fn,
    )
    register_algorithm(spec)
    try:
        eng_ref, st_ref = _engine("_test_escape", 0)
        eng_sh, st_sh = _engine("_test_escape", 8)
        ref, _ = eng_ref.run_rounds(st_ref, _data(32), 3)
        out, _ = eng_sh.run_rounds(st_sh, _data(32), 3)
        _assert_state_bitwise(out, ref)
    finally:
        unregister_algorithm("_test_escape")


@multidevice
def test_sharded_run_round_matches_run_rounds():
    """Per-round dispatch and the fused scan agree under sharding (same
    shared _prepare_round/_flat_round_step, shard_map inside both)."""
    eng, st = _engine("fedcm", 8)
    eng2, st2 = _engine("fedcm", 8)
    for _ in range(3):
        st, _ = eng.run_round(st, _data(32))
    fused, _ = eng2.run_rounds(st2, _data(32), 3)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(fused.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
