"""Checkpoint roundtrip incl. bf16 and structure mismatch errors."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import latest_step, load_checkpoint, save_checkpoint


def _tree():
    return {
        "params": {
            "w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)), jnp.float32),
            "b16": jnp.asarray(np.random.default_rng(1).normal(size=(8,)), jnp.bfloat16),
        },
        "opt": (jnp.int32(7), [jnp.zeros((2,), jnp.float32)]),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 42, t, meta={"note": "x"})
    restored, meta = load_checkpoint(str(tmp_path), 42, t)
    assert meta["step"] == 42 and meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_latest_step(tmp_path):
    t = _tree()
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 30, t)
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 30
    restored, meta = load_checkpoint(str(tmp_path), None, t)
    assert meta["step"] == 30


def test_missing_leaf_raises(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 0, {"params": t["params"]})
    with pytest.raises(KeyError):
        load_checkpoint(str(tmp_path), 0, t)


def test_fed_state_roundtrip(tmp_path):
    """FedState (params + momentum + client states) persists across rounds —
    a server crash must not lose Δ_t."""
    from repro.configs.base import FedConfig
    from repro.core import FederatedEngine
    from repro.data import FederatedData, make_synthetic_classification
    from repro.models.small import classification_loss, mlp_classifier

    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=400, n_test=8)
    model = mlp_classifier((8, 16, 4))
    cfg = FedConfig(algo="fedcm", num_clients=8, cohort_size=3, local_steps=2)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, 8, seed=0)
    st = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    st, _ = eng.run_round(st, data)
    tree = {"params": st.params, "momentum": st.server.momentum}
    save_checkpoint(str(tmp_path), 1, tree)
    restored, _ = load_checkpoint(str(tmp_path), 1, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
