"""Checkpoint roundtrip incl. bf16 and structure mismatch errors."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import latest_step, load_checkpoint, save_checkpoint


def _tree():
    return {
        "params": {
            "w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)), jnp.float32),
            "b16": jnp.asarray(np.random.default_rng(1).normal(size=(8,)), jnp.bfloat16),
        },
        "opt": (jnp.int32(7), [jnp.zeros((2,), jnp.float32)]),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 42, t, meta={"note": "x"})
    restored, meta = load_checkpoint(str(tmp_path), 42, t)
    assert meta["step"] == 42 and meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_latest_step(tmp_path):
    t = _tree()
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 30, t)
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 30
    restored, meta = load_checkpoint(str(tmp_path), None, t)
    assert meta["step"] == 30


def test_missing_leaf_raises(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 0, {"params": t["params"]})
    with pytest.raises(KeyError):
        load_checkpoint(str(tmp_path), 0, t)


def test_fed_state_roundtrip(tmp_path):
    """FedState (params + momentum + client states) persists across rounds —
    a server crash must not lose Δ_t."""
    from repro.configs.base import FedConfig
    from repro.core import FederatedEngine
    from repro.data import FederatedData, make_synthetic_classification
    from repro.models.small import classification_loss, mlp_classifier

    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=400, n_test=8)
    model = mlp_classifier((8, 16, 4))
    cfg = FedConfig(algo="fedcm", num_clients=8, cohort_size=3, local_steps=2)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, 8, seed=0)
    st = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    st, _ = eng.run_round(st, data)
    tree = {"params": st.params, "momentum": st.server.momentum}
    save_checkpoint(str(tmp_path), 1, tree)
    restored, _ = load_checkpoint(str(tmp_path), 1, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# whole-FedState round-trips per registered algorithm family: the ckpt
# layer must carry every plane the spec's flags allocate — the stacked
# (N, …) client-state planes of a stateful spec, and the bf16 master
# cache of a sub-f32 model — such that a restored state CONTINUES the
# exact trajectory.
# ----------------------------------------------------------------------


def _fed_setup(algo, dtype=None):
    from dataclasses import replace as _r

    from repro.configs.base import FedConfig
    from repro.core import FederatedEngine
    from repro.data import FederatedData, make_synthetic_classification
    from repro.models.small import classification_loss, mlp_classifier
    from repro.utils.trees import tree_cast

    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=400, n_test=8)
    model = mlp_classifier((8, 16, 4))
    cfg = FedConfig(algo=algo, num_clients=8, cohort_size=3, local_steps=2)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, 8, seed=0)
    params = model.init(jax.random.PRNGKey(0))
    if dtype is not None:
        params = tree_cast(params, dtype)
    st = eng.init(params, jax.random.PRNGKey(1))
    return eng, data, st


def _assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x1, x2 in zip(la, lb):
        assert x1.dtype == x2.dtype
        np.testing.assert_array_equal(np.asarray(x1, np.float32),
                                      np.asarray(x2, np.float32))


@pytest.mark.parametrize("algo", ["scaffold", "fedcm"])
def test_full_fed_state_checkpoint_roundtrip(algo, tmp_path):
    """One stateful (scaffold: stacked (N, …) client planes) and one
    stateless registered algorithm: save FedState mid-run, restore, and
    CONTINUE — the resumed trajectory must equal the uninterrupted one."""
    eng, data, st = _fed_setup(algo)
    st, _ = eng.run_round(st, data)
    save_checkpoint(str(tmp_path), 1, st)
    restored, meta = load_checkpoint(str(tmp_path), 1, st)
    assert meta["step"] == 1
    _assert_states_equal(st, restored)
    if algo == "scaffold":  # the stacked (N, …) planes made the trip
        leaf = jax.tree_util.tree_leaves(restored.client_states)[0]
        assert leaf.shape[0] == 8
    # resuming from the restored state reproduces the uninterrupted run
    cont, _ = eng.run_round(st, data)
    resumed, _ = eng.run_round(restored, data)
    _assert_states_equal(cont, resumed)


def test_bf16_master_cache_checkpoint_roundtrip(tmp_path):
    """Sub-f32 params attach the f32 master planes (FedState.master); a
    checkpoint must round-trip them so a restored run continues the f32
    trajectory instead of re-rounding at the restore boundary."""
    eng, data, st = _fed_setup("fedcm", dtype=jnp.bfloat16)
    st, _ = eng.run_round(st, data)
    assert st.master is not None  # bf16 leaves ⇒ master cache attached
    save_checkpoint(str(tmp_path), 7, st)
    restored, _ = load_checkpoint(str(tmp_path), 7, st)
    assert restored.master is not None
    _assert_states_equal(st.master, restored.master)
    _assert_states_equal(st.params, restored.params)
    # continuing from the restored master == continuing uninterrupted,
    # BITWISE (both resume from the same f32 planes)
    cont, _ = eng.run_round(st, data)
    resumed, _ = eng.run_round(restored, data)
    _assert_states_equal(cont, resumed)
    # dropping the master on restore (the documented footgun) re-rounds —
    # the trajectory measurably forks, which is why ckpt must carry it
    forked, _ = eng.run_round(restored._replace(master=None), data)
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(cont.params),
                               jax.tree_util.tree_leaves(forked.params)))
    assert diff > 0.0
