"""Per-architecture smoke tests (deliverable (f)).

For every assigned architecture: instantiate the REDUCED same-family
variant (≤2 layers, d_model ≤ 512, ≤ 4 experts), run one forward and one
train step on CPU, assert output shapes and finiteness; run one decode step
where the family decodes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import Knobs, build_train_step
from repro.models import build_model

SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=16, global_batch=2)


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return arch, cfg, model, params


def _assert_finite(tree, what):
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), what


def test_reduced_respects_assignment_contract():
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4


def test_full_configs_match_assignment():
    """The FULL configs must carry the exact assigned hyperparameters."""
    expect = {
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }
    for arch, (L, D, H, Hkv, F, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == D, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == Hkv, arch
        assert cfg.d_ff == F, arch
        assert cfg.vocab_size == V, arch
    # family-specific extras
    assert get_config("llama4-maverick-400b-a17b").n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").top_k == 1
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("gemma3-12b").local_global_pattern == (5, 1)
    assert get_config("qwen3-14b").qk_norm
    assert get_config("seamless-m4t-large-v2").is_encoder_decoder


def test_forward_shapes_and_finiteness(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = model.dummy_batch(SMOKE_SHAPE)
    if cfg.is_encoder_decoder:
        logits, _, aux = model.apply(params, batch)
    else:
        logits, cache, aux = model.apply(params, batch["tokens"])
        assert cache is None
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab_size), arch
    _assert_finite(logits, f"{arch} logits")
    _assert_finite(aux, f"{arch} aux")


def test_train_step_runs_and_is_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    mesh = make_test_mesh()
    knobs = Knobs(remat="none", param_dtype="float32", learning_rate=1e-3)
    bundle = build_train_step(cfg, SMOKE_SHAPE, mesh, knobs)
    from repro.optim.optimizers import adamw

    opt = adamw(1e-3)
    opt_state = opt.init(params)
    batch = model.dummy_batch(SMOKE_SHAPE)
    new_params, new_opt, metrics = jax.jit(bundle.fn)(params, opt_state, batch)
    assert float(metrics["loss"]) > 0.0, arch
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    _assert_finite(new_params, f"{arch} updated params")
    # params must actually move
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params))
    )
    assert moved, arch


def test_loss_decreases_over_a_few_steps(arch_setup):
    arch, cfg, model, params = arch_setup
    from repro.optim.optimizers import adamw
    from repro.utils.trees import tree_add

    opt = adamw(3e-3)
    opt_state = opt.init(params)
    batch = model.dummy_batch(SMOKE_SHAPE)

    @jax.jit
    def step(p, s):
        (l, _), g = jax.value_and_grad(lambda q: model.loss_fn(q, batch), has_aux=True)(p)
        u, s = opt.update(g, s, p)
        return tree_add(p, u), s, l

    losses = []
    for _ in range(8):
        params, opt_state, l = step(params, opt_state)
        losses.append(float(l))
    assert losses[-1] < losses[0], (arch, losses)


def test_decode_step(arch_setup):
    arch, cfg, model, params = arch_setup
    B, maxlen = 2, 8
    if cfg.is_encoder_decoder:
        from repro.models import encdec

        enc_out = encdec.encode(
            params, jnp.zeros((B, 4, cfg.d_model), jnp.float32), cfg=cfg
        )
        cache = model.init_cache(params, B, maxlen, enc_out=enc_out)
    else:
        cache = model.init_cache(params, B, maxlen)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = model.decode_step(params, tok, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    _assert_finite(logits, f"{arch} decode logits")
    # cache structure unchanged
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


def test_input_specs_are_abstract(arch_setup):
    arch, cfg, model, params = arch_setup
    for name, kind, S, B in [("train_s", "train", 32, 2), ("dec_s", "decode", 32, 2)]:
        specs = model.input_specs(ShapeConfig(name, kind, S, B))
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, name)


def test_param_count_estimate_close_to_actual(arch_setup):
    """cfg.param_count() (used for MODEL_FLOPS) ≈ the real init'd count."""
    arch, cfg, model, params = arch_setup
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    est = cfg.param_count()
    assert abs(est - actual) / actual < 0.35, (arch, est, actual)
