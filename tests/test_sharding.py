"""Sharding rules: structural checks on CPU (the real lowering is exercised
by launch/dryrun.py over 512 placeholder devices — subprocess-tested in
test_dryrun_subprocess.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, reduced
from repro.models import build_model
from repro.sharding.rules import batch_specs, cache_specs, param_specs


class FakeMesh:
    """Shape-only stand-in for a production mesh (no devices needed)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ["llama3.2-1b", "dbrx-132b", "mamba2-1.3b",
                                  "zamba2-7b", "seamless-m4t-large-v2", "gemma3-12b"])
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["1pod", "2pod"])
def test_param_specs_cover_every_leaf_with_matching_rank(arch, mesh):
    cfg = get_config(arch)
    model = build_model(cfg)
    sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_specs(sds, cfg, mesh)
    flat_s, _ = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(sds)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        # divisibility: every named axis divides its dim
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[d] % size == 0, (arch, spec, leaf.shape, d)


def test_big_matrices_are_sharded_not_replicated():
    """The FSDP story requires every large leaf to actually shard."""
    cfg = get_config("qwen3-14b")
    model = build_model(cfg)
    sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_specs(sds, cfg, MESH)
    flat = jax.tree_util.tree_flatten_with_path(specs, is_leaf=lambda x: isinstance(x, P))[0]
    sizes = {tuple(str(getattr(e, "key", e)) for e in path): s for path, s in flat}
    leaves = {tuple(str(getattr(e, "key", e)) for e in path): l
              for path, l in jax.tree_util.tree_flatten_with_path(sds)[0]}
    for path, leaf in leaves.items():
        if leaf.size >= (1 << 22):  # ≥ 4M params ⇒ must shard
            spec = sizes[path]
            assert any(e is not None for e in spec), (path, leaf.shape, spec)


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_batch_specs_match_input_specs_structure(shape_name):
    cfg = get_config("llama3.2-1b")
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    ins = model.input_specs(shape)
    specs = batch_specs(cfg, shape, MESH)
    assert jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, ins)
    ) == jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, specs, is_leaf=lambda x: isinstance(x, P))
    )


def test_long500k_cache_shards_sequence():
    """B=1 ⇒ cache sequence dim carries BOTH data and model axes."""
    cfg = get_config("gemma3-12b")
    shape = SHAPES["long_500k"]
    specs = cache_specs(cfg, shape, MESH)
    kv = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    five_dim = [s for s in kv if isinstance(s, P) and len(s) == 5]
    assert five_dim, "no kv specs found"
    for s in five_dim:
        seq_entry = s[2]
        assert seq_entry is not None and "model" in (
            seq_entry if isinstance(seq_entry, tuple) else (seq_entry,)
        ), s


def test_decode32k_cache_shards_batch_and_sequence():
    cfg = get_config("llama3.2-1b")
    shape = SHAPES["decode_32k"]
    specs = cache_specs(cfg, shape, MESH)
    kv = [s for s in jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
          if isinstance(s, P) and len(s) == 5]
    for s in kv:
        assert s[1] is not None  # batch sharded over fsdp
        assert s[2] == "model"  # sequence over model
