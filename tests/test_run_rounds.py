"""Fused multi-round engine (engine.run_rounds / run_rounds_async) tests.

* trajectory equivalence: run_rounds(n) must reproduce the sequential
  run_round × n trajectory (params, server momentum, metrics) to tolerance
  for fedcm + fedavg + scaffold (stateful) — same rng threading, same
  round-step implementation, so the tolerance is tight.
* compile-count: N rounds execute as ONE trace of the scanned program, and
  a second call with the same shapes does not retrace.
* fused Pallas kernel path (cfg.use_fused_kernel): matches the unfused
  tree_map arithmetic (ref.py is the kernel's own oracle in test_kernels).
* client_sharding: constraining the cohort axis changes nothing numerically.
* async pipelined engine (run_rounds_async): the degenerate schedule
  (pipeline_depth=1, staleness=0) must be EXACTLY run_rounds — f32
  bitwise — for every algorithm on both the jnp and kernel paths; depth>1
  fills/folds/drains correctly; staleness>0 still converges on a
  heterogeneous quadratic toy problem.
* bf16 master plane: sequential run_round and fused run_rounds share the
  f32 master-plane carry, so their bf16 trajectories stay within
  f32-noise tolerance (the legacy per-boundary re-rounding was a bf16 ulp
  per round — the bound here would catch its return).
"""
from dataclasses import replace
from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.trace import assert_trace_budget
from repro.configs.base import FedConfig
from repro.core import FederatedEngine, list_algorithms
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier
from repro.utils.trees import tree_cast

N_ROUNDS = 5


def _setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, eng, data, model


def _fresh_state(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


def _assert_trees_close(a, b, rtol=2e-5, atol=1e-6):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol)


@pytest.mark.parametrize("algo", ["fedcm", "fedavg", "scaffold"])
def test_run_rounds_matches_sequential_trajectory(algo):
    cfg, eng, data, model = _setup(algo)
    st = _fresh_state(eng, model)
    seq_metrics = []
    for _ in range(N_ROUNDS):
        st, m = eng.run_round(st, data)
        seq_metrics.append(m)

    fused_st, fused_m = eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS)

    _assert_trees_close(st.params, fused_st.params)
    _assert_trees_close(st.server.momentum, fused_st.server.momentum)
    if cfg.algo == "scaffold":
        _assert_trees_close(st.client_states, fused_st.client_states)
    assert int(fused_st.server.round) == N_ROUNDS
    # stacked per-round metrics match the sequential per-round values
    assert fused_m.loss.shape == (N_ROUNDS,)
    np.testing.assert_allclose(
        np.array([float(m.loss) for m in seq_metrics]),
        np.asarray(fused_m.loss), rtol=2e-5,
    )
    np.testing.assert_allclose(
        np.array([float(m.eta_l) for m in seq_metrics]),
        np.asarray(fused_m.eta_l), rtol=1e-6,
    )
    np.testing.assert_array_equal(
        np.array([float(m.n_active) for m in seq_metrics]),
        np.asarray(fused_m.n_active),
    )


def test_run_rounds_is_one_trace_and_caches():
    """The per-path budget itself lives in repro.analysis.trace
    (TRACE_BUDGET): N rounds are ONE trace of the scan, a same-shapes
    call is cached, a new static n_rounds is one new path."""
    _, eng, data, model = _setup("fedcm")
    assert_trace_budget(
        eng, "run_rounds_traces",
        calls=[
            lambda: eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS),
            lambda: eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS),
            lambda: eng.run_rounds(_fresh_state(eng, model), data,
                                   N_ROUNDS + 1),
        ],
        expected_paths=[1, 1, 2],
    )


def test_run_rounds_rejects_nonpositive():
    _, eng, data, model = _setup("fedcm")
    with pytest.raises(ValueError):
        eng.run_rounds(_fresh_state(eng, model), data, 0)


@pytest.mark.parametrize("algo", list_algorithms())
def test_fused_kernel_path_matches_reference(algo):
    """Flat engine + Pallas kernels (fed_direction local steps, fused
    server fold-row passes + pure post-steps) vs the unfused jnp flat
    path — for EVERY registered algorithm (the registry parametrizes)."""
    cfg, eng, data, model = _setup(algo)
    engk = FederatedEngine(replace(cfg, use_fused_kernel=True), eng.loss_fn, batch_size=8)
    s_ref, m_ref = eng.run_rounds(_fresh_state(eng, model), data, 3)
    s_k, m_k = engk.run_rounds(_fresh_state(engk, model), data, 3)
    _assert_trees_close(s_ref.params, s_k.params, rtol=1e-5, atol=1e-6)
    _assert_trees_close(s_ref.server.momentum, s_k.server.momentum, rtol=1e-5, atol=1e-6)
    if s_ref.client_states is not None:
        _assert_trees_close(s_ref.client_states, s_k.client_states, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_ref.loss), np.asarray(m_k.loss), rtol=1e-5)


def test_fused_server_kernel_honors_aggregate_dtype():
    """Regression: the fused server kernel must quantize the uplink planes
    with cfg.aggregate_dtype before reducing, like both jnp paths do."""
    cfg, eng, data, model = _setup("fedcm")
    cfg_bf = replace(cfg, aggregate_dtype="bfloat16")
    engs = {
        "jnp_bf16": FederatedEngine(cfg_bf, eng.loss_fn, batch_size=8),
        "kern_bf16": FederatedEngine(replace(cfg_bf, use_fused_kernel=True),
                                     eng.loss_fn, batch_size=8),
        "kern_f32": FederatedEngine(replace(cfg, use_fused_kernel=True),
                                    eng.loss_fn, batch_size=8),
    }
    out = {k: e.run_rounds(_fresh_state(e, model), data, 2)[0] for k, e in engs.items()}
    # bf16 aggregation on the kernel path tracks the jnp bf16 path…
    _assert_trees_close(out["kern_bf16"].params, out["jnp_bf16"].params,
                        rtol=2e-2, atol=2e-2)
    # …and actually differs from unquantized f32 aggregation
    diff = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(out["kern_bf16"].params),
                        jax.tree_util.tree_leaves(out["kern_f32"].params))
    )
    assert diff > 0.0


def test_tree_path_ignores_fused_kernel_flag():
    """The legacy whole-tree fedcm_update launch is RETIRED: on the tree
    path ``use_fused_kernel`` is inert, so the trajectories must be
    bitwise identical (any reappearing kernel route would show up as the
    old tolerance-level drift)."""
    cfg, eng, data, model = _setup("fedcm")
    cfg_t = replace(cfg, use_flat_plane=False)
    eng_t = FederatedEngine(cfg_t, eng.loss_fn, batch_size=8)
    eng_tk = FederatedEngine(replace(cfg_t, use_fused_kernel=True), eng.loss_fn, batch_size=8)
    s_ref, _ = eng_t.run_rounds(_fresh_state(eng_t, model), data, 3)
    s_k, _ = eng_tk.run_rounds(_fresh_state(eng_tk, model), data, 3)
    for a, b in zip(jax.tree_util.tree_leaves(s_ref.params),
                    jax.tree_util.tree_leaves(s_k.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_client_sharding_constraint_is_numerically_inert():
    cfg, eng, data, model = _setup("fedcm")
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    engs = FederatedEngine(
        cfg, eng.loss_fn, batch_size=8,
        client_sharding=NamedSharding(mesh, P("data")),
    )
    s_ref, _ = eng.run_rounds(_fresh_state(eng, model), data, 3)
    s_sh, _ = engs.run_rounds(_fresh_state(engs, model), data, 3)
    _assert_trees_close(s_ref.params, s_sh.params, rtol=1e-5, atol=1e-7)
    # per-round path honors the constraint too
    st = _fresh_state(engs, model)
    st, m = engs.run_round(st, data)
    assert np.isfinite(float(m.loss))


def test_run_rounds_bernoulli_participation():
    """Masked (bernoulli) cohorts also survive the fused path."""
    cfg, eng, data, model = _setup("fedcm", participation="bernoulli",
                                   num_clients=20, cohort_size=5)
    st, ms = eng.run_rounds(_fresh_state(eng, model), data, 4)
    assert np.all(np.asarray(ms.n_active) >= 1)
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


# ----------------------------------------------------------------------
# async pipelined engine (run_rounds_async)
# ----------------------------------------------------------------------


def _assert_state_equal(a, b, check_master=False):
    """f32-exact (bitwise) equality of two FedStates' learned state."""
    pairs = [(a.params, b.params), (a.server.momentum, b.server.momentum),
             (a.client_states, b.client_states)]
    if check_master:
        pairs.append((a.master, b.master))
    for ta, tb in pairs:
        for la, lb in zip(jax.tree_util.tree_leaves(ta), jax.tree_util.tree_leaves(tb)):
            np.testing.assert_array_equal(
                np.asarray(la, np.float32), np.asarray(lb, np.float32)
            )


@pytest.mark.parametrize("algo", list_algorithms())
def test_async_depth1_is_exactly_run_rounds(algo):
    """run_rounds_async(D=1, S=0) IS the sync schedule: EVERY registered
    algorithm's trajectory AND per-round metrics must match run_rounds
    f32-EXACTLY (bitwise) — the ring degenerates to push-then-pop of the
    same slot."""
    cfg, eng, data, model = _setup(algo)
    s_sync, m_sync = eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS)
    s_async, m_async = eng.run_rounds_async(
        _fresh_state(eng, model), data, N_ROUNDS, pipeline_depth=1, staleness=0
    )
    _assert_state_equal(s_sync, s_async)
    assert int(s_async.server.round) == N_ROUNDS
    for field in ("loss", "n_active", "delta_norm", "momentum_norm",
                  "eta_l", "bytes_down", "bytes_up"):
        np.testing.assert_array_equal(
            np.asarray(getattr(m_sync, field)),
            np.asarray(getattr(m_async, field)), err_msg=field,
        )
    assert np.all(np.asarray(m_async.folded) == 1.0)


@pytest.mark.parametrize("algo", ["fedcm", "scaffold", "fedadam"])
def test_async_depth1_kernel_path_is_exactly_run_rounds(algo):
    """Same degenerate-schedule contract on the fused-kernel path (the
    staleness-discount SMEM scalar is 1.0 there — must stay exact).
    fedadam covers a spec whose round-close is fold pass + pure post."""
    cfg, eng, data, model = _setup(algo, use_fused_kernel=True)
    s_sync, _ = eng.run_rounds(_fresh_state(eng, model), data, 3)
    s_async, _ = eng.run_rounds_async(
        _fresh_state(eng, model), data, 3, pipeline_depth=1, staleness=0
    )
    _assert_state_equal(s_sync, s_async)


@pytest.mark.parametrize("use_fused_kernel", [False, True])
def test_async_pipeline_fill_fold_drain(use_fused_kernel):
    """D>1: the first D−1 rounds launch without folding (pipeline fill),
    every later round folds exactly one cohort, and the drain applies the
    leftover in-flight work (drain=False must differ — work discarded)."""
    cfg, eng, data, model = _setup("fedcm", use_fused_kernel=use_fused_kernel)
    D = 3
    st, ms = eng.run_rounds_async(_fresh_state(eng, model), data, 6,
                                  pipeline_depth=D, staleness=0)
    folded = np.asarray(ms.folded)
    np.testing.assert_array_equal(folded, [0, 0, 1, 1, 1, 1])
    assert np.all(np.asarray(ms.delta_norm)[:D - 1] == 0.0)
    assert np.all(np.asarray(ms.delta_norm)[D - 1:] > 0.0)
    assert int(st.server.round) == 6
    st_nodrain, _ = eng.run_rounds_async(_fresh_state(eng, model), data, 6,
                                         pipeline_depth=D, staleness=0,
                                         drain=False)
    diff = sum(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(st.params),
                               jax.tree_util.tree_leaves(st_nodrain.params)))
    assert diff > 0.0
    for s in (st, st_nodrain):
        for leaf in jax.tree_util.tree_leaves(s.params):
            assert bool(jnp.all(jnp.isfinite(leaf)))


def test_async_shorter_than_warmup_run():
    """n_rounds < D−1: nothing ever folds in-scan — the whole run is
    unrolled pipeline fill and the ring holds every launch; the drain must
    still apply each of them (in launch order)."""
    cfg, eng, data, model = _setup("fedcm")
    st, ms = eng.run_rounds_async(_fresh_state(eng, model), data, 2,
                                  pipeline_depth=4, staleness=0)
    np.testing.assert_array_equal(np.asarray(ms.folded), [0, 0])
    # both launched cohorts were drained: params moved off the init point
    st0 = _fresh_state(eng, model)
    diff = sum(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(st.params),
                               jax.tree_util.tree_leaves(st0.params)))
    assert diff > 0.0


def test_async_requires_flat_plane_and_validates_args():
    cfg, eng, data, model = _setup("fedcm")
    eng_tree = FederatedEngine(replace(cfg, use_flat_plane=False),
                               eng.loss_fn, batch_size=8)
    with pytest.raises(ValueError, match="use_flat_plane"):
        eng_tree.run_rounds_async(_fresh_state(eng_tree, model), data, 2)
    with pytest.raises(ValueError):
        eng.run_rounds_async(_fresh_state(eng, model), data, 0)
    with pytest.raises(ValueError):
        eng.run_rounds_async(_fresh_state(eng, model), data, 2, pipeline_depth=0)
    with pytest.raises(ValueError):
        eng.run_rounds_async(_fresh_state(eng, model), data, 2, staleness=-1)
    with pytest.raises(ValueError, match="eval_every"):
        eng.run_rounds_async(_fresh_state(eng, model), data, 2, eval_every=1)


def test_async_is_one_trace_and_caches():
    """Async budget pinned through the same repro.analysis.trace checker:
    same statics are cached, a new static depth is one new path."""
    _, eng, data, model = _setup("fedcm")
    assert_trace_budget(
        eng, "run_rounds_async_traces",
        calls=[
            lambda: eng.run_rounds_async(_fresh_state(eng, model), data, 4,
                                         pipeline_depth=2),
            lambda: eng.run_rounds_async(_fresh_state(eng, model), data, 4,
                                         pipeline_depth=2),
            lambda: eng.run_rounds_async(_fresh_state(eng, model), data, 4,
                                         pipeline_depth=4),
        ],
        expected_paths=[1, 1, 2],
    )


def test_async_inscan_eval_cadence():
    """eval_every moves eval inside the scan: accuracies appear exactly on
    cadence, −1.0 sentinels elsewhere, and the on-cadence values agree
    with the host-side make_eval_fn on the same params."""
    from repro.core import make_eval_fn

    cfg, eng, data, model = _setup("fedcm")
    x_te = np.asarray(data.client_x.reshape(-1, data.client_x.shape[-1]))[:64]
    y_te = np.asarray(data.client_y.reshape(-1))[:64]
    st, ms = eng.run_rounds_async(
        _fresh_state(eng, model), data, 6, pipeline_depth=2, eval_every=3,
        eval_data=(x_te, y_te), predict_fn=model.apply, eval_batch_size=16,
    )
    accs = np.asarray(ms.eval_acc)
    on = np.arange(6) % 3 == 2
    assert np.all(accs[~on] == -1.0)
    assert np.all(accs[on] >= 0.0)
    # NOTE: in-scan eval sees the pre-drain params of its round; the final
    # on-cadence eval runs at t=5 BEFORE the drain fold, so compare
    # against the no-drain trajectory's params
    st_nodrain, _ = eng.run_rounds_async(
        _fresh_state(eng, model), data, 6, pipeline_depth=2, drain=False
    )
    host_eval = make_eval_fn(model.apply, batch_size=16)
    np.testing.assert_allclose(
        accs[-1], host_eval(st_nodrain.params, x_te, y_te), rtol=1e-6
    )


def _quadratic_setup(staleness_discount=1.0, **cfg_kw):
    """Heterogeneous quadratic toy: client i holds points around its own
    center c_i; loss(w, batch) = ½·mean‖w − x‖² so the global optimum is
    the mean of all client centers.  Convergence here isolates the round
    machinery from model nonconvexity."""
    rng = np.random.default_rng(0)
    N, n_per, d = 12, 32, 6
    # heterogeneous client centers around a NONZERO global mean — the
    # zeros init must be far from w* so convergence is measurable
    centers = 3.0 + rng.normal(size=(N, 1, d)) * 2.0
    pts = centers + 0.1 * rng.normal(size=(N, n_per, d))
    data = SimpleNamespace(client_x=jnp.asarray(pts, jnp.float32),
                           client_y=jnp.zeros((N, n_per), jnp.int32))

    def quad_loss(params, batch):
        diff = params["w"][None, :] - batch["x"]
        return 0.5 * jnp.mean(jnp.sum(diff * diff, axis=-1))

    base = dict(algo="fedcm", num_clients=N, cohort_size=4, local_steps=4,
                participation="fixed", eta_l=0.2, eta_l_decay=1.0,
                weight_decay=0.0, staleness_discount=staleness_discount)
    base.update(cfg_kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, quad_loss, batch_size=8)
    w_star = np.asarray(pts.reshape(-1, d).mean(axis=0))
    state = eng.init({"w": jnp.zeros((d,), jnp.float32)}, jax.random.PRNGKey(3))
    return eng, data, state, w_star


@pytest.mark.parametrize("depth,stale", [(2, 1), (4, 2)])
def test_async_staleness_converges_on_quadratic(depth, stale):
    """Staleness>0 convergence smoke (the paper's robustness claim carried
    to the async schedule): overlapped cohorts descending against stale
    momentum still drive the quadratic toy to its optimum."""
    eng, data, state, w_star = _quadratic_setup(staleness_discount=0.9)
    d0 = float(np.linalg.norm(w_star))  # ‖w_0 − w*‖, w_0 = 0
    state, ms = eng.run_rounds_async(state, data, 80, pipeline_depth=depth,
                                     staleness=stale)
    w = np.asarray(state.params["w"])
    assert np.all(np.isfinite(w))
    d_final = float(np.linalg.norm(w - w_star))
    assert d_final < 0.15 * d0, (d_final, d0)
    # and the loss decayed toward the minibatch-variance floor
    losses = np.asarray(ms.loss)
    assert losses[-1] < 0.25 * losses[0]


# ----------------------------------------------------------------------
# bf16 master plane (run_round vs run_rounds divergence regression)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("use_fused_kernel", [False, True])
def test_bf16_run_round_matches_run_rounds_master_plane(use_fused_kernel):
    """Sequential run_round must continue the SAME f32 master planes the
    run_rounds scan carries (FedState.master), so their bf16 trajectories
    stay within an occasional single-ulp bf16 rounding flip of each other
    (f32-level noise pushed across a rounding boundary; ≤5e-4 here).  The
    legacy behaviour re-rounded the carried state to bf16 at EVERY
    run_round boundary — a ~4e-3 divergence that this bound would catch
    coming back."""
    cfg, eng, data, model = _setup("fedcm", use_fused_kernel=use_fused_kernel)
    p_bf16 = tree_cast(model.init(jax.random.PRNGKey(0)), jnp.bfloat16)

    st = eng.init(p_bf16, jax.random.PRNGKey(1))
    assert st.master is not None  # sub-f32 leaves attach the master planes
    for _ in range(4):
        st, _ = eng.run_round(st, data)
    st_f, _ = eng.run_rounds(eng.init(p_bf16, jax.random.PRNGKey(1)), data, 4)
    assert st_f.master is not None
    for a, b in zip(jax.tree_util.tree_leaves((st.params, st.server.momentum)),
                    jax.tree_util.tree_leaves((st_f.params, st_f.server.momentum))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0, atol=5e-4)

    # the re-rounding contract can't silently widen: stripping the master
    # (the legacy behaviour) must show the bf16-ulp boundary divergence
    st_legacy = eng.init(p_bf16, jax.random.PRNGKey(1))._replace(master=None)
    for _ in range(4):
        st_legacy, _ = eng.run_round(st_legacy, data)
        st_legacy = st_legacy._replace(master=None)
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(st_legacy.params),
                               jax.tree_util.tree_leaves(st_f.params)))
    assert diff > 5e-4, diff


def test_f32_states_carry_no_master():
    """All-f32 trees must NOT pay for the master planes (the ravel is
    exact; treedef stability keeps the trace cache warm)."""
    cfg, eng, data, model = _setup("fedcm")
    st = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    assert st.master is None
    st, _ = eng.run_round(st, data)
    assert st.master is None
    st, _ = eng.run_rounds(st, data, 2)
    assert st.master is None
