"""Fused multi-round engine (engine.run_rounds) regression tests.

* trajectory equivalence: run_rounds(n) must reproduce the sequential
  run_round × n trajectory (params, server momentum, metrics) to tolerance
  for fedcm + fedavg + scaffold (stateful) — same rng threading, same
  round-step implementation, so the tolerance is tight.
* compile-count: N rounds execute as ONE trace of the scanned program, and
  a second call with the same shapes does not retrace.
* fused Pallas kernel path (cfg.use_fused_kernel): matches the unfused
  tree_map arithmetic (ref.py is the kernel's own oracle in test_kernels).
* client_sharding: constraining the cohort axis changes nothing numerically.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FedConfig
from repro.core import FederatedEngine
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

N_ROUNDS = 5


def _setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, eng, data, model


def _fresh_state(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


def _assert_trees_close(a, b, rtol=2e-5, atol=1e-6):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol)


@pytest.mark.parametrize("algo", ["fedcm", "fedavg", "scaffold"])
def test_run_rounds_matches_sequential_trajectory(algo):
    cfg, eng, data, model = _setup(algo)
    st = _fresh_state(eng, model)
    seq_metrics = []
    for _ in range(N_ROUNDS):
        st, m = eng.run_round(st, data)
        seq_metrics.append(m)

    fused_st, fused_m = eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS)

    _assert_trees_close(st.params, fused_st.params)
    _assert_trees_close(st.server.momentum, fused_st.server.momentum)
    if cfg.algo == "scaffold":
        _assert_trees_close(st.client_states, fused_st.client_states)
    assert int(fused_st.server.round) == N_ROUNDS
    # stacked per-round metrics match the sequential per-round values
    assert fused_m.loss.shape == (N_ROUNDS,)
    np.testing.assert_allclose(
        np.array([float(m.loss) for m in seq_metrics]),
        np.asarray(fused_m.loss), rtol=2e-5,
    )
    np.testing.assert_allclose(
        np.array([float(m.eta_l) for m in seq_metrics]),
        np.asarray(fused_m.eta_l), rtol=1e-6,
    )
    np.testing.assert_array_equal(
        np.array([float(m.n_active) for m in seq_metrics]),
        np.asarray(fused_m.n_active),
    )


def test_run_rounds_is_one_trace_and_caches():
    _, eng, data, model = _setup("fedcm")
    assert eng.run_rounds_traces == 0
    eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS)
    assert eng.run_rounds_traces == 1  # N rounds, ONE trace of the scan
    eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS)
    assert eng.run_rounds_traces == 1  # same shapes: cached, no retrace
    eng.run_rounds(_fresh_state(eng, model), data, N_ROUNDS + 1)
    assert eng.run_rounds_traces == 2  # new static n_rounds: one new trace


def test_run_rounds_rejects_nonpositive():
    _, eng, data, model = _setup("fedcm")
    with pytest.raises(ValueError):
        eng.run_rounds(_fresh_state(eng, model), data, 0)


@pytest.mark.parametrize(
    "algo", ["fedcm", "mimelite", "fedavg", "fedadam", "scaffold", "feddyn"]
)
def test_fused_kernel_path_matches_reference(algo):
    """Flat engine + Pallas kernels (fed_direction local steps, fused
    server round-close where covered) vs the unfused jnp flat path."""
    cfg, eng, data, model = _setup(algo)
    engk = FederatedEngine(replace(cfg, use_fused_kernel=True), eng.loss_fn, batch_size=8)
    s_ref, m_ref = eng.run_rounds(_fresh_state(eng, model), data, 3)
    s_k, m_k = engk.run_rounds(_fresh_state(engk, model), data, 3)
    _assert_trees_close(s_ref.params, s_k.params, rtol=1e-5, atol=1e-6)
    _assert_trees_close(s_ref.server.momentum, s_k.server.momentum, rtol=1e-5, atol=1e-6)
    if s_ref.client_states is not None:
        _assert_trees_close(s_ref.client_states, s_k.client_states, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_ref.loss), np.asarray(m_k.loss), rtol=1e-5)


def test_fused_server_kernel_honors_aggregate_dtype():
    """Regression: the fused server kernel must quantize the uplink planes
    with cfg.aggregate_dtype before reducing, like both jnp paths do."""
    cfg, eng, data, model = _setup("fedcm")
    cfg_bf = replace(cfg, aggregate_dtype="bfloat16")
    engs = {
        "jnp_bf16": FederatedEngine(cfg_bf, eng.loss_fn, batch_size=8),
        "kern_bf16": FederatedEngine(replace(cfg_bf, use_fused_kernel=True),
                                     eng.loss_fn, batch_size=8),
        "kern_f32": FederatedEngine(replace(cfg, use_fused_kernel=True),
                                    eng.loss_fn, batch_size=8),
    }
    out = {k: e.run_rounds(_fresh_state(e, model), data, 2)[0] for k, e in engs.items()}
    # bf16 aggregation on the kernel path tracks the jnp bf16 path…
    _assert_trees_close(out["kern_bf16"].params, out["jnp_bf16"].params,
                        rtol=2e-2, atol=2e-2)
    # …and actually differs from unquantized f32 aggregation
    diff = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(out["kern_bf16"].params),
                        jax.tree_util.tree_leaves(out["kern_f32"].params))
    )
    assert diff > 0.0


def test_tree_path_fused_kernel_still_matches():
    """Legacy tree-path kernel route (fedcm_step_tree) stays correct."""
    cfg, eng, data, model = _setup("fedcm")
    cfg_t = replace(cfg, use_flat_plane=False)
    eng_t = FederatedEngine(cfg_t, eng.loss_fn, batch_size=8)
    eng_tk = FederatedEngine(replace(cfg_t, use_fused_kernel=True), eng.loss_fn, batch_size=8)
    s_ref, _ = eng_t.run_rounds(_fresh_state(eng_t, model), data, 3)
    s_k, _ = eng_tk.run_rounds(_fresh_state(eng_tk, model), data, 3)
    _assert_trees_close(s_ref.params, s_k.params, rtol=1e-5, atol=1e-7)


def test_client_sharding_constraint_is_numerically_inert():
    cfg, eng, data, model = _setup("fedcm")
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    engs = FederatedEngine(
        cfg, eng.loss_fn, batch_size=8,
        client_sharding=NamedSharding(mesh, P("data")),
    )
    s_ref, _ = eng.run_rounds(_fresh_state(eng, model), data, 3)
    s_sh, _ = engs.run_rounds(_fresh_state(engs, model), data, 3)
    _assert_trees_close(s_ref.params, s_sh.params, rtol=1e-5, atol=1e-7)
    # per-round path honors the constraint too
    st = _fresh_state(engs, model)
    st, m = engs.run_round(st, data)
    assert np.isfinite(float(m.loss))


def test_run_rounds_bernoulli_participation():
    """Masked (bernoulli) cohorts also survive the fused path."""
    cfg, eng, data, model = _setup("fedcm", participation="bernoulli",
                                   num_clients=20, cohort_size=5)
    st, ms = eng.run_rounds(_fresh_state(eng, model), data, 4)
    assert np.all(np.asarray(ms.n_active) >= 1)
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
