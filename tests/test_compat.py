"""Regression tests for the jax version-compat shims (repro.utils.compat).

Both resolution paths are covered: the real installed-jax path (executed),
and the "newer jax" path (simulated by monkeypatching top-level ``jax``
attributes — the shims resolve per call, so this exercises the dispatch
logic without needing a second jax install).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.utils import compat


def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


# ----------------------------------------------------------------------
# installed-jax path (whatever this container has)
# ----------------------------------------------------------------------


def test_set_mesh_context_enters_and_exits():
    mesh = _one_device_mesh()
    with compat.set_mesh(mesh):
        # a trivial lowering under the ambient mesh must work
        out = jax.jit(lambda x: x + 1)(jnp.zeros((4,)))
    np.testing.assert_array_equal(np.asarray(out), np.ones((4,)))


def test_shard_map_runs_with_check_vma_kwarg():
    mesh = _one_device_mesh()

    def body(x):
        return jax.lax.psum(x, "data")  # 1-device axis: identity

    f = compat.shard_map(
        body, mesh=mesh, in_specs=(P("data"),), out_specs=P(), check_vma=False
    )
    out = f(jnp.arange(4, dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.arange(4, dtype=np.float32))


def test_shard_map_psum_value():
    mesh = _one_device_mesh()

    def body(x):
        return jnp.sum(x, keepdims=True)

    f = compat.shard_map(
        body, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"), check_vma=False
    )
    out = f(jnp.arange(4, dtype=jnp.float32))
    assert float(out[0]) == pytest.approx(6.0)


# ----------------------------------------------------------------------
# newer-jax path (simulated: top-level jax.set_mesh / jax.shard_map exist)
# ----------------------------------------------------------------------


def test_set_mesh_prefers_toplevel_api(monkeypatch):
    sentinel = object()
    calls = []

    def fake_set_mesh(mesh):
        calls.append(mesh)
        return sentinel

    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    mesh = _one_device_mesh()
    assert compat.set_mesh(mesh) is sentinel
    assert calls == [mesh]


def test_set_mesh_falls_back_to_mesh_context(monkeypatch):
    monkeypatch.delattr(jax, "set_mesh", raising=False)
    mesh = _one_device_mesh()
    # 0.4.x path: the Mesh object itself is the context manager
    assert compat.set_mesh(mesh) is mesh


def test_shard_map_prefers_toplevel_api_and_passes_check_vma(monkeypatch):
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
        seen.update(kwargs, mesh=mesh)
        return lambda *a: "new-path"

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    mesh = _one_device_mesh()
    f = compat.shard_map(
        lambda x: x, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False
    )
    assert f(jnp.zeros(())) == "new-path"
    assert seen["check_vma"] is False
    assert seen["mesh"] is mesh


def test_shard_map_old_path_translates_check_vma_to_check_rep(monkeypatch):
    """Dispatch check: without jax.shard_map, the experimental symbol is used
    and ``check_vma`` is respelled ``check_rep``.  (A fake stands in for the
    experimental function — the real one re-enters its own module-global
    name internally, so wrapping it would intercept internal calls too.)"""
    monkeypatch.delattr(jax, "shard_map", raising=False)
    import jax.experimental.shard_map as sm

    seen = {}

    def fake(f, *, mesh, in_specs, out_specs, **kwargs):
        seen.update(kwargs, mesh=mesh)
        return lambda *a: "old-path"

    monkeypatch.setattr(sm, "shard_map", fake)
    mesh = _one_device_mesh()
    f = compat.shard_map(
        lambda x: x * 2, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False,
    )
    assert f(jnp.ones((4,))) == "old-path"
    assert seen["check_rep"] is False
    assert "check_vma" not in seen
    assert seen["mesh"] is mesh


def test_shard_map_old_path_executes_for_real():
    """End-to-end on the installed 0.4.x jax: the translated check_rep path
    actually runs (this is what models/layers.py depends on)."""
    if hasattr(jax, "shard_map"):
        pytest.skip("installed jax has top-level shard_map; old path unreachable")
    mesh = _one_device_mesh()
    f = compat.shard_map(
        lambda x: x * 2, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False,
    )
    out = f(jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4,)))
