"""Data layer: Dirichlet partitioner (paper §C.1) + synthetic generators."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.data import (
    FederatedData,
    dirichlet_partition,
    heterogeneity_score,
    make_synthetic_classification,
    make_synthetic_lm,
)
from repro.data.synthetic import make_markov_transition


@given(
    num_clients=st.sampled_from([5, 10, 20]),
    alpha=st.sampled_from([0.1, 0.6, 10.0, float("inf")]),
    seed=st.integers(0, 3),
)
@settings(max_examples=12, deadline=None)
def test_partition_is_balanced_and_disjoint(num_clients, alpha, seed):
    labels = np.random.default_rng(seed).integers(0, 10, size=2000)
    parts = dirichlet_partition(labels, num_clients, alpha, seed=seed)
    per = 2000 // num_clients
    all_idx = np.concatenate(parts)
    assert all(len(p) == per for p in parts)
    assert len(np.unique(all_idx)) == len(all_idx)  # disjoint


def test_heterogeneity_monotone_in_alpha():
    """Smaller Dirichlet α ⇒ more heterogeneity (paper §C.1)."""
    labels = np.random.default_rng(0).integers(0, 10, size=20000)
    scores = []
    for alpha in [0.05, 0.6, 10.0, float("inf")]:
        parts = dirichlet_partition(labels, 50, alpha, seed=1)
        scores.append(heterogeneity_score(labels, parts, 10))
    assert scores[0] > scores[1] > scores[2] > scores[3]
    assert scores[3] < 0.1  # IID ≈ homogeneous


def test_iid_split_is_uniform():
    labels = np.random.default_rng(0).integers(0, 10, size=10000)
    parts = dirichlet_partition(labels, 10, float("inf"), seed=0)
    s = heterogeneity_score(labels, parts, 10)
    assert s < 0.08


def test_synthetic_classification_learnable():
    """A linear probe must beat chance comfortably — the task has signal."""
    x_tr, y_tr, x_te, y_te = make_synthetic_classification(
        n_classes=4, dim=16, n_train=4000, n_test=1000, seed=0
    )
    # one-shot ridge regression to one-hot targets
    X = np.concatenate([x_tr, np.ones((len(x_tr), 1))], axis=1)
    Y = np.eye(4)[y_tr]
    W = np.linalg.lstsq(X.T @ X + 1e-3 * np.eye(17), X.T @ Y, rcond=None)[0]
    Xt = np.concatenate([x_te, np.ones((len(x_te), 1))], axis=1)
    acc = float(np.mean((Xt @ W).argmax(1) == y_te))
    assert acc > 0.5, acc


def test_markov_lm_has_low_entropy():
    """temperature≪1 ⇒ next-token is predictable from the previous token."""
    trans = make_markov_transition(64, temperature=0.2, seed=0)
    toks = make_synthetic_lm(64, 128, 256, transition=trans, seed=1)
    # empirical bigram agreement with the argmax of the chain
    prev, nxt = toks[:, :-1].ravel(), toks[:, 1:].ravel()
    agree = np.mean(trans.argmax(1)[prev] == nxt)
    assert agree > 0.5, agree


def test_federated_data_round_batches_shapes():
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=10)
    fed = FederatedData(x, y, num_clients=8, dirichlet_alpha=0.6, seed=0)
    ids = jnp.array([0, 3, 5])
    b = fed.sample_round_batches(jax.random.PRNGKey(0), ids, local_steps=4, batch_size=16)
    assert b["x"].shape == (3, 4, 16, 8)
    assert b["y"].shape == (3, 4, 16)
    # samples really come from the named client's shard
    for j, cid in enumerate([0, 3, 5]):
        pool = np.asarray(fed.client_x[cid])
        got = np.asarray(b["x"][j]).reshape(-1, 8)
        # every sampled row must appear in the client's pool
        assert all(
            np.isclose(pool, row, atol=0).all(axis=1).any() for row in got[:8]
        )


def test_clients_hold_distinct_data_under_skew():
    x, y, *_ = make_synthetic_classification(n_classes=10, dim=8, n_train=5000, n_test=10)
    fed = FederatedData(x, y, num_clients=10, dirichlet_alpha=0.1, seed=0)
    dists = []
    for c in range(10):
        yy = np.asarray(fed.client_y[c])
        dists.append(np.bincount(yy, minlength=10) / len(yy))
    dists = np.stack(dists)
    # at alpha=0.1, client label distributions differ strongly
    pair_tv = 0.5 * np.abs(dists[0] - dists[1]).sum()
    assert dists.max() > 0.4 or pair_tv > 0.3
