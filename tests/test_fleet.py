"""Fleet subsystem: telemetry store, atomic publication, hot-swap serving,
health endpoint, and the fed_train --serve driver.

The two contracts the subsystem exists for are tested head-on, not
asserted in docs:

  * NO TORN READS — a subscriber polling while a publisher races never
    observes a half-written version (each loaded payload is uniformly one
    version), and versions are strictly monotone
    (``test_publisher_no_torn_reads_under_concurrent_publish``).
  * SWAP ATOMIC UNDER DECODE LOAD — every ``serve_loop`` decode step runs
    against exactly one complete params version; the swap lands at a step
    boundary (``test_serve_loop_every_step_sees_one_complete_version``).

The driver e2e additionally pins that --serve is observation-only: same
config, same seed, with and without the fleet → identical final accuracy.
"""
import json
import os
import threading
import time
import urllib.request

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.fleet.check import check
from repro.fleet.health import FleetStatus, HealthServer, probe
from repro.fleet.publisher import (
    ModelPublisher,
    ParamsWatch,
    load_published,
    read_pointer,
)
from repro.fleet.telemetry import (
    FAULT_COUNTERS,
    ROUND_FIELDS,
    TELEMETRY_SCHEMA,
    TelemetryStore,
    events,
    replay,
    round_rows,
)
from repro.launch.serve import ServeStats, serve_loop


# --------------------------------------------------------------- telemetry
class TestTelemetry:
    def test_round_trip_and_header_schema(self, tmp_path):
        p = tmp_path / "t.jsonl"
        with TelemetryStore(p, meta={"algo": "fedcm"}) as ts:
            for i in range(4):
                ts.round_row(round=i + 1, rounds_per_s=2.5, cohort=8,
                             loss=0.5 - 0.1 * i, n_dropped=i)
            ts.event("publish", version=1, step=2)
        header, rows, truncated = replay(p)
        assert header["schema"] == TELEMETRY_SCHEMA
        assert header["kind"] == "fleet-telemetry"
        assert header["meta"]["algo"] == "fedcm"
        assert not truncated
        rnds = round_rows(rows)
        assert [r["round"] for r in rnds] == [1, 2, 3, 4]
        # every row is schema-complete: all ROUND_FIELDS present
        assert all(set(ROUND_FIELDS) <= set(r) for r in rnds)
        assert rnds[2]["n_dropped"] == 2
        assert events(rows, "publish")[0]["version"] == 1

    def test_unknown_round_field_refused(self, tmp_path):
        with TelemetryStore(tmp_path / "t.jsonl") as ts:
            with pytest.raises(ValueError, match="unknown round-row"):
                ts.round_row(round=1, not_a_field=3)

    def test_partial_final_line_tolerated_after_kill(self, tmp_path):
        """A kill mid-append leaves an unterminated (or torn-but-
        terminated) final line; replay must drop exactly that line."""
        p = tmp_path / "t.jsonl"
        with TelemetryStore(p) as ts:
            for i in range(3):
                ts.round_row(round=i + 1, rounds_per_s=1.0)
        with open(p, "ab") as f:  # simulated kill mid-write: no newline
            f.write(b'{"event":"round","round":4,"rounds_per')
        header, rows, truncated = replay(p)
        assert truncated and len(round_rows(rows)) == 3
        # a terminated-but-unparseable final line is equally tolerated
        with open(p, "ab") as f:
            f.write(b"\n")  # terminate the torn json → still unparseable
        header, rows, truncated = replay(p)
        assert truncated and len(round_rows(rows)) == 3

    def test_corrupt_middle_line_raises(self, tmp_path):
        p = tmp_path / "t.jsonl"
        with TelemetryStore(p) as ts:
            ts.round_row(round=1, rounds_per_s=1.0)
        with open(p, "ab") as f:
            f.write(b"garbage-not-json\n")
        with TelemetryStore(p, resume=True) as ts:
            pass  # resume validates the header only
        with open(p, "ab") as f:
            f.write(b'{"event":"round","round":2,"rounds_per_s":1.0}\n')
        with pytest.raises(ValueError, match="non-final"):
            replay(p)

    def test_schema_mismatch_refused(self, tmp_path):
        p = tmp_path / "t.jsonl"
        p.write_text('{"schema": 999, "kind": "fleet-telemetry"}\n')
        with pytest.raises(ValueError, match="schema"):
            replay(p)
        p.write_text('{"schema": 1, "kind": "something-else"}\n')
        with pytest.raises(ValueError, match="not a fleet"):
            replay(p)

    def test_resume_appends_after_existing_rows(self, tmp_path):
        p = tmp_path / "t.jsonl"
        with TelemetryStore(p, meta={"run": 1}) as ts:
            ts.round_row(round=1, rounds_per_s=1.0)
        with TelemetryStore(p, resume=True) as ts:
            ts.round_row(round=2, rounds_per_s=1.0)
        header, rows, _ = replay(p)
        assert header["meta"]["run"] == 1  # original header kept
        assert [r["round"] for r in round_rows(rows)] == [1, 2]

    def test_fault_counters_are_round_metrics_fields(self):
        """The telemetry row schema and the engine's RoundMetrics must
        name the same counters — the --dryrun agreement contract."""
        from repro.core import RoundMetrics

        assert set(FAULT_COUNTERS) <= set(RoundMetrics._fields)
        assert set(FAULT_COUNTERS) <= set(ROUND_FIELDS)


# --------------------------------------------------------------- publisher
def _const_params(v: float, n: int = 64):
    return {"w": np.full((n,), float(v), np.float32),
            "b": np.full((4,), float(v), np.float32)}


class TestPublisher:
    def test_versions_monotone_and_pointer(self, tmp_path):
        pub = ModelPublisher(tmp_path, retain=3)
        assert pub.version == 0 and read_pointer(tmp_path) is None
        assert pub.publish(_const_params(1), step=10) == 1
        assert pub.publish(_const_params(2), step=20) == 2
        ptr = read_pointer(tmp_path)
        assert ptr["version"] == 2 and ptr["step"] == 20
        v, params, meta = load_published(str(tmp_path), _const_params(0))
        assert v == 2 and float(params["w"][0]) == 2.0 and meta["step"] == 20

    def test_retention_ring_bounded(self, tmp_path):
        pub = ModelPublisher(tmp_path, retain=2)
        for v in range(1, 7):
            pub.publish(_const_params(v), step=v)
        payloads = sorted(n for n in os.listdir(tmp_path)
                          if n.endswith(".msgpack"))
        assert payloads == ["step_5.msgpack", "step_6.msgpack"]
        with pytest.raises(ValueError, match="retain"):
            ModelPublisher(tmp_path / "x", retain=1)

    def test_reopen_continues_version_sequence(self, tmp_path):
        ModelPublisher(tmp_path).publish(_const_params(1), step=1)
        pub2 = ModelPublisher(tmp_path)
        assert pub2.version == 1
        assert pub2.publish(_const_params(2), step=2) == 2

    def test_watch_poll_none_when_unchanged(self, tmp_path):
        w = ParamsWatch(str(tmp_path), template=_const_params(0))
        assert w.poll() is None  # nothing published yet
        pub = ModelPublisher(tmp_path)
        pub.publish(_const_params(1), step=1)
        got = w.poll()
        assert got is not None and got[0] == 1
        assert w.poll() is None  # unchanged → cheap no-op
        pub.publish(_const_params(2), step=2)
        pub.publish(_const_params(3), step=3)
        v, params, _ = w.poll()  # skipped v2 entirely — latest wins
        assert v == 3 and float(params["w"][0]) == 3.0

    def test_watch_survives_retention_outrunning_it(self, tmp_path):
        """A watcher that lags more than ``retain`` publishes behind must
        recover (re-resolve the pointer), not crash on the unlinked file."""
        pub = ModelPublisher(tmp_path, retain=2)
        w = ParamsWatch(str(tmp_path), template=_const_params(0))
        for v in range(1, 9):
            pub.publish(_const_params(v), step=v)
        v, params, _ = w.poll()
        assert v == 8 and float(params["w"][0]) == 8.0

    def test_no_torn_reads_under_concurrent_publish(self, tmp_path):
        """The headline atomicity contract: a reader polling while a
        writer publishes at full speed (retention active) never sees a
        half-written payload — every loaded version is uniformly one
        constant, equal to its version — and versions strictly increase."""
        pub = ModelPublisher(tmp_path, retain=2)
        stop = threading.Event()
        failures = []

        def writer():
            try:
                for v in range(1, 80):
                    pub.publish(_const_params(v), step=v)
            finally:
                stop.set()

        def reader():
            w = ParamsWatch(str(tmp_path), template=_const_params(0))
            last = 0
            try:
                while not stop.is_set() or w.poll() is not None:
                    got = w.poll()
                    if got is None:
                        continue
                    v, params, _ = got
                    leaves = np.concatenate(
                        [np.ravel(params["w"]), np.ravel(params["b"])]
                    )
                    if not (v > last and np.all(leaves == float(v))):
                        failures.append((v, last, leaves[:4].tolist()))
                    last = v
            except Exception as e:  # noqa: BLE001 — recorded for the assert
                failures.append(repr(e))

        threads = [threading.Thread(target=writer),
                   threading.Thread(target=reader)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not failures, failures


# -------------------------------------------------------------- serve_loop
class _ScriptedProvider:
    """Publishes version v at the provider-call count scripted for it."""

    def __init__(self, schedule):  # {call_index: version}
        self.schedule = dict(schedule)
        self.calls = 0

    def poll(self):
        self.calls += 1
        v = self.schedule.get(self.calls)
        if v is None:
            return None
        return v, {"version_tag": v}, {}


class TestServeLoop:
    def test_every_step_sees_one_complete_version(self):
        """Atomicity under load: the params a decode step runs against
        are exactly one published version — the swap happens between
        steps, never within one — and the served sequence is monotone."""
        provider = _ScriptedProvider({5: 2, 6: 3, 17: 4})
        seen = []

        def step(params, st, i):
            seen.append(params["version_tag"])
            return st

        params, stats = serve_loop(
            {"version_tag": 1}, step, params_provider=provider,
            steps_per_session=10, max_sessions=3, version=1,
        )
        assert stats.steps == 30 and stats.sessions == 3
        assert stats.swaps == 3 and stats.versions == [2, 3, 4]
        # monotone served versions, one tag per step, no interleaving
        assert seen == sorted(seen)
        assert set(seen) == {1, 2, 3, 4}
        assert params["version_tag"] == stats.served_version == 4

    def test_mid_session_swaps_counted_separately(self):
        # call 1 = session-boundary check (step 0) → NOT under decode load;
        # later calls land before step i>0 of a live session → under load
        provider = _ScriptedProvider({1: 2, 7: 3})
        _, stats = serve_loop(
            {"v": 1}, lambda p, st, i: st, params_provider=provider,
            steps_per_session=10, max_sessions=1, version=1,
        )
        assert stats.swaps == 2
        assert stats.swaps_mid_session == 1

    def test_stop_event_breaks_between_steps(self):
        stop = threading.Event()
        count = {"steps": 0}

        def step(p, st, i):
            count["steps"] += 1
            if count["steps"] >= 7:
                stop.set()
            return st

        _, stats = serve_loop(
            {"v": 1}, step, steps_per_session=5, max_sessions=None,
            stop_event=stop,
        )
        assert count["steps"] == 7  # stop honored at the next boundary
        assert stats.sessions == 1  # the interrupted session isn't counted

    def test_static_serving_without_provider(self):
        _, stats = serve_loop(
            {"v": 1}, lambda p, st, i: st, steps_per_session=4,
            max_sessions=2,
        )
        assert stats.steps == 8 and stats.swaps == 0


# ------------------------------------------------------------------ health
class TestHealth:
    def test_healthz_fresh_vs_stale(self, tmp_path):
        status = FleetStatus(deadline_s=30.0)
        server = HealthServer(status)
        try:
            code, body = probe(server.url)
            assert code == 503 and body["status"] == "stale"  # no round yet
            status.round_done(5, rounds_per_s=2.0, cohort=8)
            code, body = probe(server.url)
            assert code == 200 and body["status"] == "ok"
            assert body["last_round"] == 5
            assert body["last_round_age_s"] < 30.0
            # age past the deadline → stale again
            status.update(last_round_unix=time.time() - 31.0)
            code, body = probe(server.url)
            assert code == 503
        finally:
            server.stop()

    def test_metrics_and_tail_and_404(self, tmp_path):
        status = FleetStatus(deadline_s=30.0)
        status.round_done(2, rounds_per_s=4.0, cohort=6)
        status.bump_counters({"n_dropped": 3, "quorum_skipped": 1})
        with TelemetryStore(tmp_path / "t.jsonl") as ts:
            for i in range(5):
                ts.round_row(round=i + 1, rounds_per_s=4.0)
            server = HealthServer(status, ts.tail)
            try:
                with urllib.request.urlopen(server.url + "/metrics") as r:
                    text = r.read().decode()
                assert "fleet_n_dropped_total 3.0" in text
                assert "fleet_quorum_skipped_total 1.0" in text
                assert "fleet_rounds_per_second 4.0" in text
                with urllib.request.urlopen(
                    server.url + "/telemetry/tail?n=2"
                ) as r:
                    tail = json.loads(r.read())
                assert [t["round"] for t in tail] == [4, 5]
                code, _ = probe(server.url, "/nope")
                assert code == 404
            finally:
                server.stop()


# ------------------------------------------------------- driver end-to-end
def _tiny_mlp():
    from repro.models.small import mlp_classifier

    model = mlp_classifier((8, 16, 4))
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)),
                    jnp.float32)
    return model, params, x


class TestDriver:
    def test_driver_serves_publishes_and_records(self, tmp_path):
        from repro.fleet.driver import FleetDriver

        model, params, x = _tiny_mlp()
        fleet = FleetDriver(ckpt_dir=str(tmp_path), deadline_s=60.0,
                            meta={"algo": "test"})
        assert fleet.publish(0, params) == 1
        fleet.start_serving(model.apply, template=params, batch_x=x,
                            steps_per_session=64, step_sleep_s=0.002)
        # publish two more versions while decode is running
        for step in (2, 4):
            time.sleep(0.15)
            fleet.publish(step, jax.tree_util.tree_map(
                lambda a: a + 0.1, params))
        host = {
            "loss": np.asarray([0.5, 0.4]), "n_active": np.asarray([4, 5]),
            "n_dropped": np.asarray([1.0, 0.0]),
            "bytes_up": np.asarray([848.0, 848.0]),
        }
        fleet.record_chunk(start_round=0, host=host, seconds=0.5,
                           eval_acc=0.75, published_version=3)
        assert fleet.drain_swaps(timeout_s=20.0)
        summary = fleet.stop()
        assert summary["swaps"] >= 2
        assert summary["served_version"] == 3
        assert summary["health_status"] == 200
        header, rows, truncated = replay(fleet.telemetry.path)
        assert not truncated
        rnds = round_rows(rows)
        assert [r["round"] for r in rnds] == [1, 2]
        assert rnds[0]["n_dropped"] == 1.0
        assert all(r["uplink_bytes"] == 848.0 for r in rnds)
        assert rnds[1]["eval_acc"] == 0.75
        assert rnds[1]["published_version"] == 3
        assert [e["version"] for e in events(rows, "publish")] == [1, 2, 3]
        s = events(rows, "serve_summary")[-1]
        assert s["swaps"] >= 2 and s["steps"] > 0
        hp = events(rows, "health_probe")[-1]
        assert hp["status"] == 200 and hp["last_round_age_s"] < 60.0
        # the checker CI runs agrees
        assert check(fleet.telemetry.path, min_rounds=2, min_swaps=1,
                     require_health=True) == []

    def test_check_fails_loudly(self, tmp_path):
        with TelemetryStore(tmp_path / "t.jsonl") as ts:
            ts.round_row(round=1, rounds_per_s=1.0)
        fails = check(str(tmp_path / "t.jsonl"), min_rounds=3, min_swaps=2,
                      require_health=True)
        assert len(fails) == 4
        assert any("round rows" in f for f in fails)
        assert any("uplink_bytes" in f for f in fails)
        assert any("serve_summary" in f for f in fails)
        assert any("health" in f for f in fails)


class TestFedTrainServe:
    def test_serve_run_matches_non_serve_run(self, tmp_path):
        """The fleet loop end-to-end through fed_train's chunk loop — and
        the observation-only contract: the SAME tiny run with and without
        --serve reaches the identical final accuracy."""
        from repro.configs.base import FedConfig
        from repro.launch.fed_train import run_federated

        def run(serve: bool, ckpt_dir: str):
            cfg = FedConfig(algo="fedcm", num_clients=12, cohort_size=4,
                            local_steps=2, rounds=4, seed=3)
            return run_federated(
                cfg, 0.6, eval_every=2, seed=3, echo=False,
                n_train=2_000, n_test=500,
                ckpt_every=2, ckpt_dir=ckpt_dir, serve=serve,
                round_deadline_s=60.0,
            )

        acc_plain, _ = run(False, str(tmp_path / "plain"))
        acc_serve, _ = run(True, str(tmp_path / "fleet"))
        assert acc_serve == acc_plain  # fleet is observation-only
        path = tmp_path / "fleet" / "telemetry.jsonl"
        header, rows, truncated = replay(path)
        assert not truncated
        rnds = round_rows(rows)
        assert [r["round"] for r in rnds] == [1, 2, 3, 4]
        assert all(r["rounds_per_s"] > 0 for r in rnds)
        assert all(r["cohort"] > 0 for r in rnds)
        # cadence evals land on chunk-final rounds
        assert rnds[1]["eval_acc"] is not None
        assert rnds[3]["eval_acc"] == pytest.approx(acc_serve, abs=1e-6)
        # publications: v1 init + one per ckpt boundary (rounds 2 and 4)
        assert [e["version"] for e in events(rows, "publish")] == [1, 2, 3]
        assert events(rows, "health_probe")[-1]["status"] == 200
        assert check(str(path), min_rounds=4, require_health=True) == []

    def test_serve_flag_validations(self):
        from repro.launch.fed_train import main

        with pytest.raises(SystemExit) as e:  # needs --ckpt-every
            main(["--dryrun", "--serve"])
        assert e.value.code == 2
        with pytest.raises(SystemExit):  # needs --ckpt-dir
            main(["--dryrun", "--serve", "--ckpt-every", "2"])
        with pytest.raises(SystemExit):  # retain floor
            main(["--dryrun", "--serve", "--ckpt-every", "2",
                  "--ckpt-dir", "/tmp/x", "--publish-retain", "1"])
