"""Tests for repro.analysis — Layer-1 lint fixtures + Layer-2 contracts.

Each REP rule gets at least one failing, one passing, and one suppressed
fixture, run through ``lint_sources`` with virtual repo paths so scope
rules (REP001's core/kernels/sharding gate, REP002's compat exemption)
are exercised too.  The Layer-2 tests prove the checkers *detect*
violations (deliberately broken donation, a bf16 scan carry), not just
that the shipped engine passes them.
"""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis.lint import (
    REP001,
    REP002,
    REP003,
    REP004,
    REP005,
    lint_repo,
    lint_sources,
    load_baseline,
    sync_readme,
)
from repro.analysis.trace import (
    TRACE_BUDGET,
    collective_primitives,
    donation_alias_report,
    quick_contracts,
    scan_carry_violations,
)

CORE = "src/repro/core/fixture.py"
LAUNCH = "src/repro/launch/fixture.py"


def rules_of(findings):
    return sorted({f.rule for f in findings})


def run_rule(rule_cls, src, path=CORE):
    return lint_sources({path: src}, rules=[rule_cls()])


# --------------------------------------------------------------- REP001
REP001_BAD = """
def pick(cfg):
    if cfg.algo == "fedcm":
        return 1
    return 0
"""

REP001_OK = """
def pick(cfg, registry):
    spec = registry.get(cfg.algo)
    return spec.local_update
"""


def test_rep001_flags_name_keyed_branch():
    assert rules_of(run_rule(REP001, REP001_BAD)) == ["REP001"]


def test_rep001_passes_registry_dispatch():
    assert run_rule(REP001, REP001_OK) == []


def test_rep001_scope_is_core_kernels_sharding_only():
    assert run_rule(REP001, REP001_BAD, path=LAUNCH) == []


def test_rep001_suppressed_with_reason():
    src = REP001_BAD.replace(
        '== "fedcm":', '== "fedcm":  # repro: noqa REP001 -- legacy shim')
    assert run_rule(REP001, src) == []


def test_reasonless_noqa_is_ignored():
    src = REP001_BAD.replace('== "fedcm":', '== "fedcm":  # repro: noqa REP001')
    assert rules_of(run_rule(REP001, src)) == ["REP001"]


# --------------------------------------------------------------- REP002
REP002_BAD = """
import jax
from jax.sharding import Mesh

def build(devs):
    jax.make_mesh((1,), ("x",))
    return Mesh(devs, ("clients",))
"""

REP002_OK = """
from repro.utils.compat import device_mesh, make_mesh

def build(devs):
    make_mesh((1,), ("x",))
    return device_mesh(devs, ("clients",))
"""


def test_rep002_flags_direct_mesh_apis():
    found = run_rule(REP002, REP002_BAD, path=LAUNCH)
    assert len(found) == 2 and rules_of(found) == ["REP002"]
    assert "compat" in found[0].message


def test_rep002_passes_compat_routed():
    assert run_rule(REP002, REP002_OK, path=LAUNCH) == []


def test_rep002_exempts_compat_module_itself():
    assert run_rule(REP002, REP002_BAD, path="src/repro/utils/compat.py") == []


def test_rep002_suppressed_with_reason():
    src = REP002_BAD.replace(
        '("x",))', '("x",))  # repro: noqa REP002 -- version probe').replace(
        '("clients",))', '("clients",))  # repro: noqa REP002 -- version probe')
    assert run_rule(REP002, src, path=LAUNCH) == []


# --------------------------------------------------------------- REP003
REP003_BAD = """
import jax

@jax.jit
def step(x):
    return float(x)
"""

# reachability: the sync lives in a helper the jitted root calls
REP003_BAD_INDIRECT = """
import jax

def helper(x):
    return x.item()

@jax.jit
def step(x):
    return helper(x)
"""

REP003_OK = """
import jax

@jax.jit
def step(x, cfg):
    return x * float(cfg.lr)

def host_side(x):
    return float(x)
"""


def test_rep003_flags_host_sync_in_jit():
    assert rules_of(run_rule(REP003, REP003_BAD)) == ["REP003"]


def test_rep003_resolves_call_graph():
    found = run_rule(REP003, REP003_BAD_INDIRECT)
    assert rules_of(found) == ["REP003"] and ".item()" in found[0].message


def test_rep003_static_config_and_host_code_pass():
    # float(cfg.lr) is static at trace time; host_side is unreachable
    assert run_rule(REP003, REP003_OK) == []


def test_rep003_suppressed_with_reason():
    src = REP003_BAD.replace(
        "float(x)", "float(x)  # repro: noqa REP003 -- fixture")
    assert run_rule(REP003, src) == []


# --------------------------------------------------------------- REP004
REP004_BAD = """
import jax

def f(key):
    a = jax.random.normal(key)
    b = jax.random.normal(key)
    return a + b
"""

REP004_BAD_RAW = """
import jax

def f(state):
    return jax.random.normal(state.rng)
"""

REP004_OK = """
import jax

def f(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1)
    b = jax.random.normal(k2)
    return a + b
"""


def test_rep004_flags_key_reuse():
    found = run_rule(REP004, REP004_BAD)
    assert rules_of(found) == ["REP004"] and "more than one" in found[0].message


def test_rep004_flags_stored_raw_key():
    found = run_rule(REP004, REP004_BAD_RAW)
    assert rules_of(found) == ["REP004"] and "state.rng" in found[0].message


def test_rep004_passes_split_discipline():
    assert run_rule(REP004, REP004_OK) == []


def test_rep004_suppressed_with_reason():
    src = REP004_BAD.replace(
        "b = jax.random.normal(key)",
        "b = jax.random.normal(key)  # repro: noqa REP004 -- fixture")
    assert run_rule(REP004, src) == []


# --------------------------------------------------------------- REP005
REP005_BAD = """
import jax.numpy as jnp

def fold(x):
    return jnp.sum(x.astype(jnp.bfloat16))
"""

REP005_OK = """
import jax.numpy as jnp

def fold(x, w):
    a = jnp.sum(x.astype(jnp.bfloat16), dtype=jnp.float32)
    b = jnp.mean(x.astype(jnp.bfloat16)).astype(jnp.float32)
    c = jnp.sum(x)                       # no sub-f32 cast involved
    d = jnp.einsum("i,i->", x, w.astype(x.dtype))  # alignment cast
    return a + b + c + d
"""


def test_rep005_flags_subf32_reduction():
    assert rules_of(run_rule(REP005, REP005_BAD)) == ["REP005"]


def test_rep005_passes_mitigated_and_aligned():
    assert run_rule(REP005, REP005_OK) == []


def test_rep005_suppressed_with_reason():
    src = REP005_BAD.replace(
        "bfloat16))", "bfloat16))  # repro: noqa REP005 -- fixture")
    assert run_rule(REP005, src) == []


# ----------------------------------------------------- repo + README gates
def test_repo_is_lint_clean_modulo_baseline():
    base = load_baseline()
    fresh = [f for f in lint_repo() if f.baseline_key not in base]
    assert fresh == [], "\n".join(str(f) for f in fresh)


def test_readme_rule_table_in_sync():
    assert sync_readme(write=False), (
        "src/repro/analysis/README.md rule table is stale — regenerate "
        "with `python -m repro.analysis.lint --write`")


# ------------------------------------------------------- Layer-2 contracts
def test_donation_break_is_detected():
    def step(state, x):
        return state + x

    s, x = jnp.zeros(4), jnp.ones(4)
    good = jax.jit(step, donate_argnums=(0,)).lower(s, x).as_text()
    bad = jax.jit(step).lower(s, x).as_text()
    ok, _ = donation_alias_report(good, n_nondonated=1)
    assert ok
    broken, summary = donation_alias_report(bad, n_nondonated=1)
    assert not broken and "aliased 0/1" in summary


def test_scan_carry_dtype_audit_detects_subf32():
    def scanned(c, xs):
        return lax.scan(lambda c, x: (c + x, x), c, xs)

    bf = jax.make_jaxpr(scanned)(
        jnp.zeros(3, jnp.bfloat16), jnp.zeros((4, 3), jnp.bfloat16))
    f32 = jax.make_jaxpr(scanned)(
        jnp.zeros(3, jnp.float32), jnp.zeros((4, 3), jnp.float32))
    assert scan_carry_violations(bf)
    assert scan_carry_violations(f32) == []


def test_collective_audit_sees_primitives():
    if jax.device_count() < 2:
        mapped = jax.make_jaxpr(lambda x: x)(jnp.zeros(2))
        assert "psum_scatter" not in collective_primitives(mapped)
        return
    from repro.utils.compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((2,), ("c",))
    f = shard_map(lambda x: lax.psum_scatter(x, "c"), mesh=mesh,
                  in_specs=P("c"), out_specs=P("c"))
    prims = collective_primitives(jax.make_jaxpr(f)(jnp.zeros((2, 2))))
    assert any("psum" in p for p in prims)


def test_quick_contracts_pass_on_shipped_engine():
    sc = quick_contracts(use_async=False, use_fused_kernel=True)
    assert sc["donation_ok"] and sc["transfer_guard_ok"]
    assert sc["trace_count"] == sc["trace_budget"] == TRACE_BUDGET
