"""Property tests of the paper's structural claims (§4.1, Appendix B).

* Lemma 4.1 — Δ_{t+1} = α·Δ̃_t + (1−α)·Δ_t exactly (Δ̃ recomputed by hand).
* α = 1 degenerates FedCM to FedAvg bit-exactly.
* Lemma B.7 — the auxiliary sequence obeys z_{t+1} = z_t − η_g_eff·Δ̃_t.
* Statelessness: FedCM keeps no client state; SCAFFOLD/FedDyn do.
* Payload asymmetry (§4.2): FedCM doubles downlink only.
"""
from dataclasses import replace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, sample_cohort
from repro.core.algorithms import client_state_init
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier
from repro.utils.trees import tree_norm, tree_sub


def _setup(algo="fedcm", alpha=0.3, K=3, clients=8, cohort=3, eta_l=0.05, eta_g=1.0,
           participation="fixed", wd=0.0, decay=1.0, seed=0):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=640, n_test=16, seed=seed)
    model = mlp_classifier((8, 16, 4))
    loss_fn = classification_loss(model.apply)
    cfg = FedConfig(algo=algo, num_clients=clients, cohort_size=cohort, local_steps=K,
                    alpha=alpha, eta_l=eta_l, eta_g=eta_g, weight_decay=wd,
                    eta_l_decay=decay, participation=participation)
    data = FederatedData(x, y, clients, seed=seed)
    eng = FederatedEngine(cfg, loss_fn, batch_size=16)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, data, eng, params, loss_fn


def _manual_delta_tilde(cfg, loss_fn, params, momentum, ids, batches):
    """Recompute Δ̃_t = (1/KS)·Σ g_{i,k} along the FedCM trajectory."""
    grads = []
    for i in range(ids.shape[0]):
        x = params
        for k in range(cfg.local_steps):
            b = jax.tree_util.tree_map(lambda a: a[i, k], batches)
            g = jax.grad(loss_fn)(x, b)
            grads.append(g)
            v = jax.tree_util.tree_map(
                lambda gi, mi: cfg.alpha * gi + (1 - cfg.alpha) * mi, g, momentum
            )
            x = jax.tree_util.tree_map(lambda xi, vi: xi - cfg.eta_l * vi, x, v)
    return jax.tree_util.tree_map(lambda *gs: jnp.mean(jnp.stack(gs), 0), *grads)


@given(
    alpha=st.sampled_from([0.05, 0.1, 0.3, 0.7, 1.0]),
    K=st.integers(1, 4),
    seed=st.integers(0, 5),
)
@settings(max_examples=10, deadline=None)
def test_lemma_4_1_ema(alpha, K, seed):
    cfg, data, eng, params, loss_fn = _setup(alpha=alpha, K=K, seed=seed)
    state = eng.init(params, jax.random.PRNGKey(seed + 100))
    for _ in range(2):  # check the lemma at two rounds (Δ_0 = 0 and Δ_1 ≠ 0)
        rng, kc, kb = jax.random.split(state.rng, 3)
        ids, mask = sample_cohort(kc, cfg)
        batches = data.sample_round_batches(kb, ids, cfg.local_steps, 16)
        prev = state.server.momentum
        new_state, _ = eng.round_step(state._replace(rng=rng), batches, ids, mask)
        tilde = _manual_delta_tilde(cfg, loss_fn, state.params, prev, ids, batches)
        lemma = jax.tree_util.tree_map(
            lambda t, pm: cfg.alpha * t + (1 - cfg.alpha) * pm, tilde, prev
        )
        err = float(tree_norm(tree_sub(lemma, new_state.server.momentum)))
        ref = float(tree_norm(new_state.server.momentum)) + 1e-12
        assert err / ref < 1e-4, (alpha, K, err / ref)
        state = new_state


def test_alpha_1_is_fedavg_bitexact():
    cfg, data, eng_cm, params, loss_fn = _setup(algo="fedcm", alpha=1.0)
    cfg_avg = replace(cfg, algo="fedavg")
    eng_avg = FederatedEngine(cfg_avg, loss_fn, batch_size=16)
    s_cm = eng_cm.init(params, jax.random.PRNGKey(7))
    s_avg = eng_avg.init(params, jax.random.PRNGKey(7))
    for _ in range(4):
        s_cm, _ = eng_cm.run_round(s_cm, data)
        s_avg, _ = eng_avg.run_round(s_avg, data)
    for a, b in zip(jax.tree_util.tree_leaves(s_cm.params),
                    jax.tree_util.tree_leaves(s_avg.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lemma_b7_z_recursion():
    """z_{t+1} = z_t − η_g_eff·Δ̃_t with z_t = x_t + (1−α)/α·(x_t − x_{t−1})."""
    cfg, data, eng, params, loss_fn = _setup(alpha=0.25, K=2)
    state = eng.init(params, jax.random.PRNGKey(3))
    xs = [state.params]
    tildes = []
    for _ in range(3):
        rng, kc, kb = jax.random.split(state.rng, 3)
        ids, mask = sample_cohort(kc, cfg)
        batches = data.sample_round_batches(kb, ids, cfg.local_steps, 16)
        tildes.append(_manual_delta_tilde(cfg, loss_fn, state.params,
                                          state.server.momentum, ids, batches))
        state, _ = eng.round_step(state._replace(rng=rng), batches, ids, mask)
        xs.append(state.params)

    a = cfg.alpha
    eta_eff = cfg.eta_g * cfg.eta_l * cfg.local_steps

    def z(t):
        if t == 0:
            return xs[0]
        return jax.tree_util.tree_map(
            lambda xt, xp: xt + (1 - a) / a * (xt - xp), xs[t], xs[t - 1]
        )

    for t in range(2):
        lhs = z(t + 1)
        rhs = jax.tree_util.tree_map(lambda zt, d: zt - eta_eff * d, z(t), tildes[t])
        err = float(tree_norm(tree_sub(lhs, rhs))) / (float(tree_norm(lhs)) + 1e-12)
        assert err < 1e-4, (t, err)


def test_statelessness():
    cfg, *_ , params, _ = _setup(algo="fedcm")
    assert client_state_init(params, cfg) is None
    for algo in ("fedavg", "fedadam", "mimelite"):
        assert client_state_init(params, replace(cfg, algo=algo)) is None
    for algo in ("scaffold", "feddyn"):
        cst = client_state_init(params, replace(cfg, algo=algo))
        assert cst is not None
        leaf = jax.tree_util.tree_leaves(cst)[0]
        assert leaf.shape[0] == cfg.num_clients


def test_payload_asymmetry():
    """§4.2: FedCM costs 2×down / 1×up; SCAFFOLD 2×/2×; FedAvg 1×/1×;
    MimeLite 2×down (x_t + m) and 2×up (Δ + full-batch grad)."""
    from repro.utils.trees import tree_bytes

    cfg, data, eng, params, loss_fn = _setup()
    P = tree_bytes(params)
    expect = {
        "fedavg": (P, P),
        "fedcm": (2 * P, P),
        "fedadam": (P, P),
        "scaffold": (2 * P, 2 * P),
        "feddyn": (P, P),
        "mimelite": (2 * P, 2 * P),
    }
    for algo, (dn, up) in expect.items():
        e = FederatedEngine(replace(cfg, algo=algo), loss_fn, batch_size=16)
        pay = e.payload_bytes(params)
        assert pay["down_per_client"] == dn, algo
        assert pay["up_per_client"] == up, algo


def test_momentum_is_zero_at_init_and_moves():
    cfg, data, eng, params, _ = _setup()
    state = eng.init(params, jax.random.PRNGKey(0))
    assert float(tree_norm(state.server.momentum)) == 0.0
    state, m = eng.run_round(state, data)
    assert float(tree_norm(state.server.momentum)) > 0.0
    assert float(m.momentum_norm) == 0.0  # norm of Δ_t ENTERING round 0
