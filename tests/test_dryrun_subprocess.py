"""Integration: the multi-pod dry-run actually lowers + compiles.

Run as a subprocess because XLA_FLAGS (512 placeholder devices) must be set
before jax initializes — the in-process test session already owns a
1-device jax.  One cheap pair per mesh keeps CI time sane; the full 40-pair
sweep is `python -m repro.launch.dryrun --all --both-meshes` (EXPERIMENTS.md
§Dry-run records its output).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _run_dryrun(*args):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=900, env=env, cwd=str(REPO),
    )


@pytest.mark.slow
def test_single_pod_decode_lowers():
    r = _run_dryrun("--arch", "llama3.2-1b", "--shape", "decode_32k")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL DRY-RUNS PASSED" in r.stdout


@pytest.mark.slow
def test_multi_pod_train_lowers():
    r = _run_dryrun("--arch", "llama3.2-1b", "--shape", "train_4k", "--multi-pod")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL DRY-RUNS PASSED" in r.stdout
    art = REPO / "benchmarks" / "artifacts" / "dryrun" / "llama3.2-1b_train_4k_multi_pod_2x16x16.json"
    assert art.exists()
    data = json.loads(art.read_text())
    assert data["chips"] == 512
    assert data["hlo_flops_per_device"] > 0
    assert data["collective_bytes_per_device"] > 0
