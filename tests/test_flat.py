"""Flat parameter-plane engine (core/flat.py + engine flat path) tests.

* FlatSpec: ravel/unravel round-trips (shapes, dtypes, scalar leaves,
  stacked leading axes), view_leaf addressing, nbytes accounting, hashing.
* Engine equivalence: the flat-plane trajectory must match the tree-path
  oracle bitwise-close (well inside the atol ≤ 1e-5 acceptance bar) for
  EVERY algorithm, stateful ones included.
* Donation: run_rounds donates its input state; the returned trajectory
  must be stable when the donated buffers get recycled by later calls.
* Mixed bf16/f32 trees survive the flat round trip.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import FederatedEngine, FlatSpec, list_algorithms
from repro.data import FederatedData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

RNG = np.random.default_rng(0)


# ----------------------------------------------------------------------
# FlatSpec unit tests
# ----------------------------------------------------------------------


def _mixed_tree():
    return {
        "a": jnp.asarray(RNG.normal(size=(13, 7)), jnp.float32),
        "b": [
            jnp.asarray(RNG.normal(size=(5,)), jnp.float32),
            jnp.asarray(RNG.normal(size=(2, 3)), jnp.bfloat16),
        ],
        "scalar": jnp.float32(3.5),
    }


def test_flatspec_roundtrip_shapes_dtypes():
    tree = _mixed_tree()
    spec = FlatSpec.from_tree(tree)
    assert spec.size == 13 * 7 + 5 + 6 + 1
    flat = spec.ravel(tree)
    assert flat.shape == (spec.size,) and flat.dtype == jnp.float32
    back = spec.unravel(flat)
    for o, r in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert o.shape == r.shape and o.dtype == r.dtype
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32), rtol=1e-2, atol=1e-2
        )
    # f32 leaves round-trip bitwise
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(back["a"]))


def test_flatspec_stacked_batch_dims():
    tree = {"w": jnp.asarray(RNG.normal(size=(4, 3, 2)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(4, 5)), jnp.float32)}
    # leading axis 4 = stacked clients; plane covers (3,2) and (5,)
    per_client = {"w": jax.ShapeDtypeStruct((3, 2), jnp.float32),
                  "b": jax.ShapeDtypeStruct((5,), jnp.float32)}
    spec = FlatSpec.from_tree(per_client)
    plane = spec.ravel(tree, batch_dims=1)
    assert plane.shape == (4, 11)
    back = spec.unravel(plane)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(back["b"]), np.asarray(tree["b"]))


def test_flatspec_view_leaf_by_index_and_path():
    tree = _mixed_tree()
    spec = FlatSpec.from_tree(tree)
    flat = spec.ravel(tree)
    np.testing.assert_array_equal(np.asarray(spec.view_leaf(flat, 0)),
                                  np.asarray(tree["a"]))
    path = spec.leaves[0].path
    np.testing.assert_array_equal(np.asarray(spec.view_leaf(flat, path)),
                                  np.asarray(tree["a"]))
    with pytest.raises(KeyError):
        spec.view_leaf(flat, "nope")


def test_flatspec_nbytes_matches_tree_bytes():
    from repro.utils.trees import tree_bytes

    tree = _mixed_tree()
    assert FlatSpec.from_tree(tree).nbytes == tree_bytes(tree)


def test_flatspec_rejects_int_leaves():
    with pytest.raises(TypeError):
        FlatSpec.from_tree({"i": jnp.arange(3)})


def test_flatspec_hashable_and_eq():
    t1, t2 = _mixed_tree(), _mixed_tree()
    s1, s2 = FlatSpec.from_tree(t1), FlatSpec.from_tree(t2)
    assert s1 == s2 and hash(s1) == hash(s2)
    s3 = FlatSpec.from_tree({"a": t1["a"]})
    assert s1 != s3


def test_flatspec_empty_tree():
    spec = FlatSpec.from_tree({})
    assert spec.size == 0
    assert spec.ravel({}).shape == (0,)


# ----------------------------------------------------------------------
# engine: flat plane vs tree-path oracle
# ----------------------------------------------------------------------

N_ROUNDS = 3


def _setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, eng, data, model


def _fresh(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


def _assert_close(a, b, atol=1e-5, rtol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol)


@pytest.mark.parametrize("algo", list_algorithms())
def test_flat_plane_matches_tree_oracle(algo):
    """EVERY registered algorithm (the registry is the parametrization —
    a newly registered spec is held to this automatically)."""
    cfg, eng_flat, data, model = _setup(algo)
    assert cfg.use_flat_plane  # flat is the default engine
    eng_tree = FederatedEngine(
        replace(cfg, use_flat_plane=False), eng_flat.loss_fn, batch_size=8
    )
    s_flat, m_flat = eng_flat.run_rounds(_fresh(eng_flat, model), data, N_ROUNDS)
    s_tree, m_tree = eng_tree.run_rounds(_fresh(eng_tree, model), data, N_ROUNDS)
    _assert_close(s_flat.params, s_tree.params)
    _assert_close(s_flat.server.momentum, s_tree.server.momentum)
    _assert_close(s_flat.server.second_moment, s_tree.server.second_moment)
    if s_tree.client_states is not None:
        _assert_close(s_flat.client_states, s_tree.client_states)
        # treedef restored too: the flat engine must hand back a real tree
        assert jax.tree_util.tree_structure(
            s_flat.client_states
        ) == jax.tree_util.tree_structure(s_tree.client_states)
    np.testing.assert_allclose(np.asarray(m_flat.loss), np.asarray(m_tree.loss),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m_flat.delta_norm),
                               np.asarray(m_tree.delta_norm), rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(m_flat.n_active),
                                  np.asarray(m_tree.n_active))
    np.testing.assert_array_equal(np.asarray(m_flat.bytes_down),
                                  np.asarray(m_tree.bytes_down))


def test_flat_plane_per_round_matches_fused():
    """ravel-per-round (run_round) and ravel-once (run_rounds) must agree:
    the f32 plane round-trips through the tree losslessly between rounds."""
    _, eng, data, model = _setup("scaffold")
    st = _fresh(eng, model)
    for _ in range(N_ROUNDS):
        st, _ = eng.run_round(st, data)
    fused, _ = eng.run_rounds(_fresh(eng, model), data, N_ROUNDS)
    _assert_close(st.params, fused.params, atol=1e-6, rtol=2e-5)
    _assert_close(st.client_states, fused.client_states, atol=1e-6, rtol=2e-5)


def test_run_rounds_donation_safety():
    """run_rounds donates its input: once the trajectory is returned, later
    calls recycling those buffers must not corrupt it, and the returned
    state must itself be usable as the next donated input."""
    _, eng, data, model = _setup("fedcm")
    out1, _ = eng.run_rounds(_fresh(eng, model), data, N_ROUNDS)
    snap = [np.array(l) for l in jax.tree_util.tree_leaves(out1.params)]
    # same shapes → jax may reuse the donated buffers of this second call
    out2, _ = eng.run_rounds(_fresh(eng, model), data, N_ROUNDS)
    for s, l in zip(snap, jax.tree_util.tree_leaves(out1.params)):
        np.testing.assert_array_equal(s, np.asarray(l))
    # identical seeds → identical trajectories
    for a, b in zip(jax.tree_util.tree_leaves(out1.params),
                    jax.tree_util.tree_leaves(out2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # chaining off the returned (donated-in) state works
    out3, m3 = eng.run_rounds(out2, data, 2)
    assert int(out3.server.round) == N_ROUNDS + 2
    assert np.all(np.isfinite(np.asarray(m3.loss)))


def test_flat_engine_bf16_mixed_param_tree():
    """A params tree mixing bf16 and f32 leaves runs on the flat plane and
    stays close to the tree path (bf16 tolerance: the plane carries f32
    across local steps, the tree path re-rounds each step)."""

    def loss_fn(params, batch):
        d = params["w"].astype(jnp.float32) - batch["c"]
        return 0.5 * jnp.mean(jnp.sum(d**2, -1)) + 0.5 * jnp.mean(
            params["b"].astype(jnp.float32) ** 2
        )

    cfg = FedConfig(algo="fedcm", num_clients=4, cohort_size=2, local_steps=2,
                    participation="fixed", weight_decay=0.0)
    params = {
        "w": jnp.asarray(RNG.normal(size=(6,)), jnp.bfloat16),
        "b": jnp.asarray(RNG.normal(size=(3,)), jnp.float32),
    }
    eng = FederatedEngine(cfg, loss_fn, batch_size=2)
    engt = FederatedEngine(replace(cfg, use_flat_plane=False), loss_fn, batch_size=2)

    centers = jnp.asarray(RNG.normal(size=(4, 2, 6)), jnp.float32)  # (C, B, 6)
    batches = {"c": jnp.broadcast_to(centers[:, None], (4, 2, 2, 6))}
    ids, mask = jnp.arange(2), jnp.ones(2, bool)
    st = eng.init(params, jax.random.PRNGKey(0))
    stt = engt.init(params, jax.random.PRNGKey(0))
    b2 = jax.tree_util.tree_map(lambda a: a[:2], batches)
    new, _ = eng.round_step(st, b2, ids, mask)
    newt, _ = engt.round_step(stt, b2, ids, mask)
    assert new.params["w"].dtype == jnp.bfloat16
    assert new.params["b"].dtype == jnp.float32
    _assert_close(
        jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), new.params),
        jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), newt.params),
        atol=2e-2, rtol=2e-2,
    )


# ----------------------------------------------------------------------
# CohortUplink ring (async pipelined engine's in-flight cohort store)
# ----------------------------------------------------------------------


def _uplink(C, P, val, with_state=True):
    from repro.core import CohortUplink

    return CohortUplink(
        delta=jnp.full((C, P), val, jnp.float32),
        state_delta=jnp.full((C, P), 2 * val, jnp.float32) if with_state else None,
        extra=None,
        ids=jnp.arange(C, dtype=jnp.int32),
        w=jnp.ones((C,), jnp.float32),
        eta_l=jnp.float32(0.1 * val),
    )


def test_ring_push_rotates_oldest_first():
    from repro.core import ring_push

    C, P = 4, 11
    pending = (_uplink(C, P, 1.0), _uplink(C, P, 2.0))  # depth 3 ring: D-1 pending
    oldest, pending = ring_push(pending, _uplink(C, P, 3.0))
    np.testing.assert_array_equal(np.asarray(oldest.delta), 1.0)
    np.testing.assert_array_equal(np.asarray(oldest.state_delta), 2.0)
    assert len(pending) == 2
    np.testing.assert_array_equal(np.asarray(pending[0].delta), 2.0)
    np.testing.assert_array_equal(np.asarray(pending[1].delta), 3.0)
    # depth 1 (sync schedule): the entry folds the round it launches
    oldest, empty = ring_push((), _uplink(C, P, 9.0, with_state=False))
    assert empty == () and oldest.state_delta is None and oldest.extra is None
    np.testing.assert_array_equal(np.asarray(oldest.delta), 9.0)


def test_ring_push_is_scan_carry_compatible():
    """The rotated tuple must hold its treedef across scan iterations (the
    steady scan carries it) and work as pure dataflow under jit."""
    from repro.core import ring_push

    C, P = 2, 5

    def body(carry, x):
        pending = carry
        entry = _uplink(C, P, 0.0, with_state=False)._replace(
            delta=jnp.full((C, P), x, jnp.float32))
        oldest, pending = ring_push(pending, entry)
        return pending, jnp.max(oldest.delta)

    init = (_uplink(C, P, -2.0, with_state=False),
            _uplink(C, P, -1.0, with_state=False))  # depth 3
    pending, folded = jax.lax.scan(body, init, jnp.arange(5, dtype=jnp.float32))
    # folds see entries in launch order, D-1 = 2 rounds late
    np.testing.assert_array_equal(np.asarray(folded), [-2.0, -1.0, 0.0, 1.0, 2.0])
    # the final pending entries are the last two launches (the drain's input)
    np.testing.assert_array_equal(np.asarray(pending[0].delta), 3.0)
    np.testing.assert_array_equal(np.asarray(pending[1].delta), 4.0)
