"""Kernel sweeps: every Pallas kernel vs its pure-jnp oracle across
shapes / dtypes (deliverable (c): per-kernel allclose)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.fed_direction.kernel import fed_direction_flat
from repro.kernels.fed_direction.ops import flat_direction_step
from repro.kernels.fed_direction.ref import fed_direction_ref
from repro.kernels.fedcm_update.ref import fedcm_step_ref
from repro.kernels.server_update.ops import fused_server_step
from repro.kernels.server_update.ref import server_update_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_sequential_ref
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------------------
# fedcm blend oracle (legacy fedcm_update kernel retired to ref-only: the
# blend now launches through fed_direction with coefs (η, α, 0, 1−α) —
# these tests pin that route to Algorithm 2 line 8–9 via the RETAINED
# fedcm_step_ref oracle, independent of fed_direction's own reference)
# ----------------------------------------------------------------------


def _blend_coefs(alpha, eta):
    return jnp.asarray([eta, alpha, 0.0, 1.0 - alpha], jnp.float32)


@pytest.mark.parametrize("n", [5, 1023, 64 * 1024 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_direction_reproduces_fedcm_blend(n, dtype):
    x = jnp.asarray(RNG.normal(size=(n,)), dtype)
    g = jnp.asarray(RNG.normal(size=(n,)), dtype)
    d = jnp.asarray(RNG.normal(size=(n,)), dtype)
    out = fed_direction_flat(x, g, (d,), _blend_coefs(0.1, 0.05))
    ref = fedcm_step_ref(x, g, d, 0.1, 0.05)
    assert out.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("alpha,eta", [(0.0, 0.1), (1.0, 0.1), (0.1, 0.0), (0.37, 1.3)])
def test_fedcm_blend_hyperparam_edges(alpha, eta):
    x = jnp.asarray(RNG.normal(size=(333,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(333,)), jnp.float32)
    d = jnp.asarray(RNG.normal(size=(333,)), jnp.float32)
    np.testing.assert_allclose(
        fed_direction_flat(x, g, (d,), _blend_coefs(alpha, eta)),
        fedcm_step_ref(x, g, d, alpha, eta),
        rtol=1e-6, atol=1e-6,
    )


def test_fedcm_blend_bf16_params_keep_f32_momentum_precision():
    """Regression (dtype fidelity): bf16 params with f32 g/Δ must match the
    f32 reference — the retired wrapper once cast g/Δ to bf16 BEFORE the
    kernel, truncating the momentum the body was about to upcast anyway.
    The fed_direction route must preserve the contract."""
    x = jnp.asarray(RNG.normal(size=(4097,)), jnp.bfloat16)
    g = jnp.asarray(RNG.normal(size=(4097,)), jnp.float32)
    d = jnp.asarray(RNG.normal(size=(4097,)) * 1e-3, jnp.float32)
    out = fed_direction_flat(x, g, (d,), _blend_coefs(0.1, 0.05))
    ref = fedcm_step_ref(x, g, d, 0.1, 0.05)  # blends in full f32
    assert out.dtype == jnp.bfloat16
    # the kernel must agree with the f32-blend reference EXACTLY (both round
    # the same f32 value to bf16 once, at the end)
    np.testing.assert_array_equal(
        np.asarray(out, np.float32), np.asarray(ref, np.float32)
    )


def test_fedcm_blend_empty_tail_padding_is_dropped():
    """Non-block-multiple sizes: the padded tail must never leak into the
    output (output length and values exact for n = 1 and n = block+1)."""
    for n in (1, 64 * 1024 + 1):
        x = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
        g = jnp.ones((n,), jnp.float32)
        d = jnp.zeros((n,), jnp.float32)
        out = fed_direction_flat(x, g, (d,), _blend_coefs(1.0, 0.5))
        assert out.shape == (n,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) - 0.5,
                                   rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------------
# fed_direction (generalized fused local step)
# ----------------------------------------------------------------------

# (η, c_g, c_x, c_aux...) per algorithm family, exercising 0/1/2 aux buffers
DIRECTION_CASES = [
    ("sgd", 0, [0.05, 1.0, 0.0]),
    ("blend", 1, [0.05, 0.1, 0.0, 0.9]),
    ("scaffold", 2, [0.05, 1.0, 0.0, -1.0, 1.0]),
    ("feddyn", 2, [0.05, 1.0, 0.01, -1.0, -0.01]),
]


@pytest.mark.parametrize("name,n_aux,coefs", DIRECTION_CASES)
@pytest.mark.parametrize("n", [1, 5, 1023, 64 * 1024 + 3])
def test_fed_direction_sweep(name, n_aux, coefs, n):
    x = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    auxes = tuple(jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
                  for _ in range(n_aux))
    cf = jnp.asarray(coefs, jnp.float32)
    out = fed_direction_flat(x, g, auxes, cf)
    ref = fed_direction_ref(x, g, auxes, cf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_direction_mixed_dtype_operands(dtype):
    """bf16 plane with f32 momentum (and vice versa): operands go in raw,
    the body blends in f32, only the output is rounded to x.dtype."""
    n = 777
    x = jnp.asarray(RNG.normal(size=(n,)), dtype)
    g = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(n,)), jnp.bfloat16)
    cf = jnp.asarray([0.1, 0.3, 0.0, 0.7], jnp.float32)
    out = fed_direction_flat(x, g, (m,), cf)
    ref = fed_direction_ref(x, g, (m,), cf)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-6, atol=1e-6)


def test_flat_direction_step_algorithm_dispatch():
    """ops-level dispatch resolves each spec's DirectionRow (named streams:
    momentum = the broadcast buffer, client_state = c_i / λ_i) into the
    right affine kernel launch."""
    from repro.configs.base import FedConfig

    n = 513
    x = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    c_i = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    x0 = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    lam = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    cfg = FedConfig(alpha=0.2, feddyn_alpha=0.05)
    eta = jnp.float32(0.1)

    # (per-client state plane, expected update) — the broadcast buffer m
    # doubles as scaffold's c, exactly as the engine feeds it
    cases = {
        "fedcm": (None, x - eta * (0.2 * g + 0.8 * m)),
        "fedavg": (None, x - eta * g),
        "fedavgm": (None, x - eta * g),
        "fedacg": (None, x - eta * g),
        "scaffold": (c_i, x - eta * (g - c_i + m)),
        "feddyn": (lam, x - eta * (g - lam + 0.05 * (x - x0))),
    }
    for name, (cst, ref) in cases.items():
        out = flat_direction_step(name, cfg, x, g, m, cst, x0, eta)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6, err_msg=name)
    with pytest.raises(KeyError):
        flat_direction_step("nope", cfg, x, g, m, None, x0, eta)


def test_flat_direction_step_escape_hatch_spec():
    """A spec with a non-affine direction_fn bypasses the kernel but keeps
    the same x ← x − η_l·v contract on flat buffers."""
    from repro.configs.base import FedConfig
    from repro.core import AlgorithmSpec

    n = 257
    x = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    spec = AlgorithmSpec(
        name="_signsgd_toy", direction_row=None,
        direction_fn=lambda cfg, m, cst, xx, x0, gg: jnp.sign(gg),
    )
    out = flat_direction_step(spec, FedConfig(), x, g, None, None, x, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x - 0.1 * jnp.sign(g)),
                               rtol=1e-6, atol=1e-7)


# ----------------------------------------------------------------------
# server_update (fused masked mean + momentum EMA + param step)
# ----------------------------------------------------------------------

SERVER_CASES = [
    # (C, P) plane shapes incl. non-block-multiple and tiny planes
    (1, 1),
    (3, 129),
    (8, 1000),
    (5, 16 * 1024 + 7),
]


@pytest.mark.parametrize("C,P", SERVER_CASES)
@pytest.mark.parametrize("masked", [False, True])
def test_server_update_sweep(C, P, masked):
    deltas = jnp.asarray(RNG.normal(size=(C, P)), jnp.float32)
    mask = np.ones(C, bool)
    if masked and C > 1:
        mask[-1] = False
    w = jnp.asarray(mask, jnp.float32)
    wn = w / jnp.sum(w)
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    out = fused_server_step(deltas, wn, x, m, 0.9, 0.1, -2.0)
    coefs = jnp.asarray([0.9, 0.1, -2.0, 1.0], jnp.float32)
    ref = server_update_ref(deltas, wn, x, m, coefs)
    for o, r in zip(out, ref):
        assert o.shape == (P,)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=2e-6)
    # masked-out client must contribute nothing
    if masked and C > 1:
        garbage = deltas.at[-1].set(1e9)
        out_g = fused_server_step(garbage, wn, x, m, 0.9, 0.1, -2.0)
        for o, og in zip(out, out_g):
            np.testing.assert_array_equal(np.asarray(o), np.asarray(og))


@pytest.mark.parametrize("write_x,write_m", [(True, False), (False, True),
                                             (False, False)])
def test_server_update_reduced_outputs(write_x, write_m):
    """A pass that structurally skips the param step / momentum EMA drops
    the output (and its input read) from the launch: the emitted subset is
    bitwise the full launch's, skipped slots come back None."""
    C, P = 4, 1000
    deltas = jnp.asarray(RNG.normal(size=(C, P)), jnp.float32)
    wn = jnp.full((C,), 0.25, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    full = fused_server_step(deltas, wn, x, m, 0.9, 0.1, -2.0)
    part = fused_server_step(deltas, wn, x, m, 0.9, 0.1, -2.0,
                             write_x=write_x, write_m=write_m)
    for keep, p_out, f_out in zip((write_x, write_m, True), part, full):
        if keep:
            np.testing.assert_array_equal(np.asarray(p_out), np.asarray(f_out))
        else:
            assert p_out is None


def test_server_update_momentum_dtype_override():
    C, P = 4, 300
    deltas = jnp.asarray(RNG.normal(size=(C, P)), jnp.float32)
    wn = jnp.full((C,), 0.25, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    new_x, new_m, mean = fused_server_step(
        deltas, wn, x, m, 0.0, -2.0, 1.0, m_dtype=jnp.bfloat16)
    assert new_m.dtype == jnp.bfloat16
    assert new_x.dtype == jnp.float32 and mean.dtype == jnp.float32


@pytest.mark.parametrize("gamma", [0.0, 0.5, 0.9, 1.0])
def test_server_update_staleness_discount(gamma):
    """The SMEM discount scalar scales the EMA/step inputs but NOT the
    emitted mean (metrics must see the cohort's actual delta)."""
    C, P = 3, 777
    deltas = jnp.asarray(RNG.normal(size=(C, P)), jnp.float32)
    wn = jnp.full((C,), 1.0 / C, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    new_x, new_m, mean = fused_server_step(
        deltas, wn, x, m, 0.7, -1.5, 2.0, discount=gamma)
    ref = server_update_ref(
        deltas, wn, x, m, jnp.asarray([0.7, -1.5, 2.0, gamma], jnp.float32))
    for o, r in zip((new_x, new_m, mean), ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=2e-6)
    # mean is undiscounted: recompute from raw inputs
    raw_mean = np.tensordot(np.asarray(wn), np.asarray(deltas), axes=(0, 0))
    np.testing.assert_allclose(np.asarray(mean), raw_mean, rtol=2e-5, atol=2e-6)
    if gamma == 1.0:  # γ=1 must be bitwise the undiscounted form
        base = fused_server_step(deltas, wn, x, m, 0.7, -1.5, 2.0)
        for o, b in zip((new_x, new_m, mean), base):
            np.testing.assert_array_equal(np.asarray(o), np.asarray(b))


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Skv, H, Hkv, hd, causal, window, q_offset)
    (2, 64, 64, 4, 2, 32, True, None, 0),
    (1, 100, 100, 4, 4, 16, True, None, 0),     # ragged vs block
    (1, 128, 128, 2, 1, 32, True, 17, 0),       # sliding window (MQA)
    (1, 96, 96, 2, 2, 64, False, None, 0),      # bidirectional (encoder)
    (2, 1, 200, 4, 2, 32, True, None, 199),     # decode: 1 query vs deep KV
    (1, 257, 257, 8, 2, 128, True, None, 0),    # hd=128 MXU-width
    (1, 64, 64, 4, 2, 32, True, 1, 0),          # window=1 (self only)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, H, Hkv, hd, causal, window, off = case
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, q_offset=off, bq=32, bkv=32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_matches_model_layer_attention():
    """The kernel must agree with the model's attend_direct (GQA grouping)."""
    from repro.models.layers import attend_direct

    B, S, H, Hkv, hd = 2, 48, 8, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    pos = jnp.arange(S)
    msk = (pos[:, None] >= pos[None, :])[None, None]
    ref = attend_direct(q, k, v, msk, hd**-0.5)
    out = flash_attention(q, k, v, causal=True, bq=16, bkv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------------------
# ssd scan
# ----------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (2, 64, 3, 16, 8, 16),
    (1, 100, 2, 32, 16, 32),   # ragged
    (1, 37, 1, 8, 4, 16),      # shorter than 2 chunks
    (1, 128, 4, 64, 32, 64),   # production-ish tile
    (2, 16, 2, 8, 8, 16),      # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_sequential(case, dtype):
    B, S, H, P, N, chunk = case
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    y_ker, st_ker = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, st_ref = ssd_sequential_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_ker), np.asarray(st_ref), **tol)


def test_ssd_chunk_invariance():
    """The chunk size is an implementation detail — outputs must not move."""
    B, S, H, P, N = 1, 96, 2, 16, 8
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y16, _ = ssd(x, dt, A, Bm, Cm, chunk=16)
    y48, _ = ssd(x, dt, A, Bm, Cm, chunk=48)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y48), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_vs_model_chunked():
    """kernel == the model's jnp chunked path (the integration contract)."""
    B, S, H, P, N, chunk = 2, 80, 2, 16, 8, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y_k, st_k = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y_m, st_m = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m), rtol=2e-4, atol=2e-4)
