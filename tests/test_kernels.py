"""Kernel sweeps: every Pallas kernel vs its pure-jnp oracle across
shapes / dtypes (deliverable (c): per-kernel allclose)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.fedcm_update.ops import fedcm_step, fedcm_step_tree
from repro.kernels.fedcm_update.ref import fedcm_step_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_sequential_ref
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------------------
# fedcm_update
# ----------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(5,), (1023,), (64 * 1024 + 3,), (17, 129), (2, 3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedcm_update_sweep(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    g = jnp.asarray(RNG.normal(size=shape), dtype)
    d = jnp.asarray(RNG.normal(size=shape), dtype)
    out = fedcm_step(x, g, d, 0.1, 0.05)
    ref = fedcm_step_ref(x, g, d, 0.1, 0.05)
    assert out.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("alpha,eta", [(0.0, 0.1), (1.0, 0.1), (0.1, 0.0), (0.37, 1.3)])
def test_fedcm_update_hyperparam_edges(alpha, eta):
    x = jnp.asarray(RNG.normal(size=(333,)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(333,)), jnp.float32)
    d = jnp.asarray(RNG.normal(size=(333,)), jnp.float32)
    np.testing.assert_allclose(
        fedcm_step(x, g, d, alpha, eta), fedcm_step_ref(x, g, d, alpha, eta),
        rtol=1e-6, atol=1e-6,
    )


def test_fedcm_update_tree_matches_leafwise():
    tree = {
        "a": jnp.asarray(RNG.normal(size=(13, 7)), jnp.float32),
        "b": [jnp.asarray(RNG.normal(size=(5,)), jnp.float32),
              jnp.asarray(RNG.normal(size=(2, 3)), jnp.bfloat16)],
    }
    g = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), tree)
    m = jax.tree_util.tree_map(lambda x: 0.5 * jnp.ones_like(x), tree)
    out = fedcm_step_tree(tree, g, m, 0.2, 0.1)
    ref = jax.tree_util.tree_map(lambda x, gg, mm: fedcm_step_ref(x, gg, mm, 0.2, 0.1), tree, g, m)
    for o, r in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)):
        assert o.dtype == r.dtype
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32), rtol=2e-2, atol=2e-2
        )


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Skv, H, Hkv, hd, causal, window, q_offset)
    (2, 64, 64, 4, 2, 32, True, None, 0),
    (1, 100, 100, 4, 4, 16, True, None, 0),     # ragged vs block
    (1, 128, 128, 2, 1, 32, True, 17, 0),       # sliding window (MQA)
    (1, 96, 96, 2, 2, 64, False, None, 0),      # bidirectional (encoder)
    (2, 1, 200, 4, 2, 32, True, None, 199),     # decode: 1 query vs deep KV
    (1, 257, 257, 8, 2, 128, True, None, 0),    # hd=128 MXU-width
    (1, 64, 64, 4, 2, 32, True, 1, 0),          # window=1 (self only)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, H, Hkv, hd, causal, window, off = case
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, q_offset=off, bq=32, bkv=32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_matches_model_layer_attention():
    """The kernel must agree with the model's attend_direct (GQA grouping)."""
    from repro.models.layers import attend_direct

    B, S, H, Hkv, hd = 2, 48, 8, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    pos = jnp.arange(S)
    msk = (pos[:, None] >= pos[None, :])[None, None]
    ref = attend_direct(q, k, v, msk, hd**-0.5)
    out = flash_attention(q, k, v, causal=True, bq=16, bkv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------------------
# ssd scan
# ----------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (2, 64, 3, 16, 8, 16),
    (1, 100, 2, 32, 16, 32),   # ragged
    (1, 37, 1, 8, 4, 16),      # shorter than 2 chunks
    (1, 128, 4, 64, 32, 64),   # production-ish tile
    (2, 16, 2, 8, 8, 16),      # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_sequential(case, dtype):
    B, S, H, P, N, chunk = case
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    y_ker, st_ker = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, st_ref = ssd_sequential_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_ker, np.float32), np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_ker), np.asarray(st_ref), **tol)


def test_ssd_chunk_invariance():
    """The chunk size is an implementation detail — outputs must not move."""
    B, S, H, P, N = 1, 96, 2, 16, 8
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y16, _ = ssd(x, dt, A, Bm, Cm, chunk=16)
    y48, _ = ssd(x, dt, A, Bm, Cm, chunk=48)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y48), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_vs_model_chunked():
    """kernel == the model's jnp chunked path (the integration contract)."""
    B, S, H, P, N, chunk = 2, 80, 2, 16, 8, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y_k, st_k = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y_m, st_m = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m), rtol=2e-4, atol=2e-4)
