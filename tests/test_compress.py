"""Compressed uplink (repro.core.compress + the fused dequant fold).

Covers the tentpole layers:

1. the pure plane transforms — stochastic int8 unbiasedness (incl. the
   clip boundary), bf16 normalization, top-k error-feedback semantics,
   and the wire-bytes accounting the engine bills,
2. the fused dequant kernel against its jnp reference,
3. registry validation — lossy sparsification without a residual stream
   is refused at registration time,
4. the engine end-to-end: compressed runs tolerance-bounded against the
   uncompressed oracle on sync/async/kernel paths, the EF residual
   stream checkpointing (resident + host store) and continuing bitwise
   through a kill/resume, and the double-buffered host-store loop's
   bitwise contract against the synchronous loop.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import CompressionConfig, FedConfig
from repro.checkpoint import load_fed_run, save_fed_run
from repro.core import FederatedEngine, get_algorithm
from repro.core.compress import (
    QPlane,
    TopKPlane,
    as_qplane,
    densify_topk,
    dequantize,
    error_feedback_topk,
    plane_key,
    quantize_int8,
    round_key,
    sparsify_topk,
    topk_k,
    uplink_bytes_per_client,
    validate_compression,
    wire_plane_bytes,
)
from repro.core.registry import _validate
from repro.data import FederatedData, StreamingClientData, make_synthetic_classification
from repro.models.small import classification_loss, mlp_classifier

RNG = np.random.default_rng(0)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _assert_trees_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x1, x2 in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def _assert_trees_close(a, b, rtol=2e-5, atol=1e-6):
    for la, lb in zip(_leaves(a), _leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=rtol, atol=atol)


def _setup(algo, **kw):
    x, y, *_ = make_synthetic_classification(n_classes=4, dim=8, n_train=800, n_test=8)
    model = mlp_classifier((8, 16, 4))
    base = dict(algo=algo, num_clients=10, cohort_size=3, local_steps=2,
                participation="fixed")
    base.update(kw)
    cfg = FedConfig(**base)
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    data = FederatedData(x, y, cfg.num_clients, seed=0)
    return cfg, eng, data, model


def _fresh_state(eng, model):
    return eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))


# ---------------------------------------------------------------------------
# 1. pure plane transforms
# ---------------------------------------------------------------------------

def test_int8_stochastic_rounding_is_unbiased():
    """E[dequantize(quantize(x))] = x elementwise — the property that lets
    the masked cohort mean stay an unbiased gradient estimate."""
    plane = jnp.asarray(RNG.normal(size=(2, 64)) * 3.0, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(7), 4096)
    deq = jax.vmap(lambda k: dequantize(quantize_int8(plane, k)))(keys)
    mean = np.asarray(jnp.mean(deq, axis=0))
    scale = np.abs(np.asarray(plane)).max(axis=-1, keepdims=True) / 127.0
    # se of the mean of a U[0,1)-dithered floor ≈ scale/sqrt(12·N); 6σ bound
    np.testing.assert_allclose(mean, np.asarray(plane),
                               atol=float(6 * scale.max() / np.sqrt(12 * 4096)))


def test_int8_clip_boundary_and_zero_rows():
    """±absmax lands exactly on ±127 for every dither draw (the clip never
    biases), and an all-zero row (dropped client) stays exactly zero with
    unit scale."""
    plane = jnp.asarray([[-6.0, 0.0, 6.0], [0.0, 0.0, 0.0]], jnp.float32)
    for s in range(16):
        rep = quantize_int8(plane, jax.random.PRNGKey(s))
        q = np.asarray(rep.q)
        assert q[0, 0] == -127 and q[0, 2] == 127
        np.testing.assert_array_equal(q[1], 0)
        np.testing.assert_array_equal(np.asarray(rep.scale[1]), 1.0)
        deq = np.asarray(dequantize(rep))
        assert deq[0, 0] == pytest.approx(-6.0) and deq[0, 2] == pytest.approx(6.0)


def test_as_qplane_bf16_unit_scale_is_exact():
    plane = jnp.asarray(RNG.normal(size=(3, 32)), jnp.float32)
    rep = as_qplane(plane.astype(jnp.bfloat16))
    assert isinstance(rep, QPlane)
    np.testing.assert_array_equal(np.asarray(rep.scale), 1.0)
    # dequant with unit scale == plain bf16→f32 upcast, bitwise
    np.testing.assert_array_equal(
        np.asarray(dequantize(rep)),
        np.asarray(plane.astype(jnp.bfloat16).astype(jnp.float32)))


def test_topk_roundtrip_and_k_bounds():
    comp = CompressionConfig(kind="topk", topk_frac=0.1)
    assert topk_k(comp, 100) == 10
    assert topk_k(comp, 3) == 1  # floor at 1
    assert topk_k(CompressionConfig(kind="topk", topk_frac=1.0), 7) == 7
    plane = jnp.asarray(RNG.normal(size=(4, 50)), jnp.float32)
    rep = sparsify_topk(plane, 5)
    assert isinstance(rep, TopKPlane)
    dense = np.asarray(densify_topk(rep, 50))
    for c in range(4):
        nz = np.flatnonzero(dense[c])
        assert len(nz) == 5
        # kept entries are exact and are the top-5 magnitudes of the row
        row = np.asarray(plane[c])
        np.testing.assert_array_equal(dense[c][nz], row[nz])
        kept = set(nz)
        top5 = set(np.argsort(-np.abs(row))[:5])
        assert kept == top5


def test_error_feedback_semantics():
    """Active rows: sent + residual' == plane + residual (nothing is ever
    lost, only deferred).  Inactive rows: residual untouched, recon zero
    (they must fold as zeros, not as a stale accumulator)."""
    comp = CompressionConfig(kind="topk", topk_frac=0.2)
    plane = jnp.asarray(RNG.normal(size=(3, 20)), jnp.float32)
    res = jnp.asarray(RNG.normal(size=(3, 20)) * 0.1, jnp.float32)
    active = jnp.asarray([1.0, 0.0, 1.0])
    rep, recon, new_res = error_feedback_topk(comp, plane, res, active, 20)
    recon, new_res = np.asarray(recon), np.asarray(new_res)
    acc = np.asarray(plane) + np.asarray(res)
    for c in (0, 2):  # active: conservation of the accumulated signal
        np.testing.assert_allclose(recon[c] + new_res[c], acc[c],
                                   rtol=1e-6, atol=1e-7)
        assert np.count_nonzero(recon[c]) == topk_k(comp, 20)
    np.testing.assert_array_equal(recon[1], 0.0)  # inactive folds as zero
    np.testing.assert_array_equal(new_res[1], np.asarray(res)[1])


def test_wire_bytes_accounting():
    P = 1000
    assert wire_plane_bytes(None, P, 4 * P) == 4 * P
    assert wire_plane_bytes(CompressionConfig(kind="bf16"), P, 4 * P) == 2 * P
    assert wire_plane_bytes(CompressionConfig(kind="int8"), P, 4 * P) == P + 4
    comp = CompressionConfig(kind="topk", topk_frac=0.01)
    assert wire_plane_bytes(comp, P, 4 * P) == 10 * 8
    # top-k only sparsifies the delta stream; other wire planes ride f32
    assert uplink_bytes_per_client(comp, ("delta", "state_delta"), P, 4 * P) \
        == 10 * 8 + 4 * P
    assert uplink_bytes_per_client(
        CompressionConfig(kind="int8"), ("delta", "extra"), P, 4 * P
    ) == 2 * (P + 4)


def test_round_keys_are_plane_and_round_distinct():
    comp = CompressionConfig(kind="int8", seed=3)
    k2, k3 = round_key(comp, 2), round_key(comp, 3)
    assert not np.array_equal(np.asarray(k2), np.asarray(k3))
    kd, ks = plane_key(k2, "delta"), plane_key(k2, "state_delta")
    assert not np.array_equal(np.asarray(kd), np.asarray(ks))


def test_validate_compression_rejects_malformed():
    with pytest.raises(ValueError, match="unknown compression kind"):
        validate_compression(CompressionConfig(kind="int4"))
    with pytest.raises(ValueError, match="topk_frac"):
        validate_compression(CompressionConfig(kind="topk", topk_frac=0.0))
    with pytest.raises(ValueError, match="topk_frac"):
        validate_compression(CompressionConfig(kind="topk", topk_frac=1.5))


# ---------------------------------------------------------------------------
# 2. fused dequant kernel vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C,P", [(3, 129), (8, 1000)])
@pytest.mark.parametrize("kind", ["int8", "bf16"])
def test_dequant_kernel_matches_ref(C, P, kind):
    """The fused dequantize→mean→EMA→step pass equals the jnp reference
    AND the dense fused_server_step over the pre-dequantized plane."""
    from repro.kernels.server_update.ops import dequant_server_step, fused_server_step
    from repro.kernels.server_update.ref import dequant_server_update_ref

    plane = jnp.asarray(RNG.normal(size=(C, P)), jnp.float32)
    if kind == "int8":
        rep = quantize_int8(plane, jax.random.PRNGKey(1))
    else:
        rep = as_qplane(plane.astype(jnp.bfloat16))
    wn = jnp.full((C,), 1.0 / C, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    out = dequant_server_step(rep.q, rep.scale, wn, x, m, 0.9, 0.1, -2.0)
    coefs = jnp.asarray([0.9, 0.1, -2.0, 1.0], jnp.float32)
    ref = dequant_server_update_ref(rep.q, rep.scale, wn, x, m, coefs)
    dense = fused_server_step(dequantize(rep), wn, x, m, 0.9, 0.1, -2.0)
    for o, r, d in zip(out, ref, dense):
        assert o.shape == (P,)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(np.asarray(o), np.asarray(d),
                                   rtol=2e-5, atol=2e-6)


def test_dequant_kernel_masked_client_contributes_nothing():
    from repro.kernels.server_update.ops import dequant_server_step

    C, P = 4, 257
    plane = jnp.asarray(RNG.normal(size=(C, P)), jnp.float32)
    w = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    wn = w / jnp.sum(w)
    rep = quantize_int8(plane, jax.random.PRNGKey(2))
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    out = dequant_server_step(rep.q, rep.scale, wn, x, m, 0.9, 0.1, -2.0)
    garbage = QPlane(q=rep.q.at[-1].set(127), scale=rep.scale.at[-1].set(1e9))
    out_g = dequant_server_step(garbage.q, garbage.scale, wn, x, m, 0.9, 0.1, -2.0)
    for o, og in zip(out, out_g):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(og))


# ---------------------------------------------------------------------------
# 3. registry validation
# ---------------------------------------------------------------------------

def test_registry_rejects_topk_without_residual_stream():
    spec = get_algorithm("fedcm")
    with pytest.raises(ValueError, match="needs_residual"):
        _validate(spec._replace(uplink_compression="topk"))
    # scaffold's client_state rides the wire — declaring lossy compression
    # on it without the residual stream must be refused the same way
    sc = get_algorithm("scaffold")
    assert sc.client_state_uplink
    with pytest.raises(ValueError, match="needs_residual"):
        _validate(sc._replace(uplink_compression="topk"))
    with pytest.raises(ValueError, match="only 'topk' carries residuals"):
        _validate(spec._replace(needs_residual=True, uplink_compression="int8"))
    with pytest.raises(ValueError, match="unknown uplink_compression"):
        _validate(spec._replace(uplink_compression="int4"))
    # the valid declaration passes
    _validate(spec._replace(uplink_compression="topk", needs_residual=True))


def test_engine_requires_flat_plane_for_compression():
    with pytest.raises(ValueError, match="flat"):
        _setup("fedcm", compression=CompressionConfig(kind="int8"),
               use_flat_plane=False)


# ---------------------------------------------------------------------------
# 4. engine end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["int8", "bf16"])
def test_compressed_run_close_to_uncompressed_oracle(kind):
    """Quantization noise is bounded: a compressed 3-round trajectory stays
    within a per-round-noise tolerance of the f32 oracle, on both the jnp
    and the fused dequant-fold routes — and the two routes agree with each
    other to kernel noise."""
    comp = CompressionConfig(kind=kind, seed=0)
    _, eng_f32, data, model = _setup("fedcm", use_fused_kernel=True)
    st_f32, _ = eng_f32.run_rounds(_fresh_state(eng_f32, model), data, 3)
    outs = {}
    for kernel in (True, False):
        _, eng, data_c, _ = _setup("fedcm", use_fused_kernel=kernel,
                                   compression=comp)
        st, ms = eng.run_rounds(_fresh_state(eng, model), data_c, 3)
        outs[kernel] = st
        # loose bound: per-round rounding noise ~ scale·eta ≪ 1e-2 here
        _assert_trees_close(st.params, st_f32.params, rtol=0.0, atol=5e-3)
    _assert_trees_close(outs[True].params, outs[False].params,
                        rtol=2e-5, atol=2e-6)


def test_compression_accounting_reaches_metrics():
    P = 212  # mlp (8, 16, 4) plane
    comp = CompressionConfig(kind="int8")
    _, eng, data, model = _setup("fedcm", compression=comp,
                                 use_fused_kernel=True)
    st, ms = eng.run_rounds(_fresh_state(eng, model), data, 2)
    per_client = int(np.asarray(ms.bytes_up)[-1]) / int(np.asarray(ms.n_active)[-1])
    assert per_client == P + 4  # int8 byte/elem + one f32 row scale
    assert eng.payload_bytes(st.params)["up_per_client"] == P + 4


def test_async_ring_carries_compression():
    """The async engine folds compressed in-flight cohorts: jnp and kernel
    routes agree, and int8 stays near the f32 async oracle."""
    outs = {}
    for kind in (None, "int8"):
        comp = None if kind is None else CompressionConfig(kind=kind)
        for kernel in (True, False):
            _, eng, data, model = _setup("fedcm", use_fused_kernel=kernel,
                                         compression=comp)
            st, _ = eng.run_rounds_async(_fresh_state(eng, model), data, 4,
                                         pipeline_depth=2, staleness=1)
            outs[(kind, kernel)] = st
    _assert_trees_close(outs[("int8", True)].params,
                        outs[("int8", False)].params, rtol=2e-5, atol=2e-6)
    _assert_trees_close(outs[("int8", True)].params,
                        outs[(None, True)].params, rtol=0.0, atol=5e-3)


def test_topk_residuals_initialized_and_updated():
    comp = CompressionConfig(kind="topk", topk_frac=0.1)
    _, eng, data, model = _setup("fedcm", compression=comp)
    st = _fresh_state(eng, model)
    assert st.residuals is not None and st.residuals.shape == (10, 212)
    np.testing.assert_array_equal(np.asarray(st.residuals), 0.0)
    st, _ = eng.run_rounds(st, data, 2)
    # the sampled cohort's rows accumulated unsent mass; others stayed zero
    assert np.any(np.asarray(st.residuals) != 0.0)


def test_residuals_roundtrip_save_fed_run_resident(tmp_path):
    comp = CompressionConfig(kind="topk", topk_frac=0.1)
    _, eng, data, model = _setup("fedcm", compression=comp)
    st, _ = eng.run_rounds(_fresh_state(eng, model), data, 2)
    save_fed_run(str(tmp_path), 2, st)
    restored, pop, res, meta = load_fed_run(str(tmp_path), 2, st)
    assert pop is None and res is None  # resident: rides the state template
    _assert_trees_equal(st, restored)
    np.testing.assert_array_equal(np.asarray(st.residuals),
                                  np.asarray(restored.residuals))


def _store_setup(algo, comp, num_clients=64, **kw):
    cfg = FedConfig(algo=algo, num_clients=num_clients, cohort_size=8,
                    local_steps=2, population_store="host",
                    compression=comp, **kw)
    data = StreamingClientData(num_clients, dim=8, n_classes=4, seed=0)
    model = mlp_classifier((8, 16, 4))
    eng = FederatedEngine(cfg, classification_loss(model.apply), batch_size=8)
    st = eng.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    return eng, data, st


def test_residuals_roundtrip_save_fed_run_host_store(tmp_path):
    comp = CompressionConfig(kind="topk", topk_frac=0.1)
    eng_a, data, st_a = _store_setup("fedcm", comp)
    assert eng_a.residual_population is not None
    st_a, _ = eng_a.run_rounds_store(st_a, data, 4)
    save_fed_run(str(tmp_path), 2, st_a,
                 population=eng_a.population,
                 residuals=eng_a.residual_population)
    eng_b, _, st_b = _store_setup("fedcm", comp)
    st_b, pop, res, meta = load_fed_run(str(tmp_path), None, st_b,
                                        num_clients=64)
    assert meta["step"] == 2 and res is not None
    np.testing.assert_array_equal(
        np.asarray(res.to_pytree()["rows"]),
        np.asarray(eng_a.residual_population.to_pytree()["rows"]))


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_kill_and_resume_is_bitwise_with_compression(kind):
    """4 straight rounds == 2 + snapshot + restore + 2 with compression on:
    the per-round rounding keys are absolute-round-keyed, and the EF
    residual plane rides the snapshot, so the trajectory continues bitwise
    through the checkpoint boundary."""
    import tempfile

    comp = CompressionConfig(kind=kind, topk_frac=0.1, seed=5)
    _, eng, data, model = _setup("fedcm", compression=comp)
    st_full, _ = eng.run_rounds(_fresh_state(eng, model), data, 2)
    st_full, _ = eng.run_rounds(st_full, data, 2)

    st_half, _ = eng.run_rounds(_fresh_state(eng, model), data, 2)
    with tempfile.TemporaryDirectory() as d:
        save_fed_run(d, 2, st_half)
        st_resumed, _pop, _res, _ = load_fed_run(d, None, st_half)
    st_resumed, _ = eng.run_rounds(st_resumed, data, 2)
    _assert_trees_equal(st_full, st_resumed)


@pytest.mark.parametrize("comp", [None,
                                  CompressionConfig(kind="int8"),
                                  CompressionConfig(kind="topk", topk_frac=0.1)])
def test_store_prefetch_loop_is_bitwise(comp):
    """The double-buffered host-store loop (store_prefetch) is bitwise the
    synchronous loop: final params, population rows, and EF residual rows
    all match exactly — the prefetched sample is provably the same draw."""
    finals = {}
    for pf in (False, True):
        eng, data, st = _store_setup("scaffold", comp, store_prefetch=pf)
        st, _ = eng.run_rounds_store(st, data, 5)
        finals[pf] = (st, eng)
    _assert_trees_equal(finals[False][0].params, finals[True][0].params)
    np.testing.assert_array_equal(
        np.asarray(finals[False][1].population.to_pytree()["rows"]),
        np.asarray(finals[True][1].population.to_pytree()["rows"]))
    if comp is not None and comp.kind == "topk":
        np.testing.assert_array_equal(
            np.asarray(finals[False][1].residual_population.to_pytree()["rows"]),
            np.asarray(finals[True][1].residual_population.to_pytree()["rows"]))


def test_store_async_launch_with_compression():
    comp = CompressionConfig(kind="topk", topk_frac=0.1)
    eng, data, st = _store_setup("scaffold", comp)
    st, ms = eng.run_rounds_store_async(st, data, 4, pipeline_depth=2,
                                        staleness=1)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in _leaves(st.params))
    rows = np.asarray(eng.residual_population.to_pytree()["rows"])
    assert rows.size and np.any(rows != 0.0)
